"""Ingestion benchmark: streamed vs materialized load, time + peak host RAM.

The paper's creation claim (§4.2.2) is that ds-arrays are built one
block-row at a time so no process ever holds the full matrix.  This bench
generates text / svmlight / npy fixtures of ``GN`` block rows, loads each
through the streaming loader AND the one-shot materializing oracle, and
measures tracemalloc peak host bytes for both — the streamed-vs-
materialized peak-memory ratio is the headline number, next to the
``costmodel.ingest_peak_ratio`` law prediction.  Wall-clock per load rides
along so the streaming overhead stays visible.

``run()`` fills ``JSON_RECORDS``; ``benchmarks/run.py`` dumps them to
``BENCH_io.json`` (op, format, rows, cols, block_rows, us_per_call,
peak_streamed, peak_materialized, ratio, blockrow_bytes, law_ratio).
"""

from __future__ import annotations

import gc
import os
import tempfile
import tracemalloc
from typing import Callable, Dict, List, Tuple

import numpy as np

from benchmarks.common import Row, obs_fields, time_call
from repro.core import costmodel
from repro.core import io as rio
from repro.core import sparse as sparse_mod
from repro.core.dsarray import from_array

JSON_RECORDS: List[Dict] = []

GN = int(os.environ.get("REPRO_BENCH_IO_BLOCKROWS", "8"))
BN, BM, M = 256, 128, 256
N = GN * BN
DENSITY = 0.1


def _peak(fn: Callable) -> Tuple[float, object]:
    """(tracemalloc peak bytes, result) of one warmed call."""
    fn()                                    # warm jit / trace paths
    gc.collect()
    tracemalloc.start()
    out = fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return float(peak), out


def _fixtures(d: str):
    rng = np.random.default_rng(0)
    dense = rng.normal(size=(N, M)).astype(np.float32)
    txt = os.path.join(d, "bench.txt")
    np.savetxt(txt, dense, delimiter=",", fmt="%.4e")
    npy = os.path.join(d, "bench.npy")
    np.save(npy, dense)
    import scipy.sparse as ssp
    S = ssp.random(N, M, density=DENSITY, random_state=0, format="csr",
                   dtype=np.float32)
    svm = os.path.join(d, "bench.svm")
    with open(svm, "w") as f:
        for i in range(N):
            row = S.getrow(i).tocoo()
            f.write(f"{float(i % 2)} " + " ".join(
                f"{c + 1}:{v:.4e}" for c, v in zip(row.col, row.data))
                + "\n")
    return txt, npy, svm, S


def _record(fmt: str, us: float, peak_s: float, peak_m: float) -> None:
    row_bytes = costmodel.ingest_blockrow_bytes(M // BM, BN, BM, 4)
    JSON_RECORDS.append({
        "op": "load_streamed", "format": fmt, "rows": N, "cols": M,
        "block_rows": GN, "us_per_call": us,
        "peak_streamed": peak_s, "peak_materialized": peak_m,
        "ratio": peak_m / max(peak_s, 1.0),
        "blockrow_bytes": row_bytes,
        "law_ratio": costmodel.ingest_peak_ratio(
            GN, M // BM, BN, BM, 4, 1 << 16), **obs_fields()})


def run() -> List[Row]:
    JSON_RECORDS.clear()
    rows: List[Row] = []
    with tempfile.TemporaryDirectory() as d:
        txt, npy, svm, S = _fixtures(d)
        cases = [
            ("txt",
             lambda: rio.load_txt_file(txt, (BN, BM)),
             lambda: rio.load_txt(txt, (BN, BM))),
            ("svmlight",
             lambda: rio.load_svmlight_file(svm, (BN, BM), n_features=M),
             lambda: sparse_mod.from_scipy(S, (BN, BM))),
            ("npy",
             lambda: rio.load_npy_rows(npy, (BN, BM)),
             lambda: from_array(np.load(npy), (BN, BM))),
        ]
        for fmt, streamed, materialized in cases:
            peak_s, _ = _peak(streamed)
            peak_m, _ = _peak(materialized)
            us = time_call(streamed, warmup=0, iters=2)
            _record(fmt, us, peak_s, peak_m)
            rec = JSON_RECORDS[-1]
            rows.append((
                f"io/load_{fmt}_{N}x{M}", us,
                f"peak_ratio={rec['ratio']:.1f}x;"
                f"streamed_blockrows="
                f"{peak_s / rec['blockrow_bytes']:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
