"""Beyond-paper table — distributed matmul schedules for ds-array ``@``.

The paper's conclusions call out matmul as the op that makes dislib 'a
distributed NumPy'; on TPU the schedule choice (GSPMD einsum vs explicit
SUMMA vs Cannon) decides the collective pattern.  This bench reports the
analytic per-device collective bytes per schedule at pod scale and measures
small-scale correctness timing (single device).
"""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row, time_call
from repro.core import costmodel, from_array


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1024, 1024)).astype(np.float32)
    y = rng.normal(size=(1024, 1024)).astype(np.float32)
    a = from_array(x, (128, 128))
    b = from_array(y, (128, 128))
    f = jax.jit(lambda a, b: a @ b)
    t = time_call(lambda: f(a, b).blocks)
    out = np.asarray(f(a, b).collect())
    ok = np.allclose(out, x @ y, atol=1e-2)
    rows.append(("matmul/measured/blocked_1dev", t,
                 f"allclose={ok};flops={2 * 1024**3:.2e}"))

    # pod-scale analytic bytes per device (16x16 mesh, bf16)
    n = k = m = 46080
    summa = costmodel.tpu_summa_bytes(n, k, m, 2, 16, 16)
    rows.append(("matmul/model/summa_bytes_per_dev", 0.0,
                 f"{summa:.3e}B={costmodel.collective_time_s(summa)*1e3:.1f}ms"))
    # Cannon: same volume, nearest-neighbour only (overlap-friendly)
    rows.append(("matmul/model/cannon_bytes_per_dev", 0.0,
                 f"{summa:.3e}B;neighbour_only=True"))
    compute_s = 2.0 * n * k * m / 256 / 197e12
    rows.append(("matmul/model/compute_per_dev", 0.0,
                 f"{compute_s*1e3:.1f}ms;comm/compute="
                 f"{costmodel.collective_time_s(summa)/compute_s:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
