"""Beyond-paper table — distributed matmul schedules for ds-array ``@``.

The paper's conclusions call out matmul as the op that makes dislib 'a
distributed NumPy'; on TPU the schedule choice (GSPMD einsum vs explicit
SUMMA vs Cannon) decides the collective pattern and the local-GEMM backend
(stacked einsum vs the fused Pallas kernel) decides the HBM traffic.  This
bench reports the analytic per-device collective bytes per schedule at pod
scale and measures the einsum-vs-``stacked_matmul`` local GEMM at 1024²,
2048² and 4096² (Pallas runs compiled on TPU, interpret mode elsewhere).

``run()`` also fills ``JSON_RECORDS`` — one dict per measured GEMM:
``{"op", "size", "us_per_call", "backend"}`` — which ``benchmarks/run.py``
dumps to ``BENCH_matmul.json`` so the perf trajectory is machine-trackable
across PRs.
"""

from __future__ import annotations

import os
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Row, obs_fields, time_call
from repro.core import costmodel, from_array
from repro.kernels.matmul.ops import local_matmul

# filled by run(); dumped by benchmarks/run.py as BENCH_matmul.json
JSON_RECORDS: List[Dict] = []


def _record(op: str, size: int, us: float, backend: str) -> None:
    """One JSON record per measured GEMM.  ``interpret: true`` marks
    interpret-mode Pallas timings (a CPU emulation of the kernel, orders of
    magnitude off compiled-TPU numbers): they prove the lowering, but MUST
    be excluded from headline einsum-vs-stacked comparisons or they poison
    the cross-PR perf trajectory."""
    JSON_RECORDS.append({"op": op, "size": size, "us_per_call": us,
                         "backend": backend,
                         "interpret": backend == "interpret",
                         **obs_fields()})


def _gemm_rows(size: int, block: int, iters: int) -> List[Row]:
    """Measured einsum vs stacked Pallas kernel on the same block tensors."""
    rows: List[Row] = []
    rng = np.random.default_rng(size)
    x = rng.normal(size=(size, size)).astype(np.float32)
    y = rng.normal(size=(size, size)).astype(np.float32)
    a = from_array(x, (block, block)).blocks
    b = from_array(y, (block, block)).blocks
    flops = 2.0 * size ** 3

    pallas_backend = "pallas" if jax.default_backend() == "tpu" else "interpret"
    e = jax.jit(lambda p, q: local_matmul(p, q, backend="einsum"))
    k = jax.jit(lambda p, q: local_matmul(p, q, backend=pallas_backend))
    out_e, out_k = e(a, b), k(a, b)      # doubles as the jit warmup
    ok = np.allclose(np.asarray(out_e), np.asarray(out_k), atol=1e-2)
    t_e = time_call(lambda: e(a, b), warmup=0, iters=iters)
    t_k = time_call(lambda: k(a, b), warmup=0, iters=iters)
    _record("gemm_einsum", size, t_e, "einsum")
    _record("gemm_stacked", size, t_k, pallas_backend)
    rows.append((f"matmul/measured/einsum_{size}", t_e,
                 f"gflops={flops / t_e / 1e3:.1f}"))
    if pallas_backend == "interpret":
        # interpret mode emulates the kernel on CPU: report it as a lowering
        # check only, never as a headline einsum-vs-stacked speed claim
        rows.append((f"matmul/measured/stacked_{size}_interpret", t_k,
                     f"backend=interpret;allclose={ok};"
                     f"excluded_from_headline=true"))
    else:
        rows.append((f"matmul/measured/stacked_{size}", t_k,
                     f"gflops={flops / t_k / 1e3:.1f};backend={pallas_backend};"
                     f"allclose={ok};vs_einsum={t_e / t_k:.2f}x"))
    return rows


def run() -> List[Row]:
    JSON_RECORDS.clear()
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1024, 1024)).astype(np.float32)
    y = rng.normal(size=(1024, 1024)).astype(np.float32)
    a = from_array(x, (128, 128))
    b = from_array(y, (128, 128))
    f = jax.jit(lambda a, b: a @ b)
    t = time_call(lambda: f(a, b).blocks)
    out = np.asarray(f(a, b).collect())
    ok = np.allclose(out, x @ y, atol=1e-2)
    _record("dsarray_matmul", 1024, t, "auto")
    rows.append(("matmul/measured/blocked_1dev", t,
                 f"allclose={ok};flops={2 * 1024**3:.2e}"))

    # local-GEMM backend comparison: 2048² always; 4096² by default only on
    # TPU (a 4096² interpret-mode GEMM takes ~20 s/call on CPU) — override
    # either way with REPRO_BENCH_MAX_GEMM
    default_max = "4096" if jax.default_backend() == "tpu" else "2048"
    max_gemm = int(os.environ.get("REPRO_BENCH_MAX_GEMM", default_max))
    for size, iters in ((2048, 3), (4096, 1)):
        if size <= max_gemm:
            rows.extend(_gemm_rows(size, 512, iters))

    # fused-vs-loop HBM law for the 4096² local GEMM (what the fused kernel
    # deletes: (2*gk-1)x C-partial round-trips)
    gk = 4096 // 512
    fused = costmodel.stacked_gemm_hbm_bytes(gk, gk, gk, 512, 512, 512, 4)
    loop = costmodel.stacked_gemm_hbm_bytes(gk, gk, gk, 512, 512, 512, 4,
                                            fused=False)
    rows.append(("matmul/model/stacked_hbm_bytes", 0.0,
                 f"fused={fused:.3e}B;loop={loop:.3e}B;saved={loop / fused:.2f}x;"
                 f"launches={costmodel.gemm_kernel_launches(gk, False)}->1"))

    # pod-scale analytic bytes per device (16x16 mesh, bf16)
    n = k = m = 46080
    summa = costmodel.tpu_summa_bytes(n, k, m, 2, 16, 16)
    rows.append(("matmul/model/summa_bytes_per_dev", 0.0,
                 f"{summa:.3e}B={costmodel.collective_time_s(summa)*1e3:.1f}ms"))
    # Cannon: same volume, nearest-neighbour only (overlap-friendly)
    rows.append(("matmul/model/cannon_bytes_per_dev", 0.0,
                 f"{summa:.3e}B;neighbour_only=True"))
    compute_s = 2.0 * n * k * m / 256 / 197e12
    rows.append(("matmul/model/compute_per_dev", 0.0,
                 f"{compute_s*1e3:.1f}ms;comm/compute="
                 f"{costmodel.collective_time_s(summa)/compute_s:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
