"""Paper Fig. 8 — shuffle weak scaling: Datasets vs ds-arrays.

Measured: wall time at increasing partition counts (300 rows x 2 features
per 'core', as the paper).  Modeled: the task-count laws
N·min(N,S)+N vs 2N under the scheduler model at 1,536 cores.
"""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row, time_call
from repro.core import Dataset, costmodel, from_array
from repro.core.shuffle import pseudo_shuffle


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    for n in [4, 8, 16, 32]:
        x = rng.normal(size=(300 * n, 2)).astype(np.float32)  # weak scaling
        ds = Dataset.from_array(x, n)
        t0 = time.perf_counter()
        out = ds.shuffle(np.random.default_rng(1))
        t_dataset = (time.perf_counter() - t0) * 1e6
        assert np.allclose(np.sort(out.collect(), 0), np.sort(x, 0))

        a = from_array(x, (300, 2))
        key = jax.random.PRNGKey(0)
        f = jax.jit(lambda k, a: pseudo_shuffle(k, a))
        t_dsarray = time_call(lambda: f(key, a).blocks)
        size = x.shape[0] // n
        rows.append((f"fig8/measured/dataset/N={n}", t_dataset,
                     f"tasks={costmodel.dataset_shuffle_tasks(n, size)}"))
        rows.append((f"fig8/measured/dsarray/N={n}", t_dsarray,
                     f"tasks={costmodel.dsarray_shuffle_tasks(n)}"))

    # paper scale: 1,536 cores, 300 samples/core
    n = 1536
    per_task = 300 * 2 * 4 / 2e9
    t_ds = costmodel.pycompss_time(costmodel.dataset_shuffle_tasks(n, 300),
                                   per_task, n)
    t_da = costmodel.pycompss_time(costmodel.dsarray_shuffle_tasks(n),
                                   per_task, n)
    rows.append((f"fig8/model/dataset/cores={n}", t_ds * 1e6,
                 f"seconds={t_ds:.1f}"))
    rows.append((f"fig8/model/dsarray/cores={n}", t_da * 1e6,
                 f"seconds={t_da:.1f}"))
    rows.append(("fig8/model/improvement", 0.0,
                 f"{(1 - t_da / t_ds) * 100:.0f}%"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
