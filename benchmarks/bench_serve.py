"""Serving latency/throughput: p50/p99 and requests/s vs batch size.

Measures the tentpole's two claims directly:

* **warm vs cold** — the first request of a geometry against an AOT-warmed
  registry vs against a cold plan cache: the delta is the XLA compile the
  warm path moved to model-load time;
* **steady-state latency** — request streams at batch sizes 1/8/32/128
  through a warmed server, dense and bcoo at ``REPRO_BENCH_SERVE_FEATURES``
  (default 4096) features, with the plan-cache discipline recorded per
  stream (misses/opt_runs deltas MUST be zero — the zero-recompile
  acceptance, machine-checked from ``BENCH_serve.json``).

``run()`` fills ``JSON_RECORDS``; ``benchmarks/run.py`` dumps them to
``BENCH_serve.json`` (mode, format, batch size, features, p50/p99 us,
requests/s, cache-hit + recompile counters).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import Row, obs_fields
from repro.core import from_array, plan
import repro.serve as serve

JSON_RECORDS: List[Dict] = []

FEATURES = int(os.environ.get("REPRO_BENCH_SERVE_FEATURES", "4096"))
ROWS = int(os.environ.get("REPRO_BENCH_SERVE_ROWS", "1024"))
STREAM = int(os.environ.get("REPRO_BENCH_SERVE_STREAM", "64"))
BATCH_SIZES = (1, 8, 32, 128)
DENSITY = 0.01
BLOCK_ROWS = 128


def _fit_ridge():
    from repro.estimators import Ridge
    rng = np.random.default_rng(11)
    X = rng.normal(size=(ROWS, FEATURES)).astype(np.float32)
    w = rng.normal(size=(FEATURES, 1)).astype(np.float32)
    y = (X @ w).astype(np.float32)
    est = Ridge(alpha=0.1)
    est.fit(from_array(X, (BLOCK_ROWS, FEATURES)),
            from_array(y, (BLOCK_ROWS, 1)))
    return est


def _payloads(fmt: str, batch: int, count: int):
    rng = np.random.default_rng(batch)
    if fmt == "dense":
        return [rng.normal(size=(batch, FEATURES)).astype(np.float32)
                for _ in range(count)]
    import scipy.sparse as sp
    return [sp.random(batch, FEATURES, density=DENSITY, format="csr",
                      random_state=rng, dtype=np.float32)
            for _ in range(count)]


def _nse() -> int:
    # per-block capacity for the declared density, with 4x headroom for
    # the binomial tail across blocks
    return max(64, int(BLOCK_ROWS * FEATURES * DENSITY * 4))


def _record(mode: str, fmt: str, batch: int, us_p50: float, us_p99: float,
            rps: float, extra: Dict) -> None:
    JSON_RECORDS.append({
        "mode": mode, "format": fmt, "batch": batch, "features": FEATURES,
        "p50_us": us_p50, "p99_us": us_p99, "requests_per_s": rps, **extra,
        **obs_fields()})


def _stream(srv, fmt: str, batch: int, count: int) -> Dict[str, float]:
    """Serve ``count`` single-batch requests one at a time; per-request
    wall latency from the future's own clock."""
    lats = []
    t0 = time.perf_counter()
    for payload in _payloads(fmt, batch, count):
        fut = srv.submit("ridge", payload)
        srv.pump()
        fut.result()
        lats.append(fut.latency)
    wall = time.perf_counter() - t0
    lats.sort()
    return {
        "p50": lats[len(lats) // 2] * 1e6,
        "p99": lats[min(len(lats) - 1, int(len(lats) * 0.99))] * 1e6,
        "rps": count * batch / wall,
    }


def run() -> List[Row]:
    est = _fit_ridge()
    rows: List[Row] = []
    try:
        import scipy.sparse  # noqa: F401
        formats = ("dense", "bcoo")
    except ImportError:                                # pragma: no cover
        formats = ("dense",)

    for fmt in formats:
        # cold: no AOT warm, first request pays plan opt + XLA compile
        plan.clear_cache()
        serve.reset_stats()
        reg = serve.ModelRegistry()
        reg.register("ridge", est, batch_sizes=BATCH_SIZES, formats=(fmt,),
                     block_rows=BLOCK_ROWS, nse=_nse(), warm=False)
        srv = serve.PredictServer(reg)
        fut = srv.submit("ridge", _payloads(fmt, 8, 1)[0])
        t0 = time.perf_counter()
        srv.pump()
        fut.result()
        cold_us = (time.perf_counter() - t0) * 1e6

        # warm: AOT-compile at load, then the same first request
        plan.clear_cache()
        serve.reset_stats()
        t0 = time.perf_counter()
        reg.warm_all()
        warm_load_us = (time.perf_counter() - t0) * 1e6
        fut = srv.submit("ridge", _payloads(fmt, 8, 1)[0])
        t0 = time.perf_counter()
        srv.pump()
        fut.result()
        warm_us = (time.perf_counter() - t0) * 1e6
        rows.append((f"serve_first_request_cold_{fmt}", cold_us, ""))
        rows.append((f"serve_first_request_warm_{fmt}", warm_us,
                     f"{cold_us / warm_us:.1f}x"))
        _record("first_request", fmt, 8, warm_us, warm_us, 0.0, {
            "cold_us": cold_us, "warm_us": warm_us,
            "warm_load_us": warm_load_us,
            "aot_compiles": plan.cache_stats()["aot_compiles"]})

        # steady state: latency/throughput per batch size, recompiles
        # must stay frozen across the whole stream
        for batch in BATCH_SIZES:
            serve.reset_stats()
            before = plan.cache_stats()
            r = _stream(srv, fmt, batch, STREAM)
            after = plan.cache_stats()
            st = serve.stats()
            _record("steady", fmt, batch, r["p50"], r["p99"], r["rps"], {
                "requests": st["requests"],
                "cache_hits": st["cache_hits"],
                "recompiles": after["misses"] - before["misses"],
                "reopts": after["opt_runs"] - before["opt_runs"]})
            rows.append((f"serve_p50_{fmt}_b{batch}", r["p50"],
                         f"p99={r['p99']:.0f}us rps={r['rps']:.0f} "
                         f"hits={st['cache_hits']}/{st['requests']}"))
    return rows
