"""Paper Fig. 7 — ALS: Datasets vs ds-arrays.

Measured: dense reduced-scale ALS (the Netflix matrix is sparse; see
DESIGN.md §2 for the density adaptation) with identical math on both data
structures; the Dataset variant pays the up-front N^2+N transposed copy, the
ds-array variant uses the O(N)-task transpose view.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.algorithms import ALS, als_dataset
from repro.core import Dataset, costmodel, from_array


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    f = 8
    n, m = 512, 384
    r = (rng.normal(size=(n, f)) @ rng.normal(size=(f, m))).astype(np.float32)

    for parts in [4, 8, 16]:
        ds = Dataset.from_array(r, parts)
        t0 = time.perf_counter()
        u, v = als_dataset(ds, n_factors=f, max_iter=5)
        t_base = (time.perf_counter() - t0) * 1e6
        rmse_b = float(np.sqrt((((u @ v.T) - r) ** 2).mean()))

        # steady state: warm the jit cache with one fit, then time
        est = ALS(n_factors=f, max_iter=5, check_convergence=False)
        arr = from_array(r, (n // parts, m // parts))
        est.fit(arr)  # compile
        t0 = time.perf_counter()
        als = est.fit(arr)
        t_da = (time.perf_counter() - t0) * 1e6
        rec = np.asarray((als.u_ @ als.v_.transpose()).collect())
        rmse_a = float(np.sqrt(((rec - r) ** 2).mean()))

        rows.append((f"fig7/measured/dataset/N={parts}", t_base,
                     f"rmse={rmse_b:.4f};transpose_tasks="
                     f"{costmodel.dataset_transpose_tasks(parts)}"))
        rows.append((f"fig7/measured/dsarray/N={parts}", t_da,
                     f"rmse={rmse_a:.4f};transpose_tasks="
                     f"{costmodel.dsarray_transpose_tasks(parts, parts)}"))

    # paper scale (192 partitions, Netflix 17,770 x 480,189)
    tasks_ds = costmodel.dataset_als_tasks(192, 10)
    tasks_da = costmodel.dsarray_als_tasks(192, 10)
    rows.append(("fig7/model/task_ratio", 0.0,
                 f"dataset={tasks_ds};dsarray={tasks_da}"))
    # memory: Dataset ALS doubles the input matrix footprint
    bytes_in = 17770 * 480189 * 4
    rows.append(("fig7/model/memory", 0.0,
                 f"dataset={2 * bytes_in / 2**30:.1f}GiB;"
                 f"dsarray={bytes_in / 2**30:.1f}GiB"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
