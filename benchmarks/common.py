"""Shared benchmark utilities: timing, CSV rows."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn(*args) in microseconds (jax-blocking)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (list, tuple, dict)) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:                                    # noqa: BLE001
            pass
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
