"""Shared benchmark utilities: timing, CSV rows."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of fn(*args) in microseconds (jax-blocking)."""
    import jax

    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (list, tuple, dict)) else None
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        try:
            jax.block_until_ready(out)
        except Exception:                                    # noqa: BLE001
            pass
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


#: the registry slice every BENCH_*.json record embeds — the cache/retry
#: discipline behind a timing, so a perf regression in the trajectory can
#: be read against recompiles/retries without re-running anything
_OBS_KEYS = ("plan.hits", "plan.misses", "plan.launches", "plan.opt_runs",
             "plan.opt_skips", "plan.eager_launches", "plan.aot_compiles",
             "resilience.retries", "resilience.degradations")


def obs_fields() -> dict:
    """``{"obs": {...}}`` for merging into a JSON record via ``**``."""
    from repro import obs

    snap = obs.snapshot("plan")
    snap.update(obs.snapshot("resilience"))
    return {"obs": {k: int(snap.get(k, 0)) for k in _OBS_KEYS}}
