"""Render the §Roofline table from dry-run JSON artifacts.

    PYTHONPATH=src python -m benchmarks.report_roofline \
        results/dryrun_1pod.json [--md]

Per (arch × shape): three roofline terms (s), dominant bottleneck, and the
MODEL_FLOPS / HLO_FLOPs usefulness ratio.
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks.roofline import model_flops, roofline_terms
from repro.configs import get_config
from repro.models.config import get_shape_cell


def render(path: str, md: bool = False) -> list:
    with open(path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("status"),
                         "reason": r.get("reason", r.get("error", ""))[:60]})
            continue
        hlo = r["hlo"]
        terms = roofline_terms(hlo["flops"], hlo["hbm_bytes"],
                               hlo["collective_bytes"])
        cfg = get_config(r["arch"])
        cell = get_shape_cell(r["shape"])
        chips = r.get("chips", 256)
        mf = model_flops(cfg, cell, r["kind"])
        if r["kind"] == "train":
            mf *= 1  # 6ND already includes bwd
        hlo_total = hlo["flops"] * chips
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "kind": r["kind"],
            "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
            "collective_s": terms["collective_s"],
            "dominant": terms["dominant"],
            "roofline_fraction": terms["roofline_fraction"],
            "model_flops_ratio": mf / hlo_total if hlo_total else 0.0,
            "temp_gib": (r["memory"]["temp_bytes"] or 0) / 2**30,
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = render(args.path, args.md)
    if args.md:
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | roofline | 6ND/HLO | temp GiB |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | "
                      f"{r['status']} | — | — | — |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                  f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                  f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
                  f"{r['model_flops_ratio']:.2f} | {r['temp_gib']:.1f} |")
    else:
        for r in rows:
            print(r)


if __name__ == "__main__":
    main()
