"""Benchmark driver — one section per paper figure (+ beyond-paper tables).

Prints ``name,us_per_call,derived`` CSV and writes ``BENCH_matmul.json``
(one record per measured GEMM: op, size, us_per_call, backend, interpret)
and ``BENCH_lazy.json`` (lazy-vs-eager elementwise chains) next to the CSV
so the perf trajectories are machine-trackable across PRs.  GEMM records
with ``interpret: true`` are CPU emulations of the Pallas kernel and are
excluded from headline comparisons.  Roofline tables come from the dry-run
artifacts (see ``benchmarks/report_roofline.py``), not from here, since
they require the 512-device lowering.
"""

from __future__ import annotations

import json
import os


def main() -> None:
    from benchmarks import (bench_als, bench_estimators, bench_io,
                            bench_kmeans, bench_lazy, bench_matmul,
                            bench_serve, bench_shuffle, bench_slicing,
                            bench_sparse, bench_transpose)
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    for mod in (bench_transpose, bench_als, bench_shuffle, bench_slicing,
                bench_kmeans, bench_matmul, bench_lazy, bench_sparse,
                bench_estimators, bench_serve, bench_io):
        emit(mod.run())

    out = os.environ.get("REPRO_BENCH_JSON", "BENCH_matmul.json")
    with open(out, "w") as f:
        json.dump(bench_matmul.JSON_RECORDS, f, indent=2)
    print(f"# wrote {out} ({len(bench_matmul.JSON_RECORDS)} records)")

    lazy_out = os.environ.get("REPRO_BENCH_LAZY_JSON", "BENCH_lazy.json")
    with open(lazy_out, "w") as f:
        json.dump(bench_lazy.JSON_RECORDS, f, indent=2)
    print(f"# wrote {lazy_out} ({len(bench_lazy.JSON_RECORDS)} records)")

    sparse_out = os.environ.get("REPRO_BENCH_SPARSE_JSON", "BENCH_sparse.json")
    with open(sparse_out, "w") as f:
        json.dump(bench_sparse.JSON_RECORDS, f, indent=2)
    print(f"# wrote {sparse_out} ({len(bench_sparse.JSON_RECORDS)} records)")

    est_out = os.environ.get("REPRO_BENCH_EST_JSON", "BENCH_estimators.json")
    with open(est_out, "w") as f:
        json.dump(bench_estimators.JSON_RECORDS, f, indent=2)
    print(f"# wrote {est_out} ({len(bench_estimators.JSON_RECORDS)} records)")

    serve_out = os.environ.get("REPRO_BENCH_SERVE_JSON", "BENCH_serve.json")
    with open(serve_out, "w") as f:
        json.dump(bench_serve.JSON_RECORDS, f, indent=2)
    print(f"# wrote {serve_out} ({len(bench_serve.JSON_RECORDS)} records)")

    io_out = os.environ.get("REPRO_BENCH_IO_JSON", "BENCH_io.json")
    with open(io_out, "w") as f:
        json.dump(bench_io.JSON_RECORDS, f, indent=2)
    print(f"# wrote {io_out} ({len(bench_io.JSON_RECORDS)} records)")


if __name__ == "__main__":
    main()
