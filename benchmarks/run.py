"""Benchmark driver — one section per paper figure (+ beyond-paper tables).

Prints ``name,us_per_call,derived`` CSV and writes ``BENCH_matmul.json``
(one record per measured GEMM: op, size, us_per_call, backend) next to the
CSV so the matmul perf trajectory is machine-trackable across PRs.  Roofline
tables come from the dry-run artifacts (see ``benchmarks/report_roofline.py``),
not from here, since they require the 512-device lowering.
"""

from __future__ import annotations

import json
import os


def main() -> None:
    from benchmarks import (bench_als, bench_kmeans, bench_matmul,
                            bench_shuffle, bench_slicing, bench_transpose)
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    for mod in (bench_transpose, bench_als, bench_shuffle, bench_slicing,
                bench_kmeans, bench_matmul):
        emit(mod.run())

    out = os.environ.get("REPRO_BENCH_JSON", "BENCH_matmul.json")
    with open(out, "w") as f:
        json.dump(bench_matmul.JSON_RECORDS, f, indent=2)
    print(f"# wrote {out} ({len(bench_matmul.JSON_RECORDS)} records)")


if __name__ == "__main__":
    main()
