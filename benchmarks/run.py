"""Benchmark driver — one section per paper figure (+ beyond-paper tables).

Prints ``name,us_per_call,derived`` CSV.  Roofline tables come from the
dry-run artifacts (see ``benchmarks/report_roofline.py``), not from here,
since they require the 512-device lowering.
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import (bench_als, bench_kmeans, bench_matmul,
                            bench_shuffle, bench_slicing, bench_transpose)
    from benchmarks.common import emit

    print("name,us_per_call,derived")
    for mod in (bench_transpose, bench_als, bench_shuffle, bench_slicing,
                bench_kmeans, bench_matmul):
        emit(mod.run())


if __name__ == "__main__":
    main()
