"""Optimized-HLO analyzer: FLOPs / HBM bytes / collective bytes with
while-loop trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE
(verified in this container: a 10-step scan reports 1/10th the flops of the
unrolled version), which would understate a 64-layer scanned model by 64x.
This module re-derives the three roofline inputs directly from
``compiled.as_text()``:

* **flops** — 2 · prod(result dims) · prod(contracting dims) per ``dot``
  (recursing into fusion subcomputations), times the product of enclosing
  loop trip counts (``backend_config known_trip_count``; falls back to the
  loop-condition constant).
* **hbm bytes** — Σ (operand + result bytes) over top-level data-moving
  instructions.  In optimized HLO the fusion is the memory unit: every
  fusion reads its operands from HBM and writes its result, so this is a
  faithful post-fusion traffic model (elementwise chains inside a fusion
  cost nothing extra).
* **collective bytes** — Σ result bytes per collective kind (per-device
  shard sizes, since SPMD HLO is the single-device program).

All figures are PER DEVICE (the SPMD module is the per-device program).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "token": 0, "u1": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s+"
                       r"([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "domain", "iota"}

# Ops a TPU compile fuses into neighbours (the CPU backend emits them as
# standalone instructions, which would overcount HBM traffic ~10x).  The
# "fused bytes" metric skips these entirely — the producer/consumer dots,
# reduces and data-movement ops still charge their operands/results.
_FUSIBLE = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
            "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs",
            "compare", "select", "and", "or", "not", "xor", "convert",
            "clamp", "power", "sign", "floor", "ceil", "round-nearest-even",
            "round-nearest-afz", "broadcast", "reshape", "copy", "exp",
            "expm1", "log-plus-one", "logistic", "cosine", "sine",
            "is-finite", "shift-left", "shift-right-logical",
            "shift-right-arithmetic", "popcnt", "clz", "real", "imag",
            "atan2", "cbrt", "erf", "remainder", "map", "pad", "slice",
            "concatenate", "reverse", "stochastic-convert"}


def _shape_elems_bytes(dt: str, dims: str) -> Tuple[int, int]:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dt, 4)


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        _, b = _shape_elems_bytes(*m.groups())
        total += b
    return total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str          # everything after the opening paren


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    fused_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_sites: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.fused_bytes += other.fused_bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult
        for site, b in other.coll_sites.items():
            self.coll_sites[site] = self.coll_sites.get(site, 0.0) + b * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cache: Dict[str, Totals] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                cur = hdr.group(1)
                self.computations[cur] = []
                if line.startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, rtype, op, rest = m.groups()
                self.computations[cur].append(Instr(name, rtype, op, rest))

    # -- per-computation symbol table ---------------------------------------
    def _sym(self, comp: str) -> Dict[str, str]:
        return {i.name: i.result_type for i in self.computations.get(comp, [])}

    def _dot_flops(self, instr: Instr, sym: Dict[str, str]) -> float:
        out_dims = _shape_dims(instr.result_type) or []
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
        if not m:
            return 0.0
        cdims = [int(d) for d in m.group(1).split(",")] if m.group(1) else []
        ops = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
        if not ops:
            return 0.0
        lhs_type = sym.get(ops[0])
        lhs_dims = _shape_dims(lhs_type or "") or []
        k = 1
        for d in cdims:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        n_out = 1
        for d in out_dims:
            n_out *= d
        return 2.0 * n_out * k

    def analyze(self, comp: Optional[str] = None) -> Totals:
        comp = comp or self.entry
        if comp in self._cache:
            return self._cache[comp]
        t = Totals()
        sym = self._sym(comp)
        for instr in self.computations.get(comp, []):
            op = instr.op
            if op == "while":
                trip = 1
                mt = _TRIP_RE.search(instr.rest)
                if mt:
                    trip = int(mt.group(1))
                else:
                    trip = self._trip_from_cond(instr) or 1
                mb = _BODY_RE.search(instr.rest)
                if mb:
                    t.add(self.analyze(mb.group(1)), trip)
                mc = _COND_RE.search(instr.rest)
                if mc:
                    t.add(self.analyze(mc.group(1)), trip)
                continue
            if op in ("call", "async-start"):
                mcalls = _CALLS_RE.search(instr.rest)
                if mcalls:
                    t.add(self.analyze(mcalls.group(1)))

            # collectives (count -start, skip -done halves)
            base = None
            for kind in _COLLECTIVES:
                if op == kind or (op.startswith(kind) and
                                  not op.endswith("-done")):
                    base = kind
                    break
            if base is not None:
                b = _type_bytes(instr.result_type)
                t.coll[base] += b
                t.coll_counts[base] += 1
                t.bytes += b + self._operand_bytes(instr, sym)
                t.fused_bytes += b
                site = f"{base}:{self._site(instr)}"
                t.coll_sites[site] = t.coll_sites.get(site, 0.0) + b
                continue
            if op.endswith("-done"):
                continue

            if op == "dot":
                t.flops += self._dot_flops(instr, sym)
                b = (_type_bytes(instr.result_type)
                     + self._operand_bytes(instr, sym))
                t.bytes += b
                t.fused_bytes += b
                continue
            if op == "fusion":
                mcalls = _CALLS_RE.search(instr.rest)
                inner_comp = mcalls.group(1) if mcalls else None
                if inner_comp:
                    inner = self.analyze(inner_comp)
                    t.flops += inner.flops          # dots inside fusions
                b = (_type_bytes(instr.result_type)
                     + self._fusion_operand_bytes(instr, sym, inner_comp))
                t.bytes += b
                t.fused_bytes += b
                continue
            if op in ("dynamic-slice", "dynamic-update-slice", "gather"):
                # reads/writes touch only the slice, not the (possibly
                # loop-invariant stacked) full operand
                b = 2 * _type_bytes(instr.result_type if op != "gather"
                                    else instr.result_type)
                t.bytes += b
                t.fused_bytes += b
                continue
            if op in _NO_TRAFFIC:
                continue
            # generic data-moving op (copy, slice, reduce, scatter, ...)
            b = (_type_bytes(instr.result_type)
                 + self._operand_bytes(instr, sym))
            t.bytes += b
            if op not in _FUSIBLE:
                t.fused_bytes += b
        self._cache[comp] = t
        return t

    def _fusion_operand_bytes(self, instr: Instr, sym: Dict[str, str],
                              inner_comp: Optional[str]) -> int:
        """Operand bytes for a fusion, charging parameters that are consumed
        ONLY via dynamic-slice / dynamic-update-slice / gather inside the
        fused computation at their SLICE size (the actual read), not the full
        (often loop-invariant stacked-weight) array size."""
        args = instr.rest.split(")", 1)[0]
        names = _OPERAND_RE.findall(args)
        if not inner_comp or inner_comp not in self.computations:
            return sum(_type_bytes(sym.get(n, "")) for n in names)
        inner = self.computations[inner_comp]
        # param name -> operand position
        param_order = [i.name for i in inner if i.op == "parameter"]
        sliced_only: Dict[str, int] = {}   # param name -> slice bytes
        used_full = set()
        for ii in inner:
            ops_used = _OPERAND_RE.findall(ii.rest.split(")", 1)[0])
            if ii.op in ("dynamic-slice", "gather"):
                if ops_used:
                    first, rest_ops = ops_used[0], ops_used[1:]
                    sliced_only[first] = (sliced_only.get(first, 0)
                                          + _type_bytes(ii.result_type))
                    used_full.update(rest_ops)
            elif ii.op == "dynamic-update-slice":
                if ops_used:
                    # operand 0 updated in place; charge update size
                    first = ops_used[0]
                    upd = ops_used[1] if len(ops_used) > 1 else None
                    if upd:
                        sliced_only[first] = (sliced_only.get(first, 0)
                                              + _type_bytes(sym.get(upd, "")
                                                            or ""))
                    used_full.update(ops_used[2:])
            elif ii.op != "parameter":
                used_full.update(ops_used)
        total = 0
        for pos, pname in enumerate(param_order):
            if pos >= len(names):
                break
            full = _type_bytes(sym.get(names[pos], ""))
            if pname in sliced_only and pname not in used_full:
                total += min(full, sliced_only[pname])
            else:
                total += full
        return total

    @staticmethod
    def _site(instr: Instr) -> str:
        m = re.search(r'op_name="([^"]*)"', instr.rest)
        if m:
            # keep the tail of the op_name path (most informative)
            parts = m.group(1).split("/")
            return "/".join(parts[-3:])[:90]
        return instr.name[:40]

    def _operand_bytes(self, instr: Instr, sym: Dict[str, str]) -> int:
        args = instr.rest.split(")", 1)[0]
        total = 0
        for name in _OPERAND_RE.findall(args):
            tstr = sym.get(name)
            if tstr:
                total += _type_bytes(tstr)
        return total

    def _trip_from_cond(self, instr: Instr) -> Optional[int]:
        mc = _COND_RE.search(instr.rest)
        if not mc:
            return None
        for ci in self.computations.get(mc.group(1), []):
            if ci.op == "constant":
                mval = re.search(r"constant\((\d+)\)", ci.op + "(" + ci.rest)
                if mval:
                    return int(mval.group(1))
        return None


def analyze_hlo(text: str, top_sites: int = 12) -> Dict[str, float]:
    mod = HloModule(text)
    t = mod.analyze()
    out = {"flops": t.flops, "hbm_bytes": t.bytes,
           "hbm_bytes_fused": t.fused_bytes,
           "collective_bytes": sum(t.coll.values())}
    for k in _COLLECTIVES:
        out[f"{k}_bytes"] = t.coll[k]
        out[f"{k}_count"] = t.coll_counts[k]
    sites = sorted(t.coll_sites.items(), key=lambda kv: -kv[1])[:top_sites]
    out["top_collective_sites"] = [
        {"site": s, "bytes": b} for s, b in sites]
    return out
