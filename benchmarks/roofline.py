"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / (chips · 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips · 819e9 B/s HBM)
    collective = Σ per-collective operand bytes / (chips · 50e9 B/s ICI link)

``cost_analysis`` supplies FLOPs/bytes for the whole (already partitioned)
module — i.e. totals across devices — so we divide by chip count.
Collective bytes are NOT in cost_analysis: ``collective_bytes_from_hlo``
parses the post-SPMD optimized HLO and sums operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops (these are
per-PARTICIPANT shard sizes, i.e. already per-device).
"""

from __future__ import annotations

import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'dtype[dims]' spec."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _result_bytes(result_part: str) -> int:
    """Bytes of an op's result type (handles tuple results)."""
    total = 0
    for m in _SHAPE_RE.finditer(result_part):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    Using the RESULT shape is the per-device received-bytes convention:
    all-gather result = full gathered tensor (bytes that land on each
    device), reduce-scatter result = the scattered shard, all-to-all /
    collective-permute results = shard moved per device.  For all-reduce the
    result equals the input; ring traffic is 2·(P-1)/P · bytes — we report
    raw result bytes and let the roofline term apply the ring factor via
    ``ring_factor``.
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_KINDS}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match "%name = <type> <op>(" or fused kinds like all-reduce-start
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", s)
        if not m:
            continue
        result_part, op = m.groups()
        base = None
        for kind in _COLLECTIVE_KINDS:
            if op == kind or op.startswith(kind + "-"):
                base = kind
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # async pair: count only the -start
        out[base] += _result_bytes(result_part)
        counts[base] += 1
    total = sum(out.values())
    return {**{f"{k}_bytes": v for k, v in out.items()},
            **{f"{k}_count": counts[k] for k in counts},
            "total_bytes": total}


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   collective_bytes_per_dev: float) -> Dict[str, float]:
    """All inputs are PER-DEVICE (the SPMD module is the per-device program;
    see benchmarks/hlo_analysis.py)."""
    compute = flops_per_dev / PEAK_FLOPS
    memory = bytes_per_dev / HBM_BW
    collective = collective_bytes_per_dev / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant,
            "bound_s": total,
            "roofline_fraction": compute / total if total > 0 else 0.0}


def model_flops(cfg, cell, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd-only), N = active params."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch
