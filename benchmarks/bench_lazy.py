"""Lazy-plan fusion vs eager dispatch on an elementwise chain.

The lazy layer's core claim (ISSUE 3 acceptance): a 6-op elementwise chain
recorded under ``repro.lazy()`` compiles to ONE fused per-block body — one
HBM read of the operand, one write of the result — while the eager path
dispatches every op separately, reading and writing the full stacked tensor
each time.  This bench measures both on the same data at 1024² and 4096²
and reports the measured speedup next to the cost-model prediction
(``costmodel.lazy_chain_hbm_bytes``: 2 passes fused vs 2·L eager, so the
memory-bound ceiling is ~L×).

The lazy timing includes recording + plan lookup per call (the compiled
plan is cached by structural hash after the first call), so the reported
ratio is end-to-end, not kernel-only.

``run()`` fills ``JSON_RECORDS`` — ``{"op", "size", "us_per_call",
"backend", "speedup"}`` — which ``benchmarks/run.py`` dumps to
``BENCH_lazy.json`` for the cross-PR trajectory.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import numpy as np

import repro
from benchmarks.common import Row, obs_fields, time_call
from repro.core import costmodel, from_array, plan

# filled by run(); dumped by benchmarks/run.py as BENCH_lazy.json
JSON_RECORDS: List[Dict] = []

CHAIN_OPS = 6


def _chain(a):
    # add, mul, sub, abs, mul, add — 6 elementwise ops, zero-preserving-free
    # mix (two FILL pads in the middle) so pad bookkeeping is exercised too
    return ((a + 1.0) * 2.0 - 3.0).abs() * 0.5 + 0.25


def _record(op: str, size: int, us: float, speedup: float = 0.0) -> None:
    JSON_RECORDS.append({"op": op, "size": size, "us_per_call": us,
                         "backend": jax.default_backend(),
                         "speedup": round(speedup, 3), **obs_fields()})


def run() -> List[Row]:
    JSON_RECORDS.clear()
    rows: List[Row] = []
    for size, block, iters in ((1024, 256, 5), (4096, 512, 3)):
        rng = np.random.default_rng(size)
        x = rng.normal(size=(size, size)).astype(np.float32)
        a = from_array(x, (block, block))

        def eager():
            return _chain(a).blocks

        def lazy():
            with repro.lazy():
                r = _chain(a)
            return r.compute().blocks

        ok = np.allclose(np.asarray(eager()), np.asarray(lazy()), atol=1e-5)
        t_e = time_call(eager, warmup=1, iters=iters)
        t_l = time_call(lazy, warmup=1, iters=iters)
        speed = t_e / t_l
        _record(f"chain{CHAIN_OPS}_eager", size, t_e)
        _record(f"chain{CHAIN_OPS}_lazy", size, t_l, speed)
        with repro.lazy():
            stats = plan.plan_for(_chain(a)).stats
        saved = costmodel.lazy_chain_hbm_saved(CHAIN_OPS, size, size, 4)
        rows.append((f"lazy/measured/chain{CHAIN_OPS}_eager_{size}", t_e,
                     f"launches={costmodel.lazy_chain_launches(CHAIN_OPS, False)}"))
        rows.append((f"lazy/measured/chain{CHAIN_OPS}_lazy_{size}", t_l,
                     f"speedup={speed:.2f}x;allclose={ok};"
                     f"nodes={stats['nodes_before']}->{stats['nodes_after']};"
                     f"launches=1;model_hbm_saved={saved:.3e}B"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
