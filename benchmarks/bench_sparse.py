"""Sparse-vs-dense ds-array benchmark: matvec + gram across densities.

The paper's sparse story (CSVM on scipy.sparse-blocked ds-arrays) pays off
only below a crossover density — above it the value+index stream of the
BCOO format moves MORE bytes than the dense tensor.  This bench measures

* ``sp @ v`` (matvec) and ``spᵀ @ sp_dense`` (gram) at 4096², densities
  1% / 5% (the headline points) plus a sweep used to locate the measured
  crossover density — the density where the sparse path stops beating the
  jitted dense path on the same machine;
* the analytic crossover from ``costmodel.sparse_storage_crossover_density``
  (1/3 for f32+i32) next to the measured one.

``run()`` fills ``JSON_RECORDS``; ``benchmarks/run.py`` dumps them to
``BENCH_sparse.json`` (op, size, density, us_per_call, backend, nse) so the
sparse perf trajectory is machine-trackable across PRs.  CPU numbers
exercise the identical bcoo_dot_general lowering the TPU path takes; only
the absolute times change on real hardware.
"""

from __future__ import annotations

import os
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Row, obs_fields, time_call
from repro.core import costmodel, from_array, random_sparse
from repro.core import sparse as sparse_mod
from repro.core.dsarray import matmul_ta

JSON_RECORDS: List[Dict] = []

SIZE = int(os.environ.get("REPRO_BENCH_MAX_SPARSE", "4096"))
HEADLINE_DENSITIES = (0.01, 0.05)
# crossover sweep runs at <=1024² (the dense gram at 4096² x 8 densities
# would dominate the whole benchmark suite's budget)
SWEEP_SIZE = min(SIZE, 1024)
SWEEP_DENSITIES = (0.002, 0.005, 0.01, 0.05, 0.1, 0.2, 0.35, 0.5)


def _record(op: str, size: int, density: float, us: float, backend: str,
            nse: int) -> None:
    JSON_RECORDS.append({"op": op, "size": size, "density": density,
                         "us_per_call": us, "backend": backend, "nse": nse,
                         **obs_fields()})


def _mk(size: int, density: float, block: int):
    key = jax.random.PRNGKey(int(density * 1000) + size)
    s = random_sparse(key, (size, size), (block, block), density=density)
    d = s.todense()
    v = from_array(np.ones((size, 1), np.float32), (block, 1))
    return s, d, v


def _measure_pair(size: int, density: float, block: int, iters: int):
    """(matvec_sparse_us, matvec_dense_us, gram_sparse_us, gram_dense_us)"""
    s, d, v = _mk(size, density, block)
    mv_s = jax.jit(lambda a, b: (a @ b).blocks)
    mv_d = jax.jit(lambda a, b: (a @ b).blocks)
    gr_s = jax.jit(lambda a, b: matmul_ta(a, b).blocks)
    gr_d = jax.jit(lambda a, b: matmul_ta(a, b).blocks)
    out_s = np.asarray(mv_s(s, v))
    out_d = np.asarray(mv_d(d, v))
    ok = np.allclose(out_s, out_d, atol=1e-2)
    t_mv_s = time_call(lambda: mv_s(s, v), warmup=0, iters=iters)
    t_mv_d = time_call(lambda: mv_d(d, v), warmup=0, iters=iters)
    gr_s(s, d), gr_d(d, d)                          # jit warmup
    t_gr_s = time_call(lambda: gr_s(s, d), warmup=0, iters=iters)
    t_gr_d = time_call(lambda: gr_d(d, d), warmup=0, iters=iters)
    return t_mv_s, t_mv_d, t_gr_s, t_gr_d, ok, int(s.blocks.nse)


def _crossover(measured) -> float:
    """Density where sparse stops winning (``ratio`` = dense/sparse time,
    measured at ascending densities), linearly interpolated.  0.0 means the
    sparse path never won on this backend even at the lowest density (the
    CPU einsum case); the max measured density means it always won."""
    if not measured:
        return 0.0
    if measured[0][1] < 1.0:
        return 0.0
    prev_d, prev_r = measured[0]
    for dens, ratio in measured[1:]:
        if ratio < 1.0 <= prev_r:
            frac = (prev_r - 1.0) / max(prev_r - ratio, 1e-9)
            return prev_d + frac * (dens - prev_d)
        prev_d, prev_r = dens, ratio
    return measured[-1][0]


def run() -> List[Row]:
    JSON_RECORDS.clear()
    rows: List[Row] = []
    backend = jax.default_backend()

    # headline points: 1% / 5% density at the full size
    block = 256 if SIZE >= 1024 else max(32, SIZE // 4)
    for dens in HEADLINE_DENSITIES:
        t_mv_s, t_mv_d, t_gr_s, t_gr_d, ok, nse = _measure_pair(
            SIZE, dens, block, iters=2)
        _record("matvec_sparse", SIZE, dens, t_mv_s, backend, nse)
        _record("matvec_dense", SIZE, dens, t_mv_d, backend, 0)
        _record("gram_sparse", SIZE, dens, t_gr_s, backend, nse)
        _record("gram_dense", SIZE, dens, t_gr_d, backend, 0)
        rows.append((f"sparse/matvec_{SIZE}_d{dens}", t_mv_s,
                     f"vs_dense={t_mv_d / t_mv_s:.2f}x;allclose={ok}"))
        rows.append((f"sparse/gram_{SIZE}_d{dens}", t_gr_s,
                     f"vs_dense={t_gr_d / t_gr_s:.2f}x"))

    # density sweep for the measured crossover (smaller size: see above)
    sweep_block = 256 if SWEEP_SIZE >= 1024 else max(32, SWEEP_SIZE // 4)
    matvec_ratios, gram_ratios = [], []
    for dens in SWEEP_DENSITIES:
        t_mv_s, t_mv_d, t_gr_s, t_gr_d, ok, nse = _measure_pair(
            SWEEP_SIZE, dens, sweep_block, iters=3)
        matvec_ratios.append((dens, t_mv_d / t_mv_s))
        gram_ratios.append((dens, t_gr_d / t_gr_s))
        _record("matvec_sparse", SWEEP_SIZE, dens, t_mv_s, backend, nse)
        _record("matvec_dense", SWEEP_SIZE, dens, t_mv_d, backend, 0)
        _record("gram_sparse", SWEEP_SIZE, dens, t_gr_s, backend, nse)
        _record("gram_dense", SWEEP_SIZE, dens, t_gr_d, backend, 0)

    mv_x = _crossover(matvec_ratios)
    gr_x = _crossover(gram_ratios)
    analytic = costmodel.sparse_storage_crossover_density(4)
    _record("crossover_matvec", SWEEP_SIZE, mv_x, 0.0, backend, 0)
    _record("crossover_gram", SWEEP_SIZE, gr_x, 0.0, backend, 0)
    _record("crossover_analytic", SWEEP_SIZE, analytic, 0.0, "costmodel", 0)
    rows.append((f"sparse/crossover_matvec_{SWEEP_SIZE}", 0.0,
                 f"density={mv_x:.3f};analytic={analytic:.3f}"))
    rows.append((f"sparse/crossover_gram_{SWEEP_SIZE}", 0.0,
                 f"density={gr_x:.3f};analytic={analytic:.3f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
