"""Structural ops — block-native vs the seed materialize-then-reblock path.

The seed implementation of ``__getitem__``/``rechunk``/``concat_rows`` built
the global ``(n, m)`` layout (``_global_padded``) and re-blocked it with
``from_array`` — O(n·m) work and memory for ANY selection, and it silently
collapsed sharded operands onto one device.  The block-native subsystem
(``core.structural``) makes an aligned slice a grid slice, a rechunk a
regroup reshape, and a concat a grid stack.

Measured in **eager** mode, which is how structural ops are dispatched in
user code (estimator ``fit`` loops, minibatching, factor slicing) — this is
where the seed path actually pays its O(n·m) relayouts.  ``jit`` rows are
reported too: under jit XLA fuses the seed path's global relayout down to
O(selected) as well, so the gap narrows — the block-native win under jit is
the absent full-size intermediate (memory) and preserved sharding, which the
no-global-intermediate tests assert on the jaxpr.

Acceptance headline: ``slicing/aligned/.../speedup`` ≥ 10x at the 8192²
default size.
"""

from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, time_call
from repro.core import ceil_div, concat_rows, costmodel, from_array
from repro.core.dsarray import DsArray


# ---------------------------------------------------------------------------
# The seed paths, preserved verbatim for comparison (they were deleted from
# DsArray when core.structural landed).
# ---------------------------------------------------------------------------


def _seed_slice(a: DsArray, r0, r1, c0, c1, bs) -> jnp.ndarray:
    g = a._global_padded()[: a.shape[0], : a.shape[1]]
    return from_array(g[r0:r1, c0:c1], bs).blocks


def _seed_filter(a: DsArray, idx, bs) -> jnp.ndarray:
    g = a._global_padded()[: a.shape[0], : a.shape[1]]
    return from_array(g[idx], bs).blocks


def _seed_rechunk(a: DsArray, bs) -> jnp.ndarray:
    g = a._global_padded()[: a.shape[0], : a.shape[1]]
    return from_array(g, bs).blocks


def _seed_concat(parts, bs) -> jnp.ndarray:
    glob = jnp.concatenate([p.collect() for p in parts], axis=0)
    return from_array(glob, bs).blocks


def _pair(rows: List[Row], name: str, new_fn, old_fn, derived: str) -> float:
    """Time eager new/old + jitted new/old; emit rows; return eager speedup."""
    t_new = time_call(new_fn)
    t_old = time_call(old_fn)
    t_new_j = time_call(jax.jit(new_fn))
    t_old_j = time_call(jax.jit(old_fn))
    speedup = t_old / max(t_new, 1e-9)
    rows.append((f"{name}/block-native", t_new, derived))
    rows.append((f"{name}/seed-materialize", t_old, f"x{speedup:.1f}"))
    rows.append((f"{name}/jit/block-native", t_new_j,
                 f"jit-fused-x{t_old_j / max(t_new_j, 1e-9):.1f}"))
    rows.append((f"{name}/jit/seed-materialize", t_old_j, ""))
    return speedup


def run(size: int = 8192, block: int = 512) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    x = rng.normal(size=(size, size)).astype(np.float32)
    a = from_array(x, (block, block))
    jax.block_until_ready(a.blocks)
    n, bn = size, block
    half = (n // 2 // bn) * bn          # block-aligned midpoint

    # ---- block-aligned slice: grid slice vs global materialize ------------
    r0, r1, c0, c1 = 0, half, bn, half + bn
    sp = _pair(
        rows, f"slicing/aligned/{size}x{size}",
        lambda: a[r0:r1, c0:c1].blocks,
        lambda: _seed_slice(a, r0, r1, c0, c1, (bn, bn)),
        f"tasks={costmodel.dsarray_slice_tasks(ceil_div(r1 - r0, bn), ceil_div(c1 - c0, bn))}")
    rows.append((f"slicing/aligned/{size}x{size}/speedup", 0.0, f"x{sp:.1f}"))

    # ---- unaligned slice (gather lowering) --------------------------------
    r0u, r1u = 7, half + 7
    _pair(rows, f"slicing/unaligned/{size}x{size}",
          lambda: a[r0u:r1u, c0:c1].blocks,
          lambda: _seed_slice(a, r0u, r1u, c0, c1, (bn, bn)),
          f"tasks={costmodel.dsarray_filter_tasks(ceil_div(r1u - r0u, bn), ceil_div(c1 - c0, bn))}")

    # ---- integer-array row filter -----------------------------------------
    idx = jnp.asarray(rng.choice(n, size=n // 4, replace=False).astype(np.int32))
    fb = min(bn, n // 4)
    _pair(rows, f"slicing/filter-quarter/{size}x{size}",
          lambda: a[idx].blocks,
          lambda: _seed_filter(a, idx, (fb, bn)),
          f"bytes={costmodel.tpu_filter_bytes(n // 4, size, 4, 1, 1):.2e}")

    # ---- rechunk, evenly dividing (regroup vs two global layouts) ---------
    g = ceil_div(size, bn)
    _pair(rows, f"rechunk/split2x2/{size}x{size}",
          lambda: a.rechunk((bn // 2, bn // 2)).blocks,
          lambda: _seed_rechunk(a, (bn // 2, bn // 2)),
          f"tasks={costmodel.dsarray_rechunk_tasks(g, g)}")

    # ---- concat of two aligned parts --------------------------------------
    b = from_array(rng.normal(size=(size // 2, size)).astype(np.float32),
                   (bn, bn))
    jax.block_until_ready(b.blocks)
    _pair(rows, f"concat/2parts/{size}x{size}",
          lambda: concat_rows([a, b]).blocks,
          lambda: _seed_concat([a, b], (bn, bn)),
          f"tasks={costmodel.dsarray_concat_tasks(2)}")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
