"""Estimator fit/predict throughput, dense vs sparse, with plan-cache hits.

The estimator layer's perf story has two axes the ISSUE names:

* **dense vs bcoo** at 4096²-scale inputs — CSVM's kernel block and Ridge's
  normal equations ride ``bcoo_dot_general`` for sparse inputs, so their
  fit/predict time should track the nnz-proportional spmm laws
  (``costmodel.csvm_kernel_*``) rather than the dense GEMM's;
* **plan-cache behaviour** — a fit loop records one structural plan per
  iteration; everything after iteration 1 must be optimizer skips + compiled
  hits (``opt_runs == 1``), which this bench records per fit.

``run()`` fills ``JSON_RECORDS``; ``benchmarks/run.py`` dumps them to
``BENCH_estimators.json`` (estimator, op, size, density, us_per_call,
backend, cache stats).  ``REPRO_BENCH_MAX_EST`` caps the row count (default
4096; the full size is CPU-feasible because the data is 1% sparse and the
dense comparison uses the same moderate feature count).
"""

from __future__ import annotations

import os
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import Row, obs_fields, time_call
from repro.core import from_array, plan, random_sparse
from repro.estimators import CascadeSVM, Ridge

JSON_RECORDS: List[Dict] = []

SIZE = int(os.environ.get("REPRO_BENCH_MAX_EST", "4096"))
FEATURES = SIZE                 # the ISSUE's 4096² headline point is square
DENSITY = 0.01
BLOCK = (512, 64)


def _record(estimator: str, op: str, size: int, density: float, us: float,
            backend: str, fmt: str, cache: Dict[str, int]) -> None:
    JSON_RECORDS.append({
        "estimator": estimator, "op": op, "size": size, "density": density,
        "us_per_call": us, "backend": backend, "format": fmt,
        "opt_runs": cache.get("opt_runs", 0),
        "opt_skips": cache.get("opt_skips", 0),
        "plan_hits": cache.get("hits", 0),
        "plan_misses": cache.get("misses", 0),
        **obs_fields(),
    })


def _mk_data(n: int, m: int, density: float):
    key = jax.random.PRNGKey(7)
    sp = random_sparse(key, (n, m), BLOCK, density=density)
    dn = sp.todense()
    host = np.asarray(dn.collect())
    w = np.random.default_rng(1).normal(size=m).astype(np.float32)
    y_reg = (host @ w).astype(np.float32)
    y_cls = (y_reg > np.median(y_reg)).astype(np.int32)
    return dn, sp, y_reg, y_cls


def _fit_once(factory, x, y):
    """(median fit us, plan-cache stats of one clean fit)."""
    t = time_call(lambda: factory().fit(x, y), warmup=1, iters=2)
    plan.clear_cache()
    factory().fit(x, y)
    return t, plan.cache_stats()


def run() -> List[Row]:
    JSON_RECORDS.clear()
    rows: List[Row] = []
    backend = jax.default_backend()
    n = SIZE
    dn, sp, y_reg, y_cls = _mk_data(n, FEATURES, DENSITY)

    # Ridge: one-plan normal equations, dense vs sparse
    ridge = lambda: Ridge(alpha=1.0)                       # noqa: E731
    for label, x in (("dense", dn), ("sparse", sp)):
        t_fit, cache = _fit_once(ridge, x, y_reg)
        est = ridge().fit(x, y_reg)
        t_pred = time_call(lambda: est.predict(x).blocks, warmup=1, iters=3)
        _record("ridge", "fit", n, DENSITY, t_fit, backend, label, cache)
        _record("ridge", "predict", n, DENSITY, t_pred, backend, label, {})
        rows.append((f"est/ridge_fit_{label}_{n}", t_fit,
                     f"opt_runs={cache['opt_runs']}"))
        rows.append((f"est/ridge_predict_{label}_{n}", t_pred, ""))

    # CSVM: 5-iteration cascade, the recorded kernel-block loop
    iters = 3
    csvm = lambda: CascadeSVM(kernel="rbf", sv_cap=64,       # noqa: E731
                              max_iter=iters, tol=-1.0,
                              n_chunks=8, solver_iters=100)
    for label, x in (("dense", dn), ("sparse", sp)):
        t_fit, cache = _fit_once(csvm, x, y_cls)
        est = csvm().fit(x, y_cls)
        t_pred = time_call(lambda: est.predict(x).blocks, warmup=1, iters=3)
        _record("csvm", "fit", n, DENSITY, t_fit, backend, label, cache)
        _record("csvm", "predict", n, DENSITY, t_pred, backend, label, {})
        rows.append((f"est/csvm_fit_{label}_{n}", t_fit,
                     f"opt_runs={cache['opt_runs']};"
                     f"opt_skips={cache['opt_skips']};"
                     f"hits={cache['hits']}"))
        rows.append((f"est/csvm_predict_{label}_{n}", t_pred, ""))

    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
