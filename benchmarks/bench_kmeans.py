"""Paper Fig. 9 — K-means: the control experiment.

The paper's point: K-means is representation-neutral, so ds-arrays must show
NO regression vs Datasets.  Measured at matching partition counts; also
benchmarks the fused Pallas kernel path (interpret mode — correctness/
structure, not TPU wall-time).
"""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row
from repro.algorithms import KMeans, kmeans_dataset
from repro.core import Dataset, from_array
from repro.kernels.kmeans.ops import kmeans_assign
from repro.kernels.kmeans.ref import kmeans_assign_ref


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)
    k, d = 8, 32
    centers = rng.normal(size=(k, d)).astype(np.float32) * 6
    pts = np.concatenate([
        rng.normal(c, 0.5, size=(2000, d)).astype(np.float32)
        for c in centers])
    rng.shuffle(pts)

    for parts in [8, 16]:
        arr = from_array(pts, (pts.shape[0] // parts, d))
        est = KMeans(n_clusters=k, max_iter=10, seed=0)
        est.fit(arr)  # compile (steady-state timing below)
        t0 = time.perf_counter()
        est.fit(arr)
        t_da = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        kmeans_dataset(Dataset.from_array(pts, parts), k, max_iter=10, seed=0)
        t_ds = (time.perf_counter() - t0) * 1e6
        ratio = t_da / t_ds
        rows.append((f"fig9/measured/dsarray/N={parts}", t_da,
                     f"ratio_vs_dataset={ratio:.2f}"))
        rows.append((f"fig9/measured/dataset/N={parts}", t_ds, ""))

    # fused-kernel inner loop vs oracle (structure check)
    x = jnp_x = jax.numpy.asarray(pts[:4096])
    c = jax.numpy.asarray(centers)
    t0 = time.perf_counter()
    l1, s1, c1 = kmeans_assign(jnp_x, c, block_n=512, interpret=True)
    jax.block_until_ready(s1)
    t_kernel = (time.perf_counter() - t0) * 1e6
    l2, s2, c2 = kmeans_assign_ref(jnp_x, c)
    ok = bool((np.asarray(l1) == np.asarray(l2)).all())
    rows.append(("fig9/kernel/fused_assign(interpret)", t_kernel,
                 f"allclose={ok};flops={2 * 4096 * k * d:.2e}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
