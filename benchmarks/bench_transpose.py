"""Paper Fig. 6 — transpose: Datasets vs ds-arrays.

Measured (container scale): wall time of the Dataset N^2+N task transpose vs
the ds-array fused transpose at increasing partition counts.
Modeled (MareNostrum scale): the calibrated PyCOMPSs scheduler model at the
paper's 1,536 partitions, plus the TPU collective-byte cost of the same op.
"""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from benchmarks.common import Row, time_call
from repro.core import Dataset, costmodel, from_array


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    # ---- measured: strong scaling in partition count, fixed 1024x1024 ----
    x = rng.normal(size=(1024, 1024)).astype(np.float32)
    for n in [4, 8, 16, 32]:
        ds = Dataset.from_array(x, n)
        t0 = time.perf_counter()
        out = ds.transpose()
        t_dataset = (time.perf_counter() - t0) * 1e6
        assert np.allclose(out.collect(), x.T)

        a = from_array(x, (1024 // n, 1024 // n))
        f = jax.jit(lambda a: a.transpose())
        t_dsarray = time_call(lambda: f(a).blocks)
        rows.append((f"fig6/measured/dataset/N={n}", t_dataset,
                     f"tasks={costmodel.dataset_transpose_tasks(n)}"))
        rows.append((f"fig6/measured/dsarray/N={n}", t_dsarray,
                     f"tasks={costmodel.dsarray_transpose_tasks(n, n)}"))

    # ---- modeled: the paper's strong-scaling experiment ----
    n_sub = 1536
    per_task_s = (46080 * 46080 * 4 / 1536) / 2e9   # bytes/task over ~2GB/s
    for cores in [48, 96, 192, 384, 768]:
        t_ds = costmodel.pycompss_time(
            costmodel.dataset_transpose_tasks(n_sub), per_task_s, cores)
        t_da = costmodel.pycompss_time(
            costmodel.dsarray_transpose_tasks(n_sub, 1), per_task_s, cores)
        rows.append((f"fig6/model/dataset/cores={cores}", t_ds * 1e6,
                     f"hours={t_ds/3600:.2f}"))
        rows.append((f"fig6/model/dsarray/cores={cores}", t_da * 1e6,
                     f"seconds={t_da:.1f}"))

    # paper claim: 4.5 h -> seconds at 768 cores (>=2 orders of magnitude)
    speedup = (costmodel.pycompss_time(costmodel.dataset_transpose_tasks(n_sub),
                                       per_task_s, 768)
               / costmodel.pycompss_time(
                   costmodel.dsarray_transpose_tasks(n_sub, 1), per_task_s, 768))
    rows.append(("fig6/model/speedup@768cores", 0.0, f"x{speedup:.0f}"))

    # ---- TPU analogue: collective bytes for the same matrix ----
    b = costmodel.tpu_transpose_bytes(46080, 46080, 4, 16, 16)
    rows.append(("fig6/tpu/collective_bytes_per_dev", 0.0,
                 f"{b:.3e}B={costmodel.collective_time_s(b)*1e3:.2f}ms"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
