"""K-means and ALS: convergence + ds-array/Dataset parity (paper §5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.algorithms import ALS, KMeans, als_dataset, kmeans_dataset
from repro.core import Dataset, from_array


def blobs(seed=0, k=3, n_per=80, d=4, spread=8.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)).astype(np.float32) * spread
    pts = np.concatenate([
        rng.normal(c, 0.4, size=(n_per, d)).astype(np.float32)
        for c in centers])
    rng.shuffle(pts)
    return pts, centers


def match_error(found, true):
    d = np.linalg.norm(true[:, None, :] - found[None], axis=-1)
    return d.min(axis=1).max()


def test_kmeans_recovers_blobs():
    pts, true = blobs()
    x = from_array(pts, (32, 4))
    km = KMeans(n_clusters=3, max_iter=50, seed=0).fit(x)
    assert match_error(np.asarray(km.centers_), true) < 0.5
    labels = km.predict(x)
    assert labels.shape == (pts.shape[0], 1)
    lab = np.asarray(labels.collect()).ravel()
    assert set(np.unique(lab)) <= {0, 1, 2}
    # score is negative inertia; near-optimal clustering -> small magnitude
    assert -km.score(x) < pts.shape[0] * 4 * 0.4 ** 2 * 3


def test_kmeans_parity_with_dataset_baseline():
    """Paper Fig. 9: same algorithm, same result, either data structure."""
    pts, true = blobs(seed=1)
    km = KMeans(n_clusters=3, max_iter=50, seed=0).fit(from_array(pts, (40, 4)))
    cb = kmeans_dataset(Dataset.from_array(pts, 6), 3, max_iter=50, seed=0)
    e1 = match_error(np.asarray(km.centers_), true)
    e2 = match_error(cb, true)
    assert e1 < 0.5 and e2 < 0.5


def test_kmeans_blocking_invariance():
    """Results must not depend on the block layout (pure data-structure)."""
    pts, _ = blobs(seed=2)
    a = KMeans(n_clusters=3, max_iter=30, seed=0).fit(from_array(pts, (16, 4)))
    b = KMeans(n_clusters=3, max_iter=30, seed=0).fit(from_array(pts, (100, 2)))
    np.testing.assert_allclose(np.asarray(a.centers_),
                               np.asarray(b.centers_), atol=1e-3)


def test_als_low_rank_recovery():
    rng = np.random.default_rng(0)
    f = 4
    u0 = rng.normal(size=(50, f)).astype(np.float32)
    v0 = rng.normal(size=(40, f)).astype(np.float32)
    r = u0 @ v0.T
    als = ALS(n_factors=f, reg=1e-3, max_iter=25, tol=1e-7).fit(
        from_array(r, (16, 16)))
    rec = np.asarray((als.u_ @ als.v_.transpose()).collect())
    assert np.sqrt(((rec - r) ** 2).mean()) < 0.05
    # predict single entries
    assert abs(als.predict(3, 5) - r[3, 5]) < 0.3


def test_als_parity_with_dataset_baseline():
    rng = np.random.default_rng(1)
    f = 3
    r = (rng.normal(size=(30, f)) @ rng.normal(size=(f, 24))).astype(np.float32)
    als = ALS(n_factors=f, reg=1e-3, max_iter=25, tol=1e-7).fit(
        from_array(r, (8, 8)))
    u, v = als_dataset(Dataset.from_array(r, 5), n_factors=f, reg=1e-3,
                       max_iter=25)
    e1 = np.sqrt(((np.asarray((als.u_ @ als.v_.T).collect()) - r) ** 2).mean())
    e2 = np.sqrt((((u @ v.T) - r) ** 2).mean())
    assert e1 < 0.05 and e2 < 0.05


def test_als_no_transpose_copy_needed():
    """ds-array ALS uses the O(N)-task transpose; Dataset ALS pays N^2+N
    (checked via the baseline's own task counter)."""
    rng = np.random.default_rng(0)
    r = rng.normal(size=(20, 20)).astype(np.float32)
    ds = Dataset.from_array(r, 4)
    before = ds.counter.tasks
    als_dataset(ds, n_factors=2, max_iter=2)
    # baseline paid at least the N^2+N transpose tasks up front
    from repro.core import costmodel
    assert ds.counter.tasks - before >= costmodel.dataset_transpose_tasks(4)


def test_pca_matches_svd():
    from repro.algorithms.linalg import frobenius, pca
    rng = np.random.default_rng(0)
    basis = np.linalg.qr(rng.normal(size=(6, 6)))[0]
    data = ((rng.normal(size=(400, 6)) * [10, 5, 2, .1, .1, .1]) @ basis.T
            ).astype(np.float32)
    x = from_array(data, (100, 3))
    comps, var = pca(x, 2, n_iter=50)
    _, s, vt = np.linalg.svd(data - data.mean(0), full_matrices=False)
    overlap = np.abs(np.asarray(comps) @ vt[:2].T)
    assert np.allclose(np.sort(np.diag(overlap)), [1, 1], atol=0.02)
    assert np.allclose(np.asarray(var), s[:2] ** 2 / 399, rtol=0.05)
    assert abs(frobenius(x) - np.linalg.norm(data)) < 1e-2


def test_tsqr():
    from repro.algorithms.linalg import tsqr
    rng = np.random.default_rng(1)
    for n, bs in [(240, 48), (200, 33)]:
        a = rng.normal(size=(n, 8)).astype(np.float32)
        q, r = tsqr(from_array(a, (bs, 8)))
        assert np.allclose(np.asarray(q) @ np.asarray(r), a, atol=1e-4)
        assert np.allclose(np.asarray(q).T @ np.asarray(q), np.eye(8),
                           atol=1e-4)
