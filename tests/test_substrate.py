"""Pipeline / optimizer / checkpoint / fault-tolerance substrate tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.data import PipelineConfig, SyntheticPipeline
from repro.distributed.fault_tolerance import Heartbeat, run_with_restarts
from repro.optim import clip_by_global_norm, cosine_schedule, make_optimizer


def test_pipeline_determinism_and_shift():
    pipe = SyntheticPipeline(PipelineConfig(seed=3, global_batch=4,
                                            seq_len=16, vocab_size=97))
    b1, b2, b3 = pipe.batch_at(7), pipe.batch_at(7), pipe.batch_at(8)
    assert (np.asarray(b1.tokens) == np.asarray(b2.tokens)).all()
    assert not (np.asarray(b1.tokens) == np.asarray(b3.tokens)).all()
    assert (np.asarray(b1.labels[:, :-1]) == np.asarray(b1.tokens[:, 1:])).all()
    assert int(b1.tokens.max()) < 97 and int(b1.tokens.min()) >= 0


def test_pipeline_frontends():
    pipe = SyntheticPipeline(PipelineConfig(global_batch=2, seq_len=8,
                                            vocab_size=10, frontend="vision",
                                            frontend_dim=6,
                                            frontend_tokens=4))
    b = pipe.batch_at(0)
    assert b.patches.shape == (2, 4, 6)
    ds = b.as_dsarray(block_rows=1)
    assert ds.shape == (2, 8)


@pytest.mark.parametrize("kind,mdt", [("adamw", "float32"),
                                      ("adamw", "bfloat16"),
                                      ("adafactor", "float32")])
def test_optimizer_descends(kind, mdt):
    opt = make_optimizer(kind, peak_lr=0.05, warmup=2, total=30,
                         moment_dtype=mdt)
    p = {"w": jnp.ones((6, 3)), "b": jnp.ones((3,))}
    st = opt.init(p)
    for _ in range(30):
        g = jax.tree_util.tree_map(lambda x: 2 * x, p)   # d/dx ||x||^2
        p, st, met = opt.update(g, st, p)
    assert float(jnp.abs(p["w"]).mean()) < 0.7
    assert np.isfinite(float(met["grad_norm"]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 0.2
    assert float(lr(jnp.int32(55))) < float(lr(jnp.int32(20)))


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((5,), jnp.int32)}}
        save(d, 1, tree, extra={"k": 2})
        out = restore(d, 1, jax.tree_util.tree_map(jnp.zeros_like, tree))
        np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
        ac = AsyncCheckpointer(d, keep=2)
        for s in (2, 3, 4):
            ac.save(s, tree)
        ac.wait()
        assert latest_step(d) == 4
        assert not os.path.exists(os.path.join(d, "step_00000001"))


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save(d, 0, {"a": jnp.ones((2, 2))})
        with pytest.raises(ValueError):
            restore(d, 0, {"a": jnp.ones((3, 3))})


def test_run_with_restarts_recovers():
    with tempfile.TemporaryDirectory() as d:
        crashes = {"n": 0}

        def init():
            return {"x": jnp.zeros(())}

        def step(state, i):
            if i == 5 and crashes["n"] == 0:
                crashes["n"] += 1
                raise RuntimeError("boom")
            return {"x": state["x"] + 1}, {"loss": float(state["x"])}

        state, stats = run_with_restarts(
            init_state=init, step_fn=step, ckpt_root=d, total_steps=10,
            ckpt_every=2, heartbeat=Heartbeat(os.path.join(d, "hb.json")))
        assert stats.failures == 1
        assert float(state["x"]) == 10.0  # deterministic replay-free resume
        hb = Heartbeat(os.path.join(d, "hb.json"))
        assert hb.age() is not None and hb.age() < 60


def test_hlo_analysis_trip_counts():
    from benchmarks.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    r = analyze_hlo(c.as_text())
    assert abs(r["flops"] - 7 * 2 * 64 * 32 * 32) / r["flops"] < 1e-6
    assert r["hbm_bytes"] > 7 * 64 * 32 * 4  # at least the activations
