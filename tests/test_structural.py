"""Block-native structural ops: NumPy-oracle equality + no-global-intermediate.

Two families of assertions:

* ``collect()`` equality with the NumPy reference for every selection kind
  (aligned/unaligned slices, negative steps, integer-array filtering,
  rechunk up/down, concat of mixed block shapes);
* jaxpr inspection: the block-aligned slice, evenly-dividing rechunk and
  aligned concat must not create ANY rank-2 intermediate of global extent
  (the seed materialize path created exactly that), and the gather paths
  must not either — their intermediates stay in block layout.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BlockGrid, DsArray, concat_rows, from_array,
                        structural)

RNG = np.random.default_rng(7)


def mk(n, m, bn, bm):
    x = (RNG.normal(size=(n, m)) + 1.0).astype(np.float32)  # nonzero data
    return x, from_array(x, (bn, bm))


def ref2d(ref, rows_key):
    if np.isscalar(ref) or ref.ndim == 0:
        return np.asarray(ref).reshape(1, 1)
    if ref.ndim == 1:
        return ref.reshape(1, -1) if isinstance(rows_key, int) else ref.reshape(-1, 1)
    return ref


def assert_pad_zero(a: DsArray):
    """The pad-is-zero invariant must survive every structural op."""
    gn, gm, bn, bm = a.blocks.shape
    g = np.asarray(a.blocks).transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)
    n, m = a.shape
    assert np.all(g[n:] == 0) and np.all(g[:, m:] == 0)


# ---------------------------------------------------------------------------
# Oracle equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m,bn,bm", [(17, 13, 4, 3), (32, 32, 8, 8),
                                       (1, 1, 3, 3), (40, 7, 12, 12),
                                       (9, 23, 2, 5)])
def test_slicing_matches_numpy(n, m, bn, bm):
    x, a = mk(n, m, bn, bm)
    keys = [
        (slice(0, max(1, n // 2)), slice(0, max(1, m // 2))),   # aligned start
        (slice(bn % n or 0, n), slice(0, m)),                    # aligned rows
        (slice(1, n), slice(1, m)),                              # unaligned
        (slice(0, n, 2), slice(None)),                           # stride
        (slice(None, None, -1), slice(None, None, -1)),          # negative step
        (slice(n, None, -2), slice(None)),
        (slice(3, 1), slice(None)),                              # empty
        (0, slice(None)),
        (slice(None), m - 1),
        (-1, -1),
    ]
    for rows, cols in keys:
        got = np.asarray(a[rows, cols].collect())
        want = ref2d(x[rows, cols], rows)
        assert got.shape == want.shape, (rows, cols)
        np.testing.assert_allclose(got, want, err_msg=str((rows, cols)))
        assert_pad_zero(a[rows, cols])


@pytest.mark.parametrize("n,m,bn,bm", [(20, 11, 6, 4), (33, 8, 8, 8)])
def test_integer_array_filtering(n, m, bn, bm):
    x, a = mk(n, m, bn, bm)
    for idx in [list(range(0, n, 2)), [0, 0, n - 1], [-1, -n, 3 % n],
                RNG.integers(0, n, size=2 * n)]:
        got = np.asarray(a[idx].collect())
        np.testing.assert_allclose(got, x[np.asarray(idx)])
    mask = RNG.random(n) > 0.4
    np.testing.assert_allclose(np.asarray(a[mask].collect()), x[mask])
    with pytest.raises(IndexError):
        a[[n]]
    # column selection too
    cidx = [m - 1] + list(range(0, m, 2))
    np.testing.assert_allclose(np.asarray(a[:, cidx].collect()), x[:, cidx])


def test_filtering_traces_through_jit():
    x, a = mk(24, 6, 5, 5)

    @jax.jit
    def sel(a, idx):
        return a[idx]

    idx = jnp.asarray([3, 1, 21, 7])
    np.testing.assert_allclose(np.asarray(sel(a, idx).collect()),
                               x[np.asarray(idx)])


@pytest.mark.parametrize("n,m,bn,bm", [(17, 13, 4, 3), (24, 24, 8, 8),
                                       (5, 9, 2, 2)])
def test_rechunk_up_down(n, m, bn, bm):
    x, a = mk(n, m, bn, bm)
    cases = [(1, 1), (2, 2), (bn * 2, bm * 3),      # merge (up)
             (max(1, bn // 2), max(1, bm // 3)),    # split (down)
             (bn * 2, max(1, bm // 2)),             # mixed
             (5, 3), (n, m), (bn, 7)]               # incl. non-dividing
    for nbs in cases:
        r = a.rechunk(nbs)
        assert r.block_shape == tuple(nbs)
        np.testing.assert_allclose(np.asarray(r.collect()), x,
                                   err_msg=str(nbs))
        assert_pad_zero(r)
    assert a.rechunk((bn, bm)) is a


def test_concat_mixed_block_shapes():
    x1, a1 = mk(16, 10, 4, 5)       # rows divisible by 4 -> grid stack
    x2, a2 = mk(8, 10, 3, 10)       # different blocks -> rechunk first
    x3, a3 = mk(5, 10, 4, 5)        # ragged tail
    got = np.asarray(concat_rows([a1, a2, a3]).collect())
    np.testing.assert_allclose(got, np.concatenate([x1, x2, x3], axis=0))
    assert_pad_zero(concat_rows([a1, a2, a3]))
    # misaligned interior part -> gather fallback
    got2 = np.asarray(concat_rows([a3, a1]).collect())
    np.testing.assert_allclose(got2, np.concatenate([x3, x1], axis=0))
    with pytest.raises(ValueError):
        concat_rows([a1, mk(4, 9, 2, 2)[1]])
    with pytest.raises(ValueError):
        concat_rows([])


def test_gram_matches_dense():
    x, a = mk(37, 6, 8, 4)
    np.testing.assert_allclose(np.asarray(structural.gram(a)), x.T @ x,
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# No-global-intermediate: inspect every aval in the jaxpr
# ---------------------------------------------------------------------------


from repro.analysis import (  # noqa: E402
    assert_no_global_intermediate, rank2_global_intermediates)


def _check_no_global(fn, a: DsArray):
    jaxpr = jax.make_jaxpr(fn)(a.blocks)
    n, m = a.shape
    gn, gm, bn, bm = a.blocks.shape
    assert_no_global_intermediate(jaxpr, n, m, gn * bn, gm * bm)


def test_aligned_slice_no_global_intermediate():
    _, a = mk(64, 48, 8, 8)
    _check_no_global(lambda b: DsArray(b, a.grid)[8:32, 8:24].blocks, a)
    # ... and the HLO text contains no global-extent constant/copy either
    hlo = jax.jit(lambda b: DsArray(b, a.grid)[8:32, 8:24].blocks) \
        .lower(a.blocks).as_text()
    assert "f32[64,48]" not in hlo


def test_unaligned_slice_no_global_intermediate():
    _, a = mk(64, 48, 8, 8)
    _check_no_global(lambda b: DsArray(b, a.grid)[3:33, 5:21].blocks, a)


def test_filter_no_global_intermediate():
    _, a = mk(64, 48, 8, 8)
    idx = jnp.asarray(np.arange(1, 64, 2))
    _check_no_global(lambda b: DsArray(b, a.grid)[idx].blocks, a)


def test_rechunk_no_global_intermediate():
    _, a = mk(64, 48, 8, 8)
    _check_no_global(lambda b: DsArray(b, a.grid).rechunk((4, 4)).blocks, a)
    _check_no_global(lambda b: DsArray(b, a.grid).rechunk((16, 24)).blocks, a)
    # gather fallback too (non-dividing)
    _check_no_global(lambda b: DsArray(b, a.grid).rechunk((5, 7)).blocks, a)


def test_concat_no_global_intermediate():
    _, a = mk(64, 48, 8, 8)

    def cat(b):
        da = DsArray(b, a.grid)
        return structural.concat_rows([da, da]).blocks

    jaxpr = jax.make_jaxpr(cat)(a.blocks)
    bad = rank2_global_intermediates(jaxpr, 128, 48, 128, 48)
    assert not bad, bad


# ---------------------------------------------------------------------------
# Satellite regressions: operator/dtype fixes
# ---------------------------------------------------------------------------


def test_rpow():
    x, a = mk(7, 5, 3, 2)
    np.testing.assert_allclose(np.asarray((2.0 ** a).collect()), 2.0 ** x,
                               rtol=1e-5)


def test_mean_integer_dtype_promotes():
    big = np.full((300, 300), 10 ** 5, np.int32)   # int32 sum would overflow
    a = from_array(big, (64, 64))
    assert jnp.issubdtype(a.dtype, jnp.integer)
    got = float(a.mean())
    assert abs(got - 1e5) / 1e5 < 1e-3
    m0 = a.mean(axis=0)
    assert jnp.issubdtype(m0.dtype, jnp.floating)
    np.testing.assert_allclose(np.asarray(m0.collect()),
                               np.full((1, 300), 1e5), rtol=1e-3)


def test_binary_pads_smaller_operand():
    x, a = mk(10, 10, 3, 3)
    grown = a._pad_grid_to((6, 6))
    for lhs, rhs in [(a, grown), (grown, a)]:
        out = lhs + rhs
        np.testing.assert_allclose(np.asarray(out.collect()), 2 * x,
                                   rtol=1e-6)
