"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode).

Shape/dtype sweeps use hypothesis where ranges matter and explicit grids for
the structured cases (head counts, windows, caps).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kmeans.ops import kmeans_assign
from repro.kernels.kmeans.ref import kmeans_assign_ref
from repro.kernels.matmul.ops import matmul
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.ssd.ops import ssd_decode_step, ssd_scan
from repro.kernels.ssd.ref import ssd_ref

settings.register_profile("kern", max_examples=10, deadline=None)
settings.load_profile("kern")

RNG = np.random.default_rng(0)


@pytest.mark.slow
@given(st.integers(1, 300), st.integers(1, 300), st.integers(1, 300),
       st.sampled_from([np.float32, np.float16]))
def test_matmul_sweep(m, k, n, dtype):
    a = RNG.normal(size=(m, k)).astype(dtype)
    b = RNG.normal(size=(k, n)).astype(dtype)
    out = matmul(jnp.asarray(a), jnp.asarray(b), block_m=128, block_n=128,
                 block_k=128, interpret=True)
    ref = matmul_ref(jnp.asarray(a), jnp.asarray(b))
    tol = 1e-3 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("tq,tk,hq,hkv,d,causal,window,cap,qoff", [
    (128, 128, 4, 2, 64, True, 0, 0.0, 0),
    (100, 100, 4, 4, 48, True, 0, 0.0, 0),
    (64, 256, 2, 1, 64, True, 0, 0.0, 192),
    (128, 128, 8, 2, 64, True, 64, 0.0, 0),
    (128, 128, 4, 2, 64, True, 0, 30.0, 0),
    (96, 160, 4, 2, 64, False, 0, 0.0, 0),
    (1, 300, 4, 2, 64, True, 0, 0.0, 299),
    (256, 512, 2, 2, 128, True, 128, 50.0, 0),
])
def test_flash_attention_sweep(tq, tk, hq, hkv, d, causal, window, cap, qoff):
    q = RNG.normal(size=(2, hq, tq, d)).astype(np.float32)
    k = RNG.normal(size=(2, hkv, tk, d)).astype(np.float32)
    v = RNG.normal(size=(2, hkv, tk, d)).astype(np.float32)
    got = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, window=window, softcap=cap,
                          q_offset=qoff, block_q=128, block_k=128,
                          interpret=True)
    ref = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                        causal=causal, window=window, softcap=cap,
                        q_offset=qoff)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-5)


def test_flash_attention_bf16():
    q = RNG.normal(size=(1, 2, 128, 128)).astype(np.float32)
    k = RNG.normal(size=(1, 2, 128, 128)).astype(np.float32)
    v = RNG.normal(size=(1, 2, 128, 128)).astype(np.float32)
    got = flash_attention(jnp.asarray(q, jnp.bfloat16),
                          jnp.asarray(k, jnp.bfloat16),
                          jnp.asarray(v, jnp.bfloat16), interpret=True)
    ref = attention_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref),
                               atol=3e-2)


@pytest.mark.slow
@given(st.integers(10, 600), st.integers(2, 130), st.integers(2, 17))
def test_kmeans_assign_sweep(n, d, k):
    x = RNG.normal(size=(n, d)).astype(np.float32)
    cen = RNG.normal(size=(k, d)).astype(np.float32)
    l1, s1, c1 = kmeans_assign(jnp.asarray(x), jnp.asarray(cen),
                               block_n=128, interpret=True)
    l2, s2, c2 = kmeans_assign_ref(jnp.asarray(x), jnp.asarray(cen))
    assert (np.asarray(l1) == np.asarray(l2)).all()
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2))


@pytest.mark.parametrize("bh,t,p,s,chunk", [
    (4, 256, 64, 32, 64),
    (2, 130, 32, 16, 64),   # ragged tail chunk
    (3, 64, 16, 8, 32),
    (1, 32, 128, 128, 16),  # big state
])
def test_ssd_sweep(bh, t, p, s, chunk):
    x = RNG.normal(size=(bh, t, p)).astype(np.float32)
    dt = RNG.uniform(0.001, 0.1, size=(bh, t)).astype(np.float32)
    a = (-RNG.uniform(0.5, 2.0, size=(bh,))).astype(np.float32)
    b = RNG.normal(size=(bh, t, s)).astype(np.float32)
    c = RNG.normal(size=(bh, t, s)).astype(np.float32)
    h0 = RNG.normal(size=(bh, s, p)).astype(np.float32)
    y1, h1 = ssd_scan(*map(jnp.asarray, (x, dt, a, b, c, h0)), chunk=chunk,
                      interpret=True)
    y2, h2 = ssd_ref(*map(jnp.asarray, (x, dt, a, b, c, h0)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)


def test_ssd_decode_matches_scan():
    bh, p, s = 3, 16, 8
    x = RNG.normal(size=(bh, 5, p)).astype(np.float32)
    dt = RNG.uniform(0.01, 0.1, size=(bh, 5)).astype(np.float32)
    a = -RNG.uniform(0.5, 2, (bh,)).astype(np.float32)
    b = RNG.normal(size=(bh, 5, s)).astype(np.float32)
    c = RNG.normal(size=(bh, 5, s)).astype(np.float32)
    y_ref, h_ref = ssd_ref(*map(jnp.asarray, (x, dt, a, b, c)))
    h = jnp.zeros((bh, s, p))
    ys = []
    for t in range(5):
        y, h = ssd_decode_step(jnp.asarray(x[:, t]), jnp.asarray(dt[:, t]),
                               jnp.asarray(a), jnp.asarray(b[:, t]),
                               jnp.asarray(c[:, t]), h)
        ys.append(y)
    np.testing.assert_allclose(np.stack([np.asarray(y) for y in ys], 1),
                               np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)
