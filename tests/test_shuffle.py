"""Row shuffles: multiset preservation, pseudo≡exact equivalence-of-content,
and the block-native lowering of ``exact_shuffle``.

The paper's contract (§5.4): a shuffle permutes rows — every row keeps
exactly one copy (pseudo is non-uniform but content-preserving).  The PR-3
satellite replaced ``exact_shuffle``'s ``collect()`` + global ``take`` (the
O(n·m)-materialize anti-pattern) with the per-axis block gather used by
``A[idx]``; asserted here on the jaxpr: no rank-2 global intermediate.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DsArray, exact_shuffle, from_array, pseudo_shuffle

RNG = np.random.default_rng(31)


def mk(n, m, bn, bm):
    x = (RNG.normal(size=(n, m)) + 1.0).astype(np.float32)
    return x, from_array(x, (bn, bm))


def row_multiset(arr: np.ndarray):
    return sorted(map(tuple, np.round(np.asarray(arr, np.float64), 5)))


def assert_pad_zero(a: DsArray):
    gn, gm, bn, bm = a.blocks.shape
    g = np.asarray(a.blocks).transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)
    n, m = a.shape
    assert np.all(g[n:] == 0) and np.all(g[:, m:] == 0)


@pytest.mark.parametrize("n,m,bn,bm", [(16, 6, 4, 3),    # rows tile evenly
                                       (13, 9, 4, 3),    # ragged tail
                                       (5, 5, 8, 8),     # single block
                                       (24, 4, 6, 4)])
def test_shuffles_preserve_row_multiset(n, m, bn, bm):
    x, a = mk(n, m, bn, bm)
    key = jax.random.PRNGKey(n * 31 + m)
    ex = exact_shuffle(key, a)
    ps = pseudo_shuffle(key, a)
    for out in (ex, ps):
        assert out.shape == a.shape and out.block_shape == a.block_shape
        assert row_multiset(out.collect()) == row_multiset(x)
        assert_pad_zero(out.ensure_zero_pad())
    # pseudo and exact are equivalent as row multisets (the paper's claim:
    # pseudo differs only in the DISTRIBUTION of permutations, not content)
    assert row_multiset(ps.collect()) == row_multiset(ex.collect())


def test_exact_shuffle_deterministic_and_actually_permutes():
    x, a = mk(32, 5, 4, 5)
    key = jax.random.PRNGKey(0)
    s1 = np.asarray(exact_shuffle(key, a).collect())
    s2 = np.asarray(exact_shuffle(key, a).collect())
    np.testing.assert_array_equal(s1, s2)
    assert not np.array_equal(s1, x)    # 32 rows: identity is (32!)⁻¹ likely


def test_exact_shuffle_traces_through_jit():
    x, a = mk(24, 6, 5, 5)

    @jax.jit
    def sh(a, key):
        return exact_shuffle(key, a)

    out = sh(a, jax.random.PRNGKey(3))
    assert row_multiset(out.collect()) == row_multiset(x)


# ---------------------------------------------------------------------------
# Block-native lowering: no rank-2 global intermediate (the seed collect()'d)
# ---------------------------------------------------------------------------


from repro.analysis import rank2_global_intermediates  # noqa: E402


def test_exact_shuffle_no_global_intermediate():
    _, a = mk(64, 48, 8, 8)

    def sh(blocks, key):
        return exact_shuffle(key, DsArray(blocks, a.grid)).blocks

    jaxpr = jax.make_jaxpr(sh)(a.blocks, jax.random.PRNGKey(0))
    gn, gm, bn, bm = a.blocks.shape
    bad = rank2_global_intermediates(jaxpr, 64, 48, gn * bn, gm * bm)
    assert not bad, f"global-shape intermediates produced: {bad}"


def test_pseudo_shuffle_ragged_falls_back_to_exact_blockwise():
    """Ragged rows: pseudo falls back to exact — which must stay block-native
    (no collect) and content-preserving."""
    x, a = mk(13, 9, 4, 3)

    def sh(blocks, key):
        return pseudo_shuffle(key, DsArray(blocks, a.grid)).blocks

    jaxpr = jax.make_jaxpr(sh)(a.blocks, jax.random.PRNGKey(0))
    gn, gm, bn, bm = a.blocks.shape
    bad = rank2_global_intermediates(jaxpr, 13, 9, gn * bn, gm * bm)
    assert not bad, bad
