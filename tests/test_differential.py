"""Differential fuzz harness: one op-chain corpus, four execution paths.

With three execution paths live (eager dense, lazy-fused dense, sparse
BCOO) correctness coverage has to scale combinatorially — instead of
hand-writing per-path cases, a deterministic fixed-seed corpus of random op
chains (elementwise / transpose / reduce / matmul / slice / filter /
rechunk / concat / astype, mixed dtypes, ragged grids) is executed

* **eager dense**  — the reference ds-array implementation,
* **lazy dense**   — the same chain recorded as an Expr plan, computed once
  at the end (metadata is checked WITHOUT computing at every step: the
  symbolically-inferred shape/dtype/pad_state/block_format must track the
  eager result exactly),
* **sparse**       — the same chain from a BCOO-blocked start (ops follow
  the documented policy: sparse-native where zero-preserving, densify
  elsewhere — values must agree regardless),
* **NumPy oracle** — plain ndarray ops (reductions keepdims-style to match
  the ds-array's always-2-D contract),

asserting allclose + metadata agreement + ``DsArray.check_invariants()``
(the pad region really is what ``pad_state`` claims, BCOO indices
in-bounds) at every step.  ~250 cases across the parametrized groups; every
case derives from ``SEED`` only, so failures replay exactly.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import DsArray, concat_rows, from_array
from repro.core.expr import LazyDsArray, LazyScalar

pytestmark = pytest.mark.sparse

SEED = 20260726
N_GROUPS = 10
CASES_PER_GROUP = 25
MAX_OPS = 5


def _mk_values(rng, n, m, dtype, sparsity=0.6):
    x = rng.normal(size=(n, m)) * 2.0
    x = np.where(rng.random((n, m)) < sparsity, 0.0, x)   # real zeros: the
    if np.issubdtype(np.dtype(dtype), np.integer):        # sparse path bites
        x = np.round(x * 3)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=1e-4, atol=1e-4) if np.issubdtype(np.dtype(dtype),
                                                       np.floating) \
        else dict(rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Op vocabulary.  Each op: (name, applicable(x), apply(rng, paths, x)) where
# ``paths`` maps path name -> array-like; ``apply`` draws its parameters
# ONCE and returns (new_paths, new_oracle).  ``e``/``sp`` are DsArrays,
# ``l`` is a LazyDsArray — all share the NumPy-like API, so most ops are a
# single lambda applied uniformly.
# ---------------------------------------------------------------------------


def _uniform(fn, np_fn=None):
    def apply(rng, paths, x):
        return {k: fn(v) for k, v in paths.items()}, (np_fn or fn)(x)
    return apply


def _is_float(x):
    return np.issubdtype(x.dtype, np.floating)


def _with_operand(rng, paths, x, op, np_op):
    """Binary op against a fresh operand.  The operand's block shape
    sometimes DIFFERS from the current one (exercising the alignment
    rechunk, which densifies a sparse operand mid-dispatch) and is
    sometimes sparse itself — the mixed-format x mismatched-blocks region
    of the matrix."""
    e = paths["e"]
    y = _mk_values(rng, x.shape[0], x.shape[1], x.dtype, sparsity=0.4)
    if op in ("div",):
        y = np.abs(y) + 1.5     # keep divisors away from zero
        y = y.astype(x.dtype)
    if rng.integers(3) == 0:    # mismatched blocks: alignment must rechunk
        bs = (int(rng.integers(1, 9)), int(rng.integers(1, 8)))
    else:
        bs = e.block_shape
    w = from_array(y, bs)
    w_sp = w.tosparse() if bool(rng.integers(2)) else w
    fns = {"add": lambda t, o: t + o, "sub": lambda t, o: t - o,
           "mul": lambda t, o: t * o, "div": lambda t, o: t / o}
    fn = fns[op]
    out = {"e": fn(paths["e"], w), "l": fn(paths["l"], w),
           "sp": fn(paths["sp"], w_sp)}
    return out, np_op(x, y)


OPS = []


def _op(name, applicable):
    def deco(apply):
        OPS.append((name, applicable, apply))
        return apply
    return deco


_always = lambda x: True                                    # noqa: E731
_float_only = _is_float
_not_tiny = lambda x: x.size >= 4                           # noqa: E731

_op("add_s", _always)(
    lambda rng, p, x: _uniform(lambda t: t + 2, lambda t: t + 2)(rng, p, x)
    if not _is_float(x)
    else _uniform(lambda t: t + 1.5, lambda t: t + 1.5)(rng, p, x))
_op("mul_s", _always)(
    lambda rng, p, x: _uniform(lambda t: t * 3, lambda t: t * 3)(rng, p, x)
    if not _is_float(x)
    else _uniform(lambda t: t * 0.5, lambda t: t * 0.5)(rng, p, x))
_op("rsub_s", _always)(_uniform(lambda t: 3 - t))
_op("neg", _always)(_uniform(lambda t: -t))
_op("abs", _always)(
    _uniform(lambda t: t.abs() if isinstance(t, (DsArray, LazyDsArray))
             else np.abs(t)))
_op("div_s", _float_only)(_uniform(lambda t: t / 2.0))
_op("sqrt_abs", _float_only)(
    _uniform(lambda t: (t.abs().sqrt()
                        if isinstance(t, (DsArray, LazyDsArray))
                        else np.sqrt(np.abs(t)))))
_op("add_b", _always)(
    lambda rng, p, x: _with_operand(rng, p, x, "add", np.add))
_op("sub_b", _always)(
    lambda rng, p, x: _with_operand(rng, p, x, "sub", np.subtract))
_op("mul_b", _always)(
    lambda rng, p, x: _with_operand(rng, p, x, "mul", np.multiply))
_op("div_b", _float_only)(
    lambda rng, p, x: _with_operand(rng, p, x, "div", np.divide))
_op("transpose", _always)(_uniform(lambda t: t.T))
_op("astype", _always)(
    lambda rng, p, x: _uniform(
        lambda t: t.astype(jnp.int32) if isinstance(
            t, (DsArray, LazyDsArray)) else t.astype(np.int32))(rng, p, x)
    if _is_float(x)
    else _uniform(
        lambda t: t.astype(jnp.float32) if isinstance(
            t, (DsArray, LazyDsArray)) else t.astype(np.float32))(rng, p, x))


@_op("slice", _not_tiny)
def _slice(rng, paths, x):
    n, m = x.shape
    r0 = int(rng.integers(0, n))
    r1 = int(rng.integers(r0 + 1, n + 1))
    c0 = int(rng.integers(0, m))
    c1 = int(rng.integers(c0 + 1, m + 1))
    key = (slice(r0, r1), slice(c0, c1))
    return {k: v[key] for k, v in paths.items()}, x[key]


@_op("filter_rows", _not_tiny)
def _filter(rng, paths, x):
    n = x.shape[0]
    idx = rng.integers(0, n, size=int(rng.integers(1, n + 1)))
    return ({k: v[idx] for k, v in paths.items()}, x[np.asarray(idx)])


@_op("rechunk", _always)
def _rechunk(rng, paths, x):
    bs = (int(rng.integers(1, 9)), int(rng.integers(1, 8)))
    return {k: v.rechunk(bs) for k, v in paths.items()}, x


@_op("matmul", lambda x: x.shape[1] >= 1)
def _matmul(rng, paths, x):
    m = x.shape[1]
    p = int(rng.integers(1, 6))
    w = _mk_values(rng, m, p, x.dtype, sparsity=0.2)
    bm = paths["e"].block_shape[1]
    wd = from_array(w, (bm, max(1, min(p, int(rng.integers(1, p + 1))))))
    return ({k: v @ wd for k, v in paths.items()},
            x.astype(np.float64) @ w.astype(np.float64)
            if _is_float(x) else x.astype(np.int64) @ w.astype(np.int64))


@_op("reduce_axis", _always)
def _reduce_axis(rng, paths, x):
    op = ["sum", "max", "min"][int(rng.integers(3))]
    axis = int(rng.integers(2))
    out = {k: getattr(v, op)(axis=axis) for k, v in paths.items()}
    np_out = getattr(np, {"sum": "sum", "max": "max", "min": "min"}[op])(
        x, axis=axis, keepdims=True)
    return out, np_out


@_op("mean_axis", _float_only)
def _mean_axis(rng, paths, x):
    axis = int(rng.integers(2))
    return ({k: v.mean(axis=axis) for k, v in paths.items()},
            x.mean(axis=axis, keepdims=True, dtype=np.float64).astype(x.dtype))


@_op("concat_self", lambda x: x.shape[0] >= 1)
def _concat(rng, paths, x):
    y = _mk_values(rng, int(rng.integers(1, 9)), x.shape[1], x.dtype)
    w = from_array(y, paths["e"].block_shape)
    return ({k: concat_rows([v, w]) for k, v in paths.items()},
            np.concatenate([x, y], axis=0))


# ---------------------------------------------------------------------------
# Harness
# ---------------------------------------------------------------------------


def _meta(v):
    return (tuple(v.shape), tuple(v.block_shape), jnp.dtype(v.dtype),
            v.pad_state, v.block_format)


def _assert_step(paths, x, label):
    e, l, sp = paths["e"], paths["l"], paths["sp"]
    tol = _tol(e.dtype)
    # eager vs oracle, and the eager pad claim actually holds
    e.check_invariants()
    np.testing.assert_allclose(np.asarray(e.collect(), np.float64),
                               np.asarray(x, np.float64), err_msg=label, **tol)
    # lazy metadata tracks eager metadata exactly — shape, blocks, dtype,
    # pad_state AND block_format — without computing anything
    assert _meta(l) == _meta(e), (label, _meta(l), _meta(e))
    # sparse: same logical result whatever the storage policy did
    sp.check_invariants()
    assert sp.shape == e.shape and sp.block_shape == e.block_shape, label
    assert jnp.dtype(sp.dtype) == jnp.dtype(e.dtype), label
    assert sp.block_format in ("dense", "bcoo"), label
    np.testing.assert_allclose(np.asarray(sp.collect(), np.float64),
                               np.asarray(x, np.float64), err_msg=label, **tol)


def _run_case(case_seed: int):
    rng = np.random.default_rng(case_seed)
    n = int(rng.integers(1, 25))
    m = int(rng.integers(1, 17))
    bn = int(rng.integers(1, 9))
    bm = int(rng.integers(1, 8))
    dtype = [np.float32, np.int32][int(rng.integers(2))]
    x = _mk_values(rng, n, m, dtype)
    base = from_array(x, (bn, bm))
    paths = {"e": base, "l": base.lazy(), "sp": base.tosparse()}
    oracle = x.astype(np.float64) if dtype == np.float32 else x
    trace = [f"init n={n} m={m} b=({bn},{bm}) {np.dtype(dtype).name}"]
    _assert_step(paths, oracle, " | ".join(trace))

    n_ops = int(rng.integers(2, MAX_OPS + 1))
    for step in range(n_ops):
        cur = np.asarray(oracle)
        applicable = [(nm, ap) for nm, cond, ap in OPS if cond(cur)]
        name, apply = applicable[int(rng.integers(len(applicable)))]
        trace.append(name)
        paths, oracle = apply(rng, paths, cur)
        _assert_step(paths, oracle, " | ".join(trace))

    # terminal: force the lazy plan through compute() and compare the four
    # paths end-to-end (plus a whole-array reduction across all of them)
    label = " | ".join(trace)
    e, l, sp = paths["e"], paths["l"], paths["sp"]
    out = l.compute()
    out.check_invariants()
    assert _meta(out) == _meta(e), (label, _meta(out), _meta(e))
    np.testing.assert_allclose(np.asarray(out.collect(), np.float64),
                               np.asarray(e.collect(), np.float64),
                               err_msg=label, **_tol(e.dtype))
    tol = dict(rtol=2e-4, atol=2e-4) if _is_float(np.asarray(oracle)) \
        else dict(rtol=0, atol=0)
    want = np.asarray(oracle).sum()
    for name, v in (("eager", e), ("sparse", sp)):
        np.testing.assert_allclose(float(v.sum()), float(want),
                                   err_msg=f"{label} | sum[{name}]", **tol)
    assert isinstance(l.sum(), LazyScalar)   # scalar recording stays lazy
    # (lazy reductions compute inside the chains via reduce_axis/mean_axis;
    # a second whole-plan compile per case would double harness runtime)


@pytest.mark.parametrize("group", range(N_GROUPS))
def test_differential_corpus(group):
    for i in range(CASES_PER_GROUP):
        _run_case(SEED + group * CASES_PER_GROUP + i)


def test_corpus_size_meets_acceptance():
    """ISSUE-4 acceptance: >= 200 corpus cases across all four paths."""
    assert N_GROUPS * CASES_PER_GROUP >= 200


# ---------------------------------------------------------------------------
# ISSUE-5: estimator fit/predict as differential steps — the same model fit
# from every input path (eager dense, bcoo, ragged grid) must agree with
# itself and with a NumPy oracle on fixed small datasets.
# ---------------------------------------------------------------------------


def _estimator_case(case_seed: int):
    rng = np.random.default_rng(case_seed)
    n = int(rng.integers(40, 90))
    m = int(rng.integers(3, 7))
    bn = int(rng.integers(4, 17))
    bm = int(rng.integers(2, m + 1))
    x = _mk_values(rng, n, m, np.float32, sparsity=0.5)
    coef = rng.normal(size=m).astype(np.float32)
    y_reg = (x @ coef + 1.0).astype(np.float32)
    y_cls = (x @ coef > np.median(x @ coef)).astype(np.int32)
    base = from_array(x, (bn, bm))
    return x, y_reg, y_cls, coef, base


@pytest.mark.parametrize("case", range(4))
def test_differential_ridge_fit_predict(case):
    from repro.estimators import Ridge
    x, y_reg, _, coef, base = _estimator_case(SEED + 1000 + case)
    paths = {"e": base, "sp": base.tosparse(),
             "ragged": from_array(x, (7, 3))}
    # NumPy oracle: closed-form ridge with unpenalized intercept
    alpha = 0.5
    m = x.shape[1]
    xa = np.concatenate([x, np.ones((len(x), 1), np.float32)], axis=1)
    reg = np.eye(m + 1) * alpha
    reg[m, m] = 0.0
    theta = np.linalg.solve(xa.T @ xa + reg, xa.T @ y_reg)
    want = xa @ theta
    for label, xd in paths.items():
        est = Ridge(alpha=alpha).fit(xd, y_reg)
        pred = np.asarray(est.predict(xd).collect(), np.float64).ravel()
        np.testing.assert_allclose(pred, want, rtol=2e-3, atol=2e-3,
                                   err_msg=label)
        np.testing.assert_allclose(est.coef_, theta[:m], rtol=2e-3,
                                   atol=2e-3, err_msg=label)


@pytest.mark.parametrize("case", range(3))
def test_differential_csvm_fit_predict(case):
    from repro.estimators import CascadeSVM
    x, _, y_cls, _, base = _estimator_case(SEED + 2000 + case)
    paths = {"e": base, "sp": base.tosparse(),
             "ragged": from_array(x, (7, 3))}
    preds = {}
    for label, xd in paths.items():
        est = CascadeSVM(kernel="linear", sv_cap=32, max_iter=3).fit(xd, y_cls)
        acc = est.score(xd, y_cls)
        assert acc >= 0.85, (label, acc)
        preds[label] = np.asarray(est.predict(xd).collect()).ravel()
    # dense and sparse fits see the same chunks (same block rows): identical
    np.testing.assert_array_equal(preds["e"], preds["sp"])


# ---------------------------------------------------------------------------
# Fault-injection lane (runs in BOTH the sparse and resilience CI lanes):
# the same kind of random chains, executed through ``run_resilient`` while
# deterministic faults fire at the ``plan_execute`` site.  Recovery — a
# transient retry, or OOM degradation down the fused → eager → einsum
# ladder — must reproduce the NumPy oracle exactly like a clean run.
# ---------------------------------------------------------------------------


def _resilient_chain(case_seed: int):
    """A random lazy matmul chain + its NumPy oracle."""
    rng = np.random.default_rng(case_seed)
    n = int(rng.integers(4, 20))
    k = int(rng.integers(3, 16))
    m = int(rng.integers(2, 12))
    x = rng.normal(size=(n, k)).astype(np.float32)
    y = rng.normal(size=(k, m)).astype(np.float32)
    a = from_array(x, (int(rng.integers(2, 8)), int(rng.integers(2, 8))))
    b = from_array(y, (int(rng.integers(2, 8)), int(rng.integers(2, 6))))
    lz = (a.lazy() @ b) * 1.5 + 0.25
    ox = (x.astype(np.float64) @ y.astype(np.float64)) * 1.5 + 0.25
    if n >= 2 and rng.random() < 0.5:
        lz, ox = lz.T, ox.T
    return lz, ox


@pytest.mark.resilience
@pytest.mark.parametrize("case", range(6))
def test_differential_recovery_matches_oracle(case):
    import repro.resilience as R

    faults = [
        (),                                             # clean baseline
        (R.FaultSpec(kind="transient", site="plan_execute",
                     at=1, times=2),),                  # 2 retries
        (R.FaultSpec(kind="oom", site="plan_execute",
                     modes=("fused",), times=None),),   # degrade: eager
        (R.FaultSpec(kind="oom", site="plan_execute",
                     modes=("fused", "eager"), times=None),),  # → einsum
    ]
    for i, specs in enumerate(faults):
        lz, want = _resilient_chain(SEED + 9000 + case)
        R.reset_stats()
        with R.inject(*specs):
            out = R.run_resilient(lz, guard="finite")
        np.testing.assert_allclose(np.asarray(out.collect(), np.float64),
                                   want, rtol=2e-4, atol=2e-4,
                                   err_msg=f"case={case} faults[{i}]")
        s = R.stats()
        assert s["recoveries"] == (1 if specs else 0), (case, i, s)
        assert s["guard_failures"] == 0
    R.reset_stats()
