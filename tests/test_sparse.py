"""Sparse (BCOO) block subsystem: construction, op policy, no-densify
acceptance, lazy integration, and sparse algorithm inputs.

The ISSUE-4 acceptance assertions live here:

* ``sp @ dense`` and the sparse reductions NEVER materialize a dense block
  for the sparse operand — asserted on the jaxpr: no intermediate whose
  shape is the densified stacked form of the BCOO input;
* the lazy layer carries ``block_format`` (sparse Blockwise nodes are
  fusion boundaries but still CSE and cache);
* the paper's workloads (k-means, PCA/Gram, ALS) accept sparse inputs and
  match their dense results.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.sparse import BCOO

import repro
from repro.core import (DsArray, costmodel, from_array, from_scipy, gram,
                        plan, random_sparse)
from repro.core import sparse as sparse_mod
from repro.core import io as io_mod
from repro.kernels.matmul.ops import local_matmul

pytestmark = pytest.mark.sparse

RNG = np.random.default_rng(41)


def mk_sparse(n=13, m=9, bn=4, bm=3, dtype=np.float32, density=0.3):
    x = (RNG.random((n, m)) < density) * RNG.normal(size=(n, m))
    if np.issubdtype(np.dtype(dtype), np.integer):
        x = np.round(x * 10)
    x = x.astype(dtype)
    a = from_array(x, (bn, bm))
    return x, a, a.tosparse()


# ---------------------------------------------------------------------------
# jaxpr helpers (shared shape-walk with test_lazy-style eqn traversal)
# ---------------------------------------------------------------------------


from repro.analysis import (
    assert_no_densify, walk_eqns)

_walk_eqns = walk_eqns          # canonical traversal lives in conftest


# ---------------------------------------------------------------------------
# Construction + conversions
# ---------------------------------------------------------------------------


def test_roundtrip_and_invariants():
    x, a, s = mk_sparse()
    assert s.block_format == "bcoo" and a.block_format == "dense"
    s.check_invariants()
    np.testing.assert_allclose(np.asarray(s.collect()), x)
    back = s.todense()
    assert back.block_format == "dense"
    back.check_invariants()
    np.testing.assert_allclose(np.asarray(back.collect()), x)
    # tosparse is idempotent, todense of dense is identity
    assert s.tosparse() is s and a.todense() is a


def test_random_sparse_density_and_pad():
    r = random_sparse(jax.random.PRNGKey(0), (21, 13), (6, 4), density=0.15)
    r.check_invariants()     # incl. zero entries in the pad region
    d = sparse_mod.density(r)
    assert 0.05 < d < 0.3, d
    # determinism
    r2 = random_sparse(jax.random.PRNGKey(0), (21, 13), (6, 4), density=0.15)
    np.testing.assert_allclose(np.asarray(r.collect()),
                               np.asarray(r2.collect()))


def test_from_scipy_never_densifies_layout():
    ssp = pytest.importorskip("scipy.sparse")
    m = ssp.random(23, 17, density=0.12, random_state=3, format="csr",
                   dtype=np.float32)
    s = from_scipy(m, (6, 5))
    s.check_invariants()
    assert s.block_format == "bcoo"
    np.testing.assert_allclose(np.asarray(s.collect()), m.toarray())
    # empty matrix edge case
    s0 = from_scipy(ssp.csr_matrix((5, 4), dtype=np.float32), (2, 2))
    s0.check_invariants()
    assert np.asarray(s0.collect()).sum() == 0


def test_io_density_auto_pick():
    dense_arr = RNG.normal(size=(12, 8)).astype(np.float32)
    sparse_arr = ((RNG.random((12, 8)) < 0.05) * dense_arr).astype(np.float32)
    assert io_mod.from_array_auto(dense_arr, (4, 4)).block_format == "dense"
    assert io_mod.from_array_auto(sparse_arr, (4, 4)).block_format == "bcoo"
    # threshold comes from the costmodel storage-crossover law
    thr = costmodel.sparse_storage_crossover_density(4)
    assert thr == pytest.approx(1 / 3)
    assert io_mod.from_array_auto(sparse_arr, (4, 4),
                                  density_threshold=0.0).block_format == "dense"
    assert io_mod.from_array_auto(dense_arr, (4, 4),
                                  block_format="bcoo").block_format == "bcoo"
    assert costmodel.tosparse_pays(0.01) and not costmodel.tosparse_pays(0.9)


def test_bcoo_requires_zero_pad_claim():
    from repro.core.dsarray import PadState
    _, _, s = mk_sparse()
    with pytest.raises(ValueError):
        DsArray(s.blocks, s.grid, PadState("fill", 3.0))


def test_check_invariants_catches_violations():
    x, a, s = mk_sparse(8, 6, 4, 3)
    # smuggle a nonzero value into an out-of-bounds slot
    sp = s.blocks
    bad_data = sp.data.at[0, 0, -1].set(7.0)
    bad_idx = sp.indices.at[0, 0, -1].set(jnp.asarray([4, 3]))
    bad = BCOO((bad_data, bad_idx), shape=sp.shape)
    with pytest.raises(AssertionError):
        DsArray(bad, s.grid).check_invariants()
    # dense: claim ZERO with a dirty pad (13x9 in (4,3) blocks has pad rows)
    from repro.core.dsarray import PAD_ZERO
    _, ragged, _ = mk_sparse(13, 9, 4, 3)
    blocks = ragged.blocks.at[-1, -1, -1, -1].set(9.0)    # global row 15: pad
    with pytest.raises(AssertionError):
        DsArray(blocks, ragged.grid, PAD_ZERO).check_invariants()


def test_repro_debug_validates_at_construction(monkeypatch):
    monkeypatch.setenv("REPRO_DEBUG", "1")
    x, a, s = mk_sparse()
    (s * 2.0).collect()          # constructions self-check without raising
    from repro.core.dsarray import PAD_ZERO
    blocks = a.blocks.at[-1, -1, -1, -1].set(9.0)
    with pytest.raises(AssertionError):
        DsArray(blocks, a.grid, PAD_ZERO)


# ---------------------------------------------------------------------------
# Op policy: sparse-native vs densifying (the docstring table, executable)
# ---------------------------------------------------------------------------


def test_elementwise_policy_and_values():
    x, a, s = mk_sparse()
    y = (RNG.normal(size=x.shape) + 2.5).astype(np.float32)
    b = from_array(y, a.block_shape)
    sb = b.tosparse()
    cases = [
        ("scale", lambda: s * 2.0, x * 2.0, "bcoo"),
        ("div_s", lambda: s / 2.0, x / 2.0, "bcoo"),
        ("neg", lambda: -s, -x, "bcoo"),
        ("abs", lambda: s.abs(), np.abs(x), "bcoo"),
        ("sqrt_abs", lambda: s.abs().sqrt(), np.sqrt(np.abs(x)), "bcoo"),
        ("pow2", lambda: s ** 2, x ** 2, "bcoo"),
        ("add_s", lambda: s + 1.0, x + 1.0, "dense"),
        ("exp", lambda: s.exp(), np.exp(x), "dense"),
        ("rdiv", lambda: 2.0 / (s + 3.0), 2.0 / (x + 3.0), "dense"),
        ("pair_add", lambda: s + sb, x + y, "bcoo"),
        ("pair_sub", lambda: s - sb, x - y, "bcoo"),
        ("pair_mul", lambda: s * sb, x * y, "bcoo"),
        ("gather_mul", lambda: s * b, x * y, "bcoo"),
        ("gather_div", lambda: s / b, x / y, "bcoo"),
        ("rev_gather", lambda: b * s, x * y, "bcoo"),
        ("dense_div_sp", lambda: b / s, None, "dense"),
        ("sp_add_dense", lambda: s + b, x + y, "dense"),
    ]
    for label, build, want, fmt in cases:
        out = build()
        assert out.block_format == fmt, (label, out.block_format)
        out.check_invariants()
        if want is not None:
            np.testing.assert_allclose(np.asarray(out.collect()), want,
                                       rtol=1e-5, atol=1e-5, err_msg=label)


def test_mixed_format_with_mismatched_blocks():
    """A block-shape mismatch makes alignment rechunk — which densifies a
    sparse operand — and the dispatch must then take the dense path (the
    gather form has no BCOO left to index)."""
    x, a, s = mk_sparse(12, 9, 4, 3)
    y = (RNG.normal(size=(12, 9)) + 2.0).astype(np.float32)
    d = from_array(y, (5, 2))                     # different block shape
    for build, want in [
            (lambda: d * s, y * x), (lambda: s * d, x * y),
            (lambda: d / (s + 2.0), y / (x + 2.0)),
            (lambda: s / d, x / y), (lambda: d + s, y + x),
            (lambda: s - d.tosparse(), x - y)]:
        out = build()
        out.check_invariants()
        np.testing.assert_allclose(np.asarray(out.collect()), want,
                                   rtol=1e-5, atol=1e-5)


def test_nonlinear_data_map_after_index_merge():
    """abs/astype over a sparse ± sparse result (duplicate indices) must
    merge split entries first — |d1 + d2| != |d1| + |d2|."""
    x, a, s = mk_sparse()
    y, b, sb = mk_sparse(13, 9, 4, 3)
    merged = (s * 2.0) - sb
    np.testing.assert_allclose(np.asarray(merged.abs().collect()),
                               np.abs(x * 2.0 - y), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(merged.astype(jnp.int32).collect()),
                               (x * 2.0 - y).astype(np.int32))


def test_transpose_reductions_mean_norm():
    x, a, s = mk_sparse(11, 7, 3, 3)
    t = s.T
    assert t.block_format == "bcoo"
    t.check_invariants()
    np.testing.assert_allclose(np.asarray(t.collect()), x.T)
    assert float(s.sum()) == pytest.approx(float(x.sum()), rel=1e-5)
    np.testing.assert_allclose(
        np.asarray(s.sum(axis=0).collect()).ravel(), x.sum(0), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(s.sum(axis=1).collect()).ravel(), x.sum(1), rtol=1e-4)
    assert float(s.max()) == pytest.approx(float(x.max()))
    np.testing.assert_allclose(
        np.asarray(s.min(axis=0).collect()).ravel(), x.min(0))
    np.testing.assert_allclose(
        np.asarray(s.mean(axis=1).collect()).ravel(), x.mean(1), rtol=1e-4)
    assert float(s.norm()) == pytest.approx(float(np.linalg.norm(x)), rel=1e-5)
    # integer mean promotes before summing on the sparse path too
    xi, ai, si = mk_sparse(9, 5, 4, 2, np.int32)
    np.testing.assert_allclose(np.asarray(si.mean(axis=0).collect()).ravel(),
                               xi.mean(0), rtol=1e-6)


def test_structural_ops_densify_but_match():
    x, a, s = mk_sparse(17, 13, 4, 3)
    np.testing.assert_allclose(np.asarray(s[2:9, 1:7].collect()), x[2:9, 1:7])
    np.testing.assert_allclose(np.asarray(s[[0, 5, 12, 3]].collect()),
                               x[[0, 5, 12, 3]])
    np.testing.assert_allclose(np.asarray(s.rechunk((5, 2)).collect()), x)
    from repro.core import concat_rows, exact_shuffle
    y, b, sb = mk_sparse(17, 13, 4, 3)
    np.testing.assert_allclose(np.asarray(concat_rows([s, sb]).collect()),
                               np.concatenate([x, y]))
    out = exact_shuffle(jax.random.PRNGKey(5), s)
    assert sorted(np.asarray(out.collect()).ravel().tolist()) == \
        sorted(x.ravel().tolist())


def test_grid_padding_keeps_invariant():
    x, a, s = mk_sparse(10, 6, 4, 3)
    grown = s._pad_grid_to((5, 4))
    grown.check_invariants()
    assert grown.stacked_grid == (5, 4)
    np.testing.assert_allclose(np.asarray(grown.collect()), x)


# ---------------------------------------------------------------------------
# Acceptance: sp @ dense / spᵀ @ dense / sparse reductions never densify
# ---------------------------------------------------------------------------


def test_spmm_matches_and_never_densifies():
    x, a, s = mk_sparse(24, 18, 6, 6, density=0.2)
    w = RNG.normal(size=(18, 10)).astype(np.float32)
    wd = from_array(w, (6, 5))
    out = s @ wd
    assert out.block_format == "dense"
    np.testing.assert_allclose(np.asarray(out.collect()), x @ w,
                               rtol=1e-4, atol=1e-4)
    # jaxpr of the whole DsArray-level matmul: the sparse operand's dense
    # stacked form (gn, gk, bn, bk) must never appear as an intermediate
    jx = jax.make_jaxpr(lambda sb, wb: local_matmul(sb, wb))(
        s.blocks, wd.ensure_zero_pad().blocks)
    assert_no_densify(jx, s.blocks.shape)


def test_spmm_transpose_a_never_densifies():
    from repro.core.dsarray import matmul_ta
    x, a, s = mk_sparse(20, 12, 5, 4, density=0.25)
    w = RNG.normal(size=(20, 6)).astype(np.float32)
    wd = from_array(w, (5, 3))
    out = matmul_ta(s, wd)
    np.testing.assert_allclose(np.asarray(out.collect()), x.T @ w,
                               rtol=1e-4, atol=1e-4)
    jx = jax.make_jaxpr(
        lambda sb, wb: local_matmul(sb, wb, transpose_a=True))(
        s.blocks, wd.ensure_zero_pad().blocks)
    assert_no_densify(jx, s.blocks.shape)


def test_sparse_matvec():
    x, a, s = mk_sparse(24, 18, 6, 6, density=0.1)
    v = RNG.normal(size=(18, 1)).astype(np.float32)
    vd = from_array(v, (6, 1))
    np.testing.assert_allclose(np.asarray((s @ vd).collect()), x @ v,
                               rtol=1e-4, atol=1e-4)


def test_sparse_reductions_never_densify():
    x, a, s = mk_sparse(24, 18, 6, 6, density=0.2)
    for fn in (lambda sb: DsArray(sb, s.grid).sum(),
               lambda sb: DsArray(sb, s.grid).sum(axis=0).blocks,
               lambda sb: DsArray(sb, s.grid).sum(axis=1).blocks):
        jx = jax.make_jaxpr(fn)(s.blocks)
        assert_no_densify(jx, s.blocks.shape)


def test_sparse_elementwise_never_densifies():
    """Data maps and gather-mul run on (gn, gm, nse)-shaped arrays only."""
    x, a, s = mk_sparse(24, 18, 6, 6, density=0.2)
    y = (RNG.normal(size=x.shape) + 2.0).astype(np.float32)
    b = from_array(y, (6, 6))
    jx = jax.make_jaxpr(
        lambda sb, db: sparse_mod.gather_fn(jnp.multiply, True)(sb, db).data)(
        s.blocks, b.blocks)
    assert_no_densify(jx, s.blocks.shape)
    jx2 = jax.make_jaxpr(
        lambda sb: sparse_mod.data_map_fn(jnp.multiply, 2.0, False)(sb).data)(
        s.blocks)
    assert_no_densify(jx2, s.blocks.shape)


# ---------------------------------------------------------------------------
# Lazy integration: formats in metadata, fusion boundary, CSE + cache
# ---------------------------------------------------------------------------


def test_lazy_sparse_formats_and_values():
    x, a, s = mk_sparse()
    y = (RNG.normal(size=x.shape) + 2.0).astype(np.float32)
    b = from_array(y, a.block_shape)
    with repro.lazy():
        r_sp = ((s * 2.0) - b.tosparse()).abs()       # stays sparse
        r_dn = (s * 3.0) + 1.0                        # densifies mid-chain
        r_ga = s * b                                  # gather stays sparse
    assert r_sp.block_format == "bcoo"
    assert r_dn.block_format == "dense"
    assert r_ga.block_format == "bcoo"
    out = r_sp.compute()
    assert out.block_format == "bcoo"
    out.check_invariants()
    np.testing.assert_allclose(np.asarray(out.collect()),
                               np.abs(x * 2.0 - y), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_dn.compute().collect()),
                               x * 3.0 + 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(r_ga.compute().collect()), x * y,
                               rtol=1e-5, atol=1e-5)


def test_lazy_sparse_is_fusion_boundary_but_cses_and_caches():
    x, a, s = mk_sparse(16, 12, 4, 4)
    with repro.lazy():
        chain = ((s * 2.0) * 3.0).abs()
    p = plan.plan_for(chain)
    assert p.stats["fused_elementwise"] == 0, p.stats    # sparse: no fusion
    with repro.lazy():
        dense_chain = ((a * 2.0) * 3.0).abs()
    assert plan.plan_for(dense_chain).stats["fused_elementwise"] == 2
    # CSE: sibling reductions over one sparse operand share it
    with repro.lazy():
        c = s * 2.0
        s0, s1 = c.sum(axis=0), c.sum(axis=1)
    ps = plan.plan_for(s0, s1)
    assert ps.roots[0].children[0] is ps.roots[1].children[0]
    # cache: same sparse structure AND capacity on fresh data hits (nse is
    # part of the leaf signature, so pin it across the fresh draws)
    plan.clear_cache()
    for i in range(3):
        xi, ai, si = mk_sparse(16, 12, 4, 4)
        with repro.lazy():
            r = (ai.tosparse(nse=8) * 2.0).sum(axis=0)
        r.compute()
    st = plan.cache_stats()
    assert st["misses"] == 1 and st["hits"] == 2, st
    # a different nse is a DIFFERENT plan (stored-entry capacity is shape)
    xi, ai, _ = mk_sparse(16, 12, 4, 4)
    with repro.lazy():
        r = (ai.tosparse(nse=16) * 2.0).sum(axis=0)
    r.compute()
    assert plan.cache_stats()["misses"] == 2


def test_lazy_conversion_nodes():
    x, a, s = mk_sparse()
    with repro.lazy():
        r = (a.lazy().tosparse(nse=16) * 2.0)
        d = s.lazy().todense() + 1.0
    assert r.block_format == "bcoo" and d.block_format == "dense"
    np.testing.assert_allclose(np.asarray(r.compute().collect()), x * 2.0,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(d.compute().collect()), x + 1.0,
                               rtol=1e-5)
    with pytest.raises(ValueError):
        a.lazy().tosparse()          # lazy conversion needs explicit nse


# ---------------------------------------------------------------------------
# Algorithms accept sparse inputs (the paper's CSVM-style workloads)
# ---------------------------------------------------------------------------


def test_kmeans_sparse_matches_dense():
    from repro.algorithms.kmeans import KMeans
    c0 = np.zeros(12); c0[1] = 5.0
    c1 = np.zeros(12); c1[7] = -5.0
    pts = np.stack([(c0 if i % 2 == 0 else c1)
                    + (RNG.random(12) < 0.2) * RNG.normal(size=12) * 0.1
                    for i in range(40)]).astype(np.float32)
    xd = from_array(pts, (8, 5))
    xs = xd.tosparse()
    km_d = KMeans(n_clusters=2, seed=1).fit(xd)
    km_s = KMeans(n_clusters=2, seed=1).fit(xs)
    np.testing.assert_allclose(np.sort(np.asarray(km_d.centers_), axis=0),
                               np.sort(np.asarray(km_s.centers_), axis=0),
                               atol=1e-4)
    labels = km_s.predict(xs)
    assert labels.shape == (40, 1)
    assert np.isfinite(km_s.score(xs))


def test_kmeans_sparse_assignment_never_densifies():
    """The Lloyd-step contractions on BCOO blocks must not materialize the
    dense stacked x."""
    from repro.algorithms.kmeans import _center_stats
    x, a, s = mk_sparse(24, 12, 6, 4, density=0.2)
    gn, gm, bn, bm = s.blocks.shape
    centers = RNG.normal(size=(3, gm * bm)).astype(np.float32)
    row_valid = np.ones((gn, bn), bool)
    x_sq = RNG.random((gn, bn)).astype(np.float32)
    jx = jax.make_jaxpr(lambda sb: _center_stats(
        sb, jnp.asarray(row_valid), jnp.asarray(centers),
        jnp.asarray(x_sq), 12))(s.blocks)
    assert_no_densify(jx, s.blocks.shape)


def test_pca_gram_als_sparse():
    from repro.algorithms.linalg import frobenius, pca
    from repro.algorithms.als import ALS
    x, a, s = mk_sparse(30, 10, 8, 4, density=0.25)
    cd, vd = pca(a, 2, n_iter=20, center=False)
    cs, vs = pca(s, 2, n_iter=20, center=False)
    np.testing.assert_allclose(np.abs(np.asarray(cd)), np.abs(np.asarray(cs)),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(vd), np.asarray(vs), rtol=1e-3)
    assert frobenius(s) == pytest.approx(float(np.linalg.norm(x)), rel=1e-5)
    np.testing.assert_allclose(np.asarray(gram(s)), x.T @ x, atol=1e-3)
    rt = ((RNG.random((24, 18)) < 0.3)
          * (RNG.random((24, 18)) * 4 + 1)).astype(np.float32)
    rd = from_array(rt, (6, 6))
    m_d = ALS(n_factors=4, max_iter=3, seed=0).fit(rd)
    m_s = ALS(n_factors=4, max_iter=3, seed=0).fit(rd.tosparse())
    np.testing.assert_allclose(
        np.asarray((m_d.u_ @ m_d.v_.T).collect()),
        np.asarray((m_s.u_ @ m_s.v_.T).collect()), atol=1e-2)


def test_distribute_sparse_single_device():
    from jax.sharding import Mesh
    x, a, s = mk_sparse(12, 8, 4, 4)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    placed = s.distribute(mesh)
    assert placed.block_format == "bcoo"
    np.testing.assert_allclose(np.asarray(placed.collect()), x)


# ---------------------------------------------------------------------------
# ISSUE-5 satellites: sparse-native aligned slicing + lazy nse re-compaction
# ---------------------------------------------------------------------------


def test_sparse_aligned_slice_stays_bcoo_and_matches_dense():
    x, a, s = mk_sparse(21, 13, 4, 3)
    cases = [
        (slice(0, 8), slice(None)),          # aligned rows, full cols
        (slice(4, 21), slice(0, 6)),         # aligned both, stop at edge
        (slice(0, 7), slice(3, 11)),         # stop mid-block (data mask)
        (slice(8, 8), slice(None)),          # empty selection
        (slice(None), slice(6, 13)),         # full rows, aligned cols
        (0, slice(None)),                    # aligned single row
        (slice(12, 21), slice(9, 13)),       # tail blocks
    ]
    for key in cases:
        out = s[key]
        ref = a[key]
        assert out.block_format == "bcoo", key
        out.check_invariants()
        assert out.shape == ref.shape and out.block_shape == ref.block_shape
        np.testing.assert_allclose(np.asarray(out.collect()),
                                   np.asarray(ref.collect()), err_msg=str(key))
    # unaligned / gather selections still take the documented densify path
    assert s[1:5].block_format == "dense"
    assert s[[0, 5, 2]].block_format == "dense"


def test_sparse_aligned_slice_no_todense_in_jaxpr():
    """The satellite's acceptance: the sliced plan contains no
    ``bcoo_todense``-style scatter and no dense-stacked intermediate —
    it is a pure batch-dim slice of data/indices."""
    x, a, s = mk_sparse(21, 13, 4, 3)
    lz = s.lazy()[0:8, 0:6]
    assert lz.block_format == "bcoo"
    jx = plan.plan_for(lz).jaxpr()
    prims = {e.primitive.name for e in _walk_eqns(jx)}
    assert "scatter" not in prims and "scatter-add" not in prims, prims
    assert_no_densify(jx, s.blocks.shape)
    out = lz.compute()
    out.check_invariants()
    np.testing.assert_allclose(np.asarray(out.collect()), x[:8, :6])


def test_rows_to_dense_matches_collect():
    x, a, s = mk_sparse(19, 11, 4, 3)
    np.testing.assert_allclose(sparse_mod.rows_to_dense(s), x)
    np.testing.assert_allclose(sparse_mod.rows_to_dense(a), x)
    # duplicate-index storage (sparse+sparse concat) still merges correctly
    two = (s + s)
    np.testing.assert_allclose(sparse_mod.rows_to_dense(two), 2 * x)


def test_lazy_sparse_chain_recompacts_nse():
    """ISSUE-5 satellite: a recorded sparse± chain inserts an nse-shrinking
    canonicalize node once the accumulated capacity passes the block bound
    (``costmodel.bcoo_recompaction_pays``), so long chains stop growing
    capacity unboundedly; values still match the eager chain."""
    from repro.core.expr import Canonicalize
    parts = [mk_sparse(8, 6, 2, 3, density=0.5)[2] for _ in range(6)]
    eager = parts[0]
    for p in parts[1:]:
        eager = eager + p
    lz = parts[0].lazy()
    for p in parts[1:]:
        lz = lz + p
    cap = 2 * 3
    assert eager.blocks.nse > cap          # the eager chain DOES grow
    assert lz.expr.meta.blocks.nse <= cap  # the recorded chain is bounded

    kinds = set()
    seen = set()

    def walk(n):
        if id(n) in seen:
            return
        seen.add(id(n))
        kinds.add(type(n).__name__)
        for c in n.children:
            walk(c)

    walk(plan.plan_for(lz).roots[0])
    assert "Canonicalize" in kinds, kinds
    out = lz.compute()
    out.check_invariants()
    assert out.block_format == "bcoo" and out.blocks.nse <= cap
    np.testing.assert_allclose(np.asarray(out.collect()),
                               np.asarray(eager.collect()), rtol=1e-5)
    # capacity below the bound stays untouched (no gratuitous node): a
    # scalar data map preserves the index structure and nse
    small = parts[0].lazy() * 2.0
    assert small.expr.meta.blocks.nse <= cap
    seen.clear(); kinds.clear()
    walk(plan.plan_for(small).roots[0])
    assert "Canonicalize" not in kinds, kinds


def test_recompaction_costmodel_law():
    assert not costmodel.bcoo_recompaction_pays(5, 6)     # below the bound
    assert costmodel.bcoo_recompaction_pays(7, 6)         # past it
    saved = costmodel.bcoo_recompaction_saved_bytes(12, 6, 4, e=4)
    assert saved == 4 * (costmodel.bcoo_bytes(12, 4)
                         - costmodel.bcoo_bytes(6, 4))
