"""repro.analysis: one known-bad fixture per rule (each provably fires
with the right id), a clean-plan fixture asserting silence, the Report
severity/suppression API, invariant-coordinate reporting, and a CLI smoke
run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import costmodel, from_array, plan as P, expr as E
from repro.core.dsarray import DsArray, PAD_ZERO
from repro.core.sparse import random_sparse

pytestmark = pytest.mark.analysis


def mk(n, m, bn, bm, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, m), jnp.float32)
    return np.asarray(x), from_array(x, (bn, bm))


def six_op_chain():
    """The PR-3 acceptance chain: 6 elementwise ops, fuses to one body."""
    _, a = mk(64, 48, 8, 8)
    return a, (((a.lazy() + a) * 2.0 - a).abs() * 0.5 + 0.25)


# ---------------------------------------------------------------------------
# Registry + clean plan
# ---------------------------------------------------------------------------


def test_registry_ships_the_contracted_rules():
    ids = set(analysis.all_rule_ids())
    assert {"no-densify", "no-full-grid-intermediate", "pad-soundness",
            "remask-budget", "recompile-hazard",
            "peak-hbm-liveness"} <= ids


def test_clean_plan_is_silent():
    """All rules over the fused 6-op chain: nothing above info (the
    liveness rule always reports its numbers at info)."""
    _, r = six_op_chain()
    rep = analysis.check(r, fail_on="warn")
    assert rep.ok, rep.render()
    assert all(f.severity == "info" for f in rep.findings), rep.render()


# ---------------------------------------------------------------------------
# no-densify
# ---------------------------------------------------------------------------


def test_no_densify_fires_on_silent_densify():
    """A Blockwise whose fn densifies internally — no Densify node claims
    the conversion, so both planes flag it."""
    s = random_sparse(jax.random.PRNGKey(0), (32, 32), (8, 8), density=0.1)
    bad = E.Blockwise(lambda b: b.todense() * 2, (E.Leaf(s),),
                      ("bad-densify",))
    rep = analysis.check(P.Plan([bad]), rules=["no-densify"])
    assert not rep.ok
    assert all(f.rule == "no-densify" for f in rep.findings)
    assert any(f.severity == "error" for f in rep.findings)


def test_no_densify_silent_on_explicit_densify():
    """`sp + scalar` records an explicit Densify node: the conversion is
    claimed, no finding."""
    s = random_sparse(jax.random.PRNGKey(1), (32, 32), (8, 8), density=0.1)
    rep = analysis.check(s.lazy() + 1.0, rules=["no-densify"])
    assert rep.ok and not rep.findings, rep.render()


def test_no_densify_silent_on_spmm():
    """sp @ dense lowers through bcoo_dot_general — a documented sparse
    sink, never flagged."""
    s = random_sparse(jax.random.PRNGKey(2), (24, 24), (8, 8), density=0.2)
    w = from_array(jax.random.normal(jax.random.PRNGKey(3), (24, 8)), (8, 8))
    rep = analysis.check(s.lazy() @ w, rules=["no-densify"])
    assert rep.ok and not rep.findings, rep.render()


# ---------------------------------------------------------------------------
# no-full-grid-intermediate
# ---------------------------------------------------------------------------


def _unfusable_chain():
    _, a = mk(64, 48, 8, 8)
    # per-block sort cannot enter a loop fusion: XLA materializes the
    # sorted full-grid tensor in ENTRY — an HBM write the plan (which
    # claims one fused body) does not account for
    x = (a.lazy() + 1.0).map_blocks(lambda b: b + jnp.sort(b, axis=-1))
    return a, x + 0.5


def test_full_grid_intermediate_fires_on_unfusable_body():
    _, bad = _unfusable_chain()
    rep = analysis.check(bad, rules=["no-full-grid-intermediate"])
    assert not rep.ok
    f = rep.findings[0]
    assert f.rule == "no-full-grid-intermediate" and f.severity == "error"
    n_defs, budget = f.data
    assert n_defs > budget


def test_full_grid_intermediate_silent_on_fused_chain():
    _, r = six_op_chain()
    rep = analysis.check(r, rules=["no-full-grid-intermediate"])
    assert rep.ok and not rep.findings, rep.render()


def test_assert_fused_single_body_wrapper():
    a, r = six_op_chain()
    analysis.assert_fused_single_body(P.plan_for(r), a.blocks.shape)
    a2, bad = _unfusable_chain()
    with pytest.raises(AssertionError):
        analysis.assert_fused_single_body(P.plan_for(bad), a2.blocks.shape)


# ---------------------------------------------------------------------------
# pad-soundness
# ---------------------------------------------------------------------------


def test_pad_soundness_fires_on_overclaimed_pad():
    """The ISSUE's dirty-pad matmul input: a map_blocks fn the probe cannot
    verify (it breaks the (1,1,1,1) probe shape) claiming PAD_ZERO, fed
    into a matmul whose mask elision would trust the claim."""
    _, a = mk(30, 30, 8, 8)
    _, b = mk(30, 30, 8, 8, seed=1)
    bad = a.lazy().map_blocks(lambda blk: blk * jnp.ones((8,), blk.dtype),
                              pad=PAD_ZERO)
    rep = analysis.check(bad @ b, rules=["pad-soundness"])
    assert not rep.ok
    assert rep.findings[0].rule == "pad-soundness"
    assert rep.findings[0].severity == "error"


def test_pad_soundness_accepts_probe_derived_and_weaker_claims():
    _, a = mk(30, 30, 8, 8)
    clean = (a.lazy() + 1.0) * 2.0              # pad probed by the recorder
    from repro.core.dsarray import PAD_DIRTY
    weaker = a.lazy().map_blocks(lambda b: b * 2.0, pad=PAD_DIRTY)
    for target in (clean, weaker):
        rep = analysis.check(target, rules=["pad-soundness"])
        assert rep.ok and not rep.findings, rep.render()


# ---------------------------------------------------------------------------
# remask-budget
# ---------------------------------------------------------------------------


def test_remask_budget_fires_on_select_heavy_fn():
    _, a = mk(64, 48, 8, 8)
    bad = a.lazy().map_blocks(
        lambda b: jnp.where(b > 0, jnp.where(b > 1, b, 0.0),
                            jnp.where(b < -1, -b, 0.0)))
    rep = analysis.check(bad, rules=["remask-budget"], fail_on="warn")
    assert not rep.ok
    assert rep.by_rule("remask-budget")
    count, budget = rep.by_rule("remask-budget")[0].data
    # the budget law is the costmodel's: one deferred pass per consumer
    assert budget == costmodel.chain_remask_passes(1, True, False) \
        * 1  # single root, no other consumers
    assert count == 3 > budget


def test_remask_budget_silent_within_budget():
    _, r = six_op_chain()
    rep = analysis.check(r, rules=["remask-budget"])
    assert not rep.findings, rep.render()


# ---------------------------------------------------------------------------
# recompile-hazard
# ---------------------------------------------------------------------------


def test_recompile_hazard_fires_on_lambda_key():
    """A raw lambda in map_blocks bakes a fresh function object into the
    plan key: every re-recording misses the compiled-plan cache."""
    _, a = mk(32, 32, 8, 8)
    rep = analysis.check(a.lazy().map_blocks(lambda b: b + 1),
                         rules=["recompile-hazard"], fail_on="warn")
    assert not rep.ok
    assert rep.findings[0].rule == "recompile-hazard"
    assert "lambda" in rep.findings[0].message


def test_recompile_hazard_fires_on_weak_type_drift():
    """The ISSUE's cache-busting baked scalar: `+ 2` and `* 2.0` bake the
    same value at two dtypes, keying two cache entries per recording."""
    _, a = mk(32, 32, 8, 8)
    rep = analysis.check((a.lazy() + 2) * 2.0,
                         rules=["recompile-hazard"], fail_on="warn")
    assert not rep.ok
    assert any("drift" in f.message for f in rep.findings), rep.render()


def test_recompile_hazard_silent_on_named_fns_and_stable_scalars():
    _, r = six_op_chain()   # named fns + distinct scalar values only
    rep = analysis.check(r, rules=["recompile-hazard"])
    assert not rep.findings, rep.render()


# ---------------------------------------------------------------------------
# peak-hbm-liveness
# ---------------------------------------------------------------------------


def _matmul_products(order=8):
    """mi = Li @ K: each product is (n, n) — much bigger than its (n, s)
    and (s, n) factors."""
    n, s = 64, 8
    K = from_array(jax.random.normal(jax.random.PRNGKey(90), (s, n)), (8, 8))
    Ls = [from_array(jax.random.normal(jax.random.PRNGKey(91 + i), (n, s)),
                     (8, 8)) for i in range(order)]
    return [L.lazy() @ K for L in Ls]


def test_liveness_flags_order_sensitive_dag():
    """Right-deep product chain: the naive child-first order computes all 8
    big (n, n) products before any matmul can free one — a
    liveness-minimizing order interleaves and stays ~3 tensors deep."""
    ms = _matmul_products()
    r = ms[-1]
    for m in reversed(ms[:-1]):
        r = m @ r
    rep = analysis.check(r, rules=["peak-hbm-liveness"], fail_on="warn")
    assert not rep.ok
    f = rep.findings[0]
    assert f.rule == "peak-hbm-liveness" and f.severity == "warn"
    naive, minimized = f.data[0], f.data[1]
    assert costmodel.liveness_reorder_pays(naive, minimized)
    assert naive >= 2 * minimized


def test_liveness_info_on_left_deep_chain():
    ms = _matmul_products()
    r = ms[0]
    for m in ms[1:]:
        r = r @ m
    rep = analysis.check(r, rules=["peak-hbm-liveness"], fail_on="warn")
    assert rep.ok
    f = rep.findings[0]
    assert f.severity == "info"
    assert f.data[0] == f.data[1]      # naive is already minimal


def test_liveness_numbers_for_six_op_chain():
    """The acceptance numbers: the fused chain holds the input leaf plus
    one fused output — naive and minimized agree at 2 full tensors."""
    a, r = six_op_chain()
    rep = analysis.liveness_report(r)
    gn, gm, bn, bm = a.blocks.shape
    tensor = costmodel.node_live_bytes((gn, gm, bn, bm), 4)
    assert rep.input_bytes == tensor
    assert rep.naive_peak == rep.minimized_peak == 2 * tensor
    assert not rep.reorder_pays


# ---------------------------------------------------------------------------
# Report API: severities, fail_on, suppression tokens
# ---------------------------------------------------------------------------


def test_fail_on_threshold_and_suppression():
    _, a = mk(32, 32, 8, 8)
    bad = a.lazy().map_blocks(lambda b: b + 1)   # recompile-hazard: warn
    assert analysis.check(bad, rules=["recompile-hazard"],
                          fail_on="error").ok
    rep = analysis.check(bad, rules=["recompile-hazard"], fail_on="warn")
    assert not rep.ok
    with pytest.raises(analysis.AnalysisError):
        rep.raise_if_failed()
    # waive by rule id, then by the finding's own token
    by_rule = analysis.check(bad, rules=["recompile-hazard"],
                             fail_on="warn", suppress=["recompile-hazard"])
    assert by_rule.ok and by_rule.suppressed
    token = rep.findings[0].token
    by_token = analysis.check(bad, rules=["recompile-hazard"],
                              fail_on="warn", suppress=[token])
    assert by_token.ok and by_token.suppressed


def test_check_coerces_dsarray_and_sequences():
    _, a = mk(16, 16, 8, 8)
    assert analysis.check(a).ok
    rep = analysis.check([a.lazy() + 1.0, a.lazy().sum()])
    assert rep.ok


# ---------------------------------------------------------------------------
# Invariant coordinates (satellite: check_invariants names the bad block)
# ---------------------------------------------------------------------------


def test_dense_invariant_failure_names_block_coordinates():
    _, a = mk(10, 10, 8, 8)
    blocks = np.asarray(a.ensure_zero_pad().blocks).copy()
    blocks[1, 1, 7, 7] = 5.0          # global (15, 15): inside the pad
    # under --repro-debug the constructor itself trips the check, so both
    # construction and the explicit call live inside the raises block
    with pytest.raises(AssertionError) as ei:
        bad = DsArray(jnp.asarray(blocks), a.grid, a.pad_state)
        bad.check_invariants()
    msg = str(ei.value)
    assert "block (1, 1)" in msg and "offset (7, 7)" in msg, msg


def test_sparse_invariant_failure_names_block_and_slot():
    from jax.experimental.sparse import BCOO
    _, a = mk(4, 4, 4, 4)
    data = jnp.asarray([[[1.0, 2.0]]])                  # (1, 1, 2)
    indices = jnp.asarray([[[[0, 0], [9, 0]]]])         # slot 1 oob (bn=4)
    sp = BCOO((data, indices), shape=(1, 1, 4, 4))
    with pytest.raises(AssertionError, match=r"block \(0, 0\) slot 1"):
        DsArray(sp, a.grid, PAD_ZERO).check_invariants()


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------


def test_cli_six_op_chain_scenario(capsys):
    from repro.analysis.__main__ import main
    rc = main(["--scenario", "six-op-chain"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "peak HBM: naive=" in out
    assert "all plans clean" in out
