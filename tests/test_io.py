"""Ingestion suite: streaming block-row loaders, readers, IO bugfixes.

* byte-range chunking: ``read_block``/``iter_line_chunks`` tile any file
  into whole-line chunks — no gaps, overlaps, or split records — across
  chunk sizes, missing trailing newlines, and CRLF endings
* streamed ``load_txt_file``/``load_svmlight_file`` are bitwise-equal to
  the in-memory ``from_array``/``from_scipy`` oracles on >=8-block-row
  fixtures (same block_format, pad_state, nse), with tracemalloc peak
  during the load < ``costmodel.INGEST_PEAK_FACTOR`` (3x) one block-row's
  bytes — the paper's "no process ever holds the full matrix" claim as a
  measured bound
* loader edge cases: empty trailing line, final partial block row, CRLF,
  a delimiter byte inside the last chunk, svmlight 1-based vs 0-based
  ids, fault-injected ``io_load`` mid-stream leaving no partial state
* IO-path regressions: sparse ``save_blocks``/``load_blocks`` round-trip
  (and ``save_npy`` raising instead of silently densifying), the
  ``from_scipy`` explicit-nse overflow guard, and ``load_npy_rows``
  streaming off its memory-map instead of materializing the range
"""

import gc
import os
import tracemalloc

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import costmodel, readers
from repro.core import io as rio
from repro.core import sparse as sparse_mod
from repro.core.dsarray import from_array
import repro.resilience as R

pytestmark = pytest.mark.io

try:
    import scipy.sparse as ssp
    HAVE_SCIPY = True
except ImportError:                                    # pragma: no cover
    HAVE_SCIPY = False

needs_scipy = pytest.mark.skipif(not HAVE_SCIPY, reason="scipy not installed")

# acceptance geometry: 8 block rows of (512, 256) blocked (512, 128) —
# one block row = 512 KiB, comfortably above the loaders' fixed costs
# (one ~64 KiB chunk + parse slab) so the 3x bound is meaningful
N, M, BN, BM = 4096, 256, 512, 128
BLOCKROW_BYTES = (M // BM) * BN * BM * 4


def _write_txt(path, arr, fmt="%.4e"):
    np.savetxt(path, arr, delimiter=",", fmt=fmt)


def _write_svm(path, mat, one_based=True, label=lambda i: float(i % 3)):
    shift = 1 if one_based else 0
    with open(path, "w") as f:
        for i in range(mat.shape[0]):
            row = mat.getrow(i).tocoo()
            feats = " ".join(f"{c + shift}:{v:.4e}"
                             for c, v in zip(row.col, row.data))
            f.write(f"{label(i)} {feats}\n")


def _svm_oracle_csr(path, n, m):
    """Re-parse a 1-based svmlight file exactly like the loader does."""
    rows, cols, vals, labs = [], [], [], []
    with open(path) as f:
        for i, ln in enumerate(f):
            toks = ln.split()
            labs.append(float(toks[0]))
            for t in toks[1:]:
                c, v = t.split(":")
                rows.append(i)
                cols.append(int(c) - 1)
                vals.append(np.float32(float(v)))
    mat = ssp.coo_matrix((vals, (rows, cols)), shape=(n, m),
                         dtype=np.float32).tocsr()
    return mat, np.asarray(labs, np.float32)


def _tracked_peak(fn):
    """tracemalloc peak of one call, after a warm-up call primes every
    jit/trace path (compilation overhead is one-time, not per-load)."""
    fn()
    gc.collect()
    tracemalloc.start()
    out = fn()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return peak, out


@pytest.fixture(scope="module")
def big_dense(tmp_path_factory):
    d = tmp_path_factory.mktemp("io_dense")
    rng = np.random.default_rng(0)
    arr = rng.normal(size=(N, M)).astype(np.float32)
    txt = str(d / "big.txt")
    _write_txt(txt, arr)
    npy = str(d / "big.npy")
    np.save(npy, arr)
    return txt, npy, arr


@pytest.fixture(scope="module")
def big_svm(tmp_path_factory):
    d = tmp_path_factory.mktemp("io_svm")
    mat = ssp.random(N, M, density=0.1, random_state=0, format="csr",
                     dtype=np.float32)
    path = str(d / "big.svm")
    _write_svm(path, mat)
    return path


# ---------------------------------------------------------------------------
# byte-range reader
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("trailing_nl", [True, False])
@pytest.mark.parametrize("chunk_bytes", [1, 3, 7, 16, 64, 10_000])
def test_chunks_tile_file_exactly(tmp_path, chunk_bytes, trailing_nl):
    """Every byte once: chunk concatenation reproduces the file for any
    chunk size, including one whose boundary lands mid-line (a delimiter
    byte inside the last chunk) and a file with no trailing newline."""
    rng = np.random.default_rng(int(chunk_bytes) + trailing_nl)
    lines = [bytes(rng.integers(97, 123, size=rng.integers(0, 40),
                                dtype=np.uint8)) for _ in range(50)]
    blob = b"\n".join(lines) + (b"\n" if trailing_nl else b"")
    p = tmp_path / "t.bin"
    p.write_bytes(blob)
    chunks = list(readers.iter_line_chunks(str(p), chunk_bytes))
    assert b"".join(chunks) == blob
    # every chunk is whole lines: it ends at a newline or at EOF
    for c in chunks[:-1]:
        assert c.endswith(b"\n")


def test_read_block_line_ownership(tmp_path):
    """A line belongs to the block its FIRST byte starts in (dask
    convention) — checked at the exact boundary offsets."""
    p = tmp_path / "t.txt"
    p.write_bytes(b"aaaa\nbbbb\ncccc\n")
    with open(p, "rb") as f:
        assert readers.read_block(f, 0, 5) == b"aaaa\n"
        # offset 5 IS the start of "bbbb": owned by this block
        assert readers.read_block(f, 5, 5) == b"bbbb\n"
        # offset 6 is mid-"bbbb": skipped, next line start is 10
        assert readers.read_block(f, 6, 2) == b""
        assert readers.read_block(f, 6, 5) == b"cccc\n"
        assert readers.read_block(f, 15, 5) == b""


def test_empty_file_raises(tmp_path):
    p = tmp_path / "empty.txt"
    p.write_bytes(b"")
    assert list(readers.iter_line_chunks(str(p))) == []
    with pytest.raises(ValueError, match="no data"):
        rio.load_txt_file(str(p), (4, 4))


# ---------------------------------------------------------------------------
# streamed loaders == in-memory oracles (bitwise) + O(block-row) host peak
# ---------------------------------------------------------------------------


def test_load_txt_file_bitwise_equal_and_memory_bound(big_dense):
    txt, _, _ = big_dense
    oracle = from_array(np.loadtxt(txt, delimiter=",", dtype=np.float32,
                                   ndmin=2), (BN, BM))
    peak, got = _tracked_peak(lambda: rio.load_txt_file(txt, (BN, BM)))
    assert got.shape == (N, M) and got.stacked_grid[0] >= 8
    assert got.block_format == oracle.block_format == "dense"
    assert got.pad_state == oracle.pad_state
    assert np.array_equal(np.asarray(got.blocks), np.asarray(oracle.blocks))
    assert peak < costmodel.INGEST_PEAK_FACTOR * BLOCKROW_BYTES, \
        f"peak {peak} >= 3x block-row {BLOCKROW_BYTES}"


@needs_scipy
def test_load_svmlight_bitwise_equal_and_memory_bound(big_svm):
    mat, labs = _svm_oracle_csr(big_svm, N, M)
    oracle = sparse_mod.from_scipy(mat, (BN, BM))
    peak, out = _tracked_peak(
        lambda: rio.load_svmlight_file(big_svm, (BN, BM), n_features=M))
    x, y = out
    assert x.block_format == oracle.block_format == "bcoo"
    assert x.pad_state == oracle.pad_state
    assert int(x.blocks.nse) == int(oracle.blocks.nse)
    assert np.array_equal(np.asarray(x.blocks.data),
                          np.asarray(oracle.blocks.data))
    assert np.array_equal(np.asarray(x.blocks.indices),
                          np.asarray(oracle.blocks.indices))
    assert y.shape == (N, 1) and y.block_shape == (BN, 1)
    assert np.array_equal(np.asarray(y.collect())[:, 0], labs)
    assert peak < costmodel.INGEST_PEAK_FACTOR * BLOCKROW_BYTES, \
        f"peak {peak} >= 3x block-row {BLOCKROW_BYTES}"


@needs_scipy
def test_load_svmlight_dense_path_equals_from_array(big_svm):
    mat, labs = _svm_oracle_csr(big_svm, N, M)
    oracle = from_array(mat.toarray(), (BN, BM))
    x, y = rio.load_svmlight_file(big_svm, (BN, BM), n_features=M,
                                  store_sparse=False)
    assert x.block_format == "dense"
    assert np.array_equal(np.asarray(x.blocks), np.asarray(oracle.blocks))
    assert np.array_equal(np.asarray(y.collect())[:, 0], labs)


def test_load_npy_rows_streams_off_the_mmap(big_dense):
    """Regression: the dense path used to hand the whole (sliced)
    memory-map to blocking in one shot; it now copies one block row at a
    time, and the tracemalloc bound pins that — any future host-side
    materialization of the range re-fails this test."""
    _, npy, arr = big_dense
    peak, got = _tracked_peak(lambda: rio.load_npy_rows(npy, (BN, BM)))
    assert np.array_equal(np.asarray(got.collect()), arr)
    assert peak < costmodel.INGEST_PEAK_FACTOR * BLOCKROW_BYTES, \
        f"peak {peak} >= 3x block-row (full file is {arr.nbytes})"
    # row ranges stream too, and stay bitwise-equal to the oracle
    sub = rio.load_npy_rows(npy, (BN, BM), row_range=(BN, 3 * BN))
    oracle = from_array(arr[BN:3 * BN], (BN, BM))
    assert np.array_equal(np.asarray(sub.blocks), np.asarray(oracle.blocks))
    # regression: an empty row range used to return a silent (0, m) array
    # instead of raising
    with pytest.raises(ValueError, match="empty row range"):
        rio.load_npy_rows(npy, (BN, BM), row_range=(BN, BN))
    # the auto-format density scan still works (it is ALLOWED to read the
    # file — only the dense path must stay O(block-row))
    auto = rio.load_npy_rows(npy, (BN, BM), row_range=(0, BN),
                             block_format="auto")
    assert auto.block_format == "dense"          # gaussian data: not sparse


# ---------------------------------------------------------------------------
# loader edge cases
# ---------------------------------------------------------------------------


def _small_arr():
    return np.arange(70, dtype=np.float32).reshape(10, 7)


def test_txt_crlf_blank_trailing_and_partial_blockrow(tmp_path):
    """CRLF endings + an empty trailing line + n % bn != 0: the final
    partial block row zero-pads and the result matches the oracle."""
    arr = _small_arr()
    p = tmp_path / "crlf.txt"
    body = b"\r\n".join(b",".join(b"%.3f" % v for v in row) for row in arr)
    p.write_bytes(body + b"\r\n\r\n")
    got = rio.load_txt_file(str(p), (4, 3), chunk_bytes=16)
    oracle = from_array(arr, (4, 3))
    assert got.shape == (10, 7)                      # 3 block rows, last ragged
    assert np.array_equal(np.asarray(got.blocks), np.asarray(oracle.blocks))


def test_txt_no_trailing_newline_delimiter_in_last_chunk(tmp_path):
    """The final line has no newline and the chunk boundary lands inside
    it: the EOF block still owns the whole line."""
    arr = _small_arr()
    p = tmp_path / "nonl.txt"
    p.write_bytes(b"\n".join(b",".join(b"%.3f" % v for v in row)
                             for row in arr))
    for cb in (7, 16, 33, 1 << 16):
        got = rio.load_txt_file(str(p), (4, 3), chunk_bytes=cb)
        assert np.array_equal(np.asarray(got.collect()), arr)


def test_txt_ragged_rows_raise(tmp_path):
    p = tmp_path / "ragged.txt"
    p.write_bytes(b"1.0,2.0\n3.0,4.0,5.0\n")
    with pytest.raises(ValueError):
        rio.load_txt_file(str(p), (2, 2), chunk_bytes=8)


@needs_scipy
def test_svmlight_one_based_vs_zero_based(tmp_path):
    pz = tmp_path / "zb.svm"
    pz.write_text("1.0 0:2.5 4:1.5\n0.0 2:3.0\n")
    po = tmp_path / "ob.svm"
    po.write_text("1.0 1:2.5 5:1.5\n0.0 3:3.0\n")
    want = np.zeros((2, 5), np.float32)
    want[0, 0], want[0, 4], want[1, 2] = 2.5, 1.5, 3.0
    xz, _ = rio.load_svmlight_file(str(pz), (2, 2), n_features=5,
                                   zero_based=True)
    xo, _ = rio.load_svmlight_file(str(po), (2, 2), n_features=5)
    assert np.array_equal(np.asarray(xz.todense().collect()), want)
    assert np.array_equal(np.asarray(xo.todense().collect()), want)
    # a 0-based file misread as 1-based: id 0 underflows -> ValueError
    with pytest.raises(ValueError, match="zero_based"):
        rio.load_svmlight_file(str(pz), (2, 2), n_features=5)
    # a 1-based file misread as 0-based: id m lands out of range
    with pytest.raises(ValueError, match="out of range"):
        rio.load_svmlight_file(str(po), (2, 2), n_features=5,
                               zero_based=True)


@needs_scipy
def test_svmlight_comments_qid_and_blank_lines(tmp_path):
    p = tmp_path / "frills.svm"
    p.write_text("1.0 qid:7 1:2.0 3:4.0 # a comment\n"
                 "\n"
                 "-1.0 2:5.0\n")
    x, y = rio.load_svmlight_file(str(p), (2, 2), n_features=3)
    want = np.array([[2.0, 0.0, 4.0], [0.0, 5.0, 0.0]], np.float32)
    assert np.array_equal(np.asarray(x.todense().collect()), want)
    assert np.array_equal(np.asarray(y.collect())[:, 0],
                          np.asarray([1.0, -1.0], np.float32))


def test_io_load_fault_mid_stream_leaves_no_partial_state(tmp_path):
    """The 3rd ``io_load`` arrival is the 2nd chunk (arrival 1 is the
    entry fire): the stream aborts mid-file with ``IOLoadError`` and the
    next load — same path, no injection — is bitwise-correct, proving
    assembly state is all-local."""
    arr = _small_arr()
    p = tmp_path / "fault.txt"
    _write_txt(str(p), arr, fmt="%.3f")
    oracle = from_array(np.loadtxt(str(p), delimiter=",", dtype=np.float32,
                                   ndmin=2), (4, 3))
    with R.inject(R.FaultSpec(kind="io", site="io_load", at=3,
                              where={"source": "load_txt_file"})):
        with pytest.raises(R.IOLoadError):
            rio.load_txt_file(str(p), (4, 3), chunk_bytes=16)
    got = rio.load_txt_file(str(p), (4, 3), chunk_bytes=16)
    assert np.array_equal(np.asarray(got.blocks), np.asarray(oracle.blocks))


@needs_scipy
def test_io_load_fault_mid_stream_svmlight(tmp_path):
    mat = ssp.random(12, 6, density=0.4, random_state=3, format="csr",
                     dtype=np.float32)
    p = tmp_path / "fault.svm"
    _write_svm(str(p), mat)
    with R.inject(R.FaultSpec(kind="io", site="io_load", at=3,
                              where={"source": "load_svmlight_file"})):
        with pytest.raises(R.IOLoadError):
            rio.load_svmlight_file(str(p), (4, 3), n_features=6,
                                   chunk_bytes=32)
    x, _ = rio.load_svmlight_file(str(p), (4, 3), n_features=6,
                                  chunk_bytes=32)
    oracle_mat, _ = _svm_oracle_csr(str(p), 12, 6)
    oracle = sparse_mod.from_scipy(oracle_mat, (4, 3))
    assert np.array_equal(np.asarray(x.blocks.data),
                          np.asarray(oracle.blocks.data))


# ---------------------------------------------------------------------------
# incremental stacked-BCOO builder
# ---------------------------------------------------------------------------


@needs_scipy
def test_builder_fixed_nse_overflow_raises():
    b = sparse_mod.StackedBCOOBuilder(4, (2, 2), nse=1)
    with pytest.raises(ValueError, match="nse=1"):
        b.append_blockrow(np.array([0, 1]), np.array([0, 1]),
                          np.array([1.0, 2.0], np.float32), 2)


@needs_scipy
def test_builder_column_out_of_range_raises():
    b = sparse_mod.StackedBCOOBuilder(4, (2, 2))
    with pytest.raises(ValueError, match="out of range"):
        b.append_blockrow(np.array([0]), np.array([4]),
                          np.array([1.0], np.float32), 1)


@needs_scipy
def test_builder_matches_from_scipy_across_row_capacities():
    """Block rows appended at different local nse pad up to one shared
    capacity in finalize — bit-identical to the one-shot from_scipy."""
    rng = np.random.default_rng(7)
    mat = ssp.random(20, 9, density=0.3, random_state=7, format="csr",
                     dtype=np.float32)
    oracle = sparse_mod.from_scipy(mat, (4, 4))
    b = sparse_mod.StackedBCOOBuilder(9, (4, 4))
    for i in range(0, 20, 4):
        sub = mat[i:i + 4].tocoo()
        b.append_blockrow(sub.row, sub.col, sub.data, min(4, 20 - i))
    got = b.finalize()
    assert int(got.blocks.nse) == int(oracle.blocks.nse)
    assert np.array_equal(np.asarray(got.blocks.data),
                          np.asarray(oracle.blocks.data))
    assert np.array_equal(np.asarray(got.blocks.indices),
                          np.asarray(oracle.blocks.indices))
    sparse_mod.check_bcoo_invariants(got)


# ---------------------------------------------------------------------------
# regression: sparse save_blocks / load_blocks / save_npy
# ---------------------------------------------------------------------------


@needs_scipy
def test_save_blocks_roundtrips_bcoo(tmp_path):
    """Regression: ``np.asarray(a.blocks)`` assumed dense — saving a BCOO
    ds-array crashed.  The spill format now writes data/indices + nse and
    restores the exact sparse array."""
    mat = ssp.random(20, 9, density=0.3, random_state=11, format="csr",
                     dtype=np.float32)
    a = sparse_mod.from_scipy(mat, (4, 4))
    d = str(tmp_path / "spill")
    rio.save_blocks(d, a)
    back = rio.load_blocks(d)
    assert back.block_format == "bcoo"
    assert back.shape == a.shape and back.block_shape == a.block_shape
    assert int(back.blocks.nse) == int(a.blocks.nse)
    assert back.blocks.indices_sorted and back.blocks.unique_indices
    assert np.array_equal(np.asarray(back.blocks.data),
                          np.asarray(a.blocks.data))
    assert np.array_equal(np.asarray(back.blocks.indices),
                          np.asarray(a.blocks.indices))


def test_save_blocks_roundtrips_dense(tmp_path):
    a = from_array(np.arange(24, dtype=np.float32).reshape(6, 4), (2, 2))
    d = str(tmp_path / "spill")
    rio.save_blocks(d, a)
    back = rio.load_blocks(d)
    assert back.block_format == "dense"
    assert np.array_equal(np.asarray(back.blocks), np.asarray(a.blocks))


@needs_scipy
def test_save_npy_raises_on_bcoo(tmp_path):
    """Regression: ``save_npy`` silently densified a sparse ds-array."""
    mat = ssp.random(8, 4, density=0.5, random_state=1, format="csr",
                     dtype=np.float32)
    a = sparse_mod.from_scipy(mat, (4, 4))
    with pytest.raises(ValueError, match="densify"):
        rio.save_npy(str(tmp_path / "x.npy"), a)
    # the documented explicit path still works
    rio.save_npy(str(tmp_path / "x.npy"), a.todense())
    assert np.array_equal(np.load(str(tmp_path / "x.npy")), mat.toarray())


# ---------------------------------------------------------------------------
# regression: from_scipy explicit-nse overflow guard
# ---------------------------------------------------------------------------


@needs_scipy
def test_from_scipy_nse_overflow_raises():
    """Regression: an explicit ``nse`` below the real max block nnz
    silently dropped entries — the packed array round-tripped to the
    WRONG matrix with no error."""
    mat = ssp.csr_matrix(np.array([[1.0, 2.0], [3.0, 4.0]], np.float32))
    assert sparse_mod.max_block_nnz(mat, (2, 2)) == 4
    with pytest.raises(ValueError, match="nse=2"):
        sparse_mod.from_scipy(mat, (2, 2), nse=2)
    # the pre-checked hot path (serve batcher) may still opt out
    capped = sparse_mod.from_scipy(mat, (2, 2), nse=2, check_nse=False)
    assert int(capped.blocks.nse) == 2
    # a sufficient explicit capacity passes the guard unchanged
    ok = sparse_mod.from_scipy(mat, (2, 2), nse=4)
    assert np.array_equal(np.asarray(ok.todense().collect()),
                          mat.toarray())


@needs_scipy
def test_from_scipy_default_nse_never_guards():
    mat = ssp.random(16, 16, density=0.4, random_state=5, format="csr",
                     dtype=np.float32)
    a = sparse_mod.from_scipy(mat, (4, 4))          # nse=None: always fits
    assert np.array_equal(np.asarray(a.todense().collect()), mat.toarray())


# ---------------------------------------------------------------------------
# costmodel ingest laws
# ---------------------------------------------------------------------------


def test_ingest_laws_shape():
    row = costmodel.ingest_blockrow_bytes(2, 512, 128, 4)
    assert row == BLOCKROW_BYTES
    streamed = costmodel.ingest_peak_host_bytes(8, 2, 512, 128, 4, 1 << 16)
    full = costmodel.ingest_peak_host_bytes(8, 2, 512, 128, 4, 1 << 16,
                                            streamed=False)
    assert streamed < full == 8 * row
    ratio = costmodel.ingest_peak_ratio(8, 2, 512, 128, 4, 1 << 16)
    assert ratio == pytest.approx(full / streamed)
    # the ratio law grows linearly with the number of block rows
    assert costmodel.ingest_peak_ratio(16, 2, 512, 128, 4, 1 << 16) > ratio
