"""Estimator subsystem: contract sweep, oracle agreement, and the ISSUE-5
acceptance assertions.

Four families:

* **contract sweep** — every estimator (new subsystem + the refactored
  algorithms classes) round-trips ``get_params``/``set_params``, rejects
  unknown params, is deterministic under a fixed seed, and accepts dense,
  bcoo and ragged-grid ds-array inputs with consistent results;
* **oracle agreement** — CSVM vs ``sklearn.svm.SVC`` (prediction
  agreement), Ridge vs ``sklearn.linear_model.Ridge`` (coefficient
  equality), forest accuracy floor; the sklearn tests skip cleanly when it
  is not installed (optional dev dependency);
* **acceptance** — CSVM ``fit`` on a bcoo input never densifies the data
  matrix (``sparse.todense`` never sees an array of the data's shape, and
  the recorded kernel-block plan's jaxpr contains no dense-stacked-x-shaped
  intermediate), and a 5-iteration recorded fit loop optimizes its plan
  exactly once (``opt_runs == 1``, like the PR-4 hot-loop regression);
* **solver behaviour** — LinearRegression's TSQR fallback fires on
  ill-conditioned tall-skinny inputs and matches the normal-equation path
  on well-conditioned ones.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.core import DsArray, from_array, plan
from repro.core import sparse as sparse_mod
from repro.algorithms import ALS, KMeans, PCA
from repro.estimators import (BaseEstimator, CascadeSVM, LinearRegression,
                              NotFittedError, RandomForestClassifier, Ridge)

pytestmark = pytest.mark.estimators

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# Fixed small datasets
# ---------------------------------------------------------------------------


def two_blobs(seed=0, n_per=60, d=4, sep=3.0):
    rng = np.random.default_rng(seed)
    a = rng.normal(-sep / 2, 1.0, size=(n_per, d))
    b = rng.normal(sep / 2, 1.0, size=(n_per, d))
    x = np.concatenate([a, b]).astype(np.float32)
    y = np.concatenate([np.zeros(n_per), np.ones(n_per)]).astype(np.int32)
    idx = rng.permutation(len(x))
    return x[idx], y[idx]


def three_blobs(seed=0, n_per=50, d=4, spread=8.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(3, d)).astype(np.float32) * spread
    x = np.concatenate([rng.normal(c, 0.5, size=(n_per, d)).astype(np.float32)
                        for c in centers])
    y = np.repeat(np.arange(3), n_per).astype(np.int32)
    idx = rng.permutation(len(x))
    return x[idx], y[idx]


def regression_data(seed=0, n=150, m=5, noise=0.05):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, m)).astype(np.float32)
    coef = rng.normal(size=m).astype(np.float32)
    y = (x @ coef + 0.5 + noise * rng.normal(size=n)).astype(np.float32)
    return x, y, coef


def sparse_two_blobs(seed=0, n_per=60, d=8):
    """Two classes separable on sparse 'topic' features (text-like):
    background activity everywhere, strong loadings on each class's own
    topic half — ~46% dense."""
    rng = np.random.default_rng(seed)
    x = np.where(rng.random((2 * n_per, d)) < 0.8, 0.0,
                 np.abs(rng.normal(size=(2 * n_per, d)))).astype(np.float32)
    sig = ((rng.random((2 * n_per, d // 2)) < 0.6) *
           np.abs(rng.normal(size=(2 * n_per, d // 2))) * 4.0)
    x[:n_per, : d // 2] += sig[:n_per].astype(np.float32)
    x[n_per:, d // 2:] += sig[n_per:].astype(np.float32)
    y = np.concatenate([np.zeros(n_per), np.ones(n_per)]).astype(np.int32)
    idx = rng.permutation(len(x))
    return x[idx], y[idx]


# (name, factory, dataset builder) — the contract-sweep registry.  Block
# shape 32x<d> for the canonical grid; the ragged case re-blocks oddly.
def _svm_linear():
    return CascadeSVM(kernel="linear", sv_cap=32, max_iter=3)


def _svm_rbf():
    return CascadeSVM(kernel="rbf", sv_cap=32, max_iter=3)


ESTIMATORS = [
    ("csvm_linear", _svm_linear, two_blobs),
    ("csvm_rbf", _svm_rbf, two_blobs),
    ("linreg", lambda: LinearRegression(),
     lambda: regression_data()[:2]),
    ("ridge", lambda: Ridge(alpha=0.5),
     lambda: regression_data()[:2]),
    ("forest", lambda: RandomForestClassifier(n_estimators=6, max_depth=5,
                                              seed=3),
     three_blobs),
    ("kmeans", lambda: KMeans(n_clusters=3, max_iter=20, seed=0),
     lambda: (three_blobs()[0], None)),
    ("pca", lambda: PCA(n_components=2, n_iter=30),
     lambda: (three_blobs()[0], None)),
    ("als", lambda: ALS(n_factors=3, reg=1e-3, max_iter=8, tol=1e-6),
     lambda: ((np.random.default_rng(3).normal(size=(48, 3)) @
               np.random.default_rng(4).normal(size=(3, 40)))
              .astype(np.float32), None)),
]

IDS = [e[0] for e in ESTIMATORS]


def _fit(est, x, y, block=(32, None)):
    bn, bm = block
    xd = from_array(x, (bn, bm or x.shape[1]))
    return est.fit(xd, y) if y is not None else est.fit(xd), xd


def _fitted_signature(est, xd):
    """Comparable summary of a fitted model: predictions where the estimator
    predicts rows, else its fitted arrays."""
    if isinstance(est, (CascadeSVM, RandomForestClassifier, LinearRegression,
                        KMeans)):
        return np.asarray(est.predict(xd).collect()).ravel()
    if isinstance(est, PCA):
        return np.asarray(est.components_)
    if isinstance(est, ALS):
        return np.asarray((est.u_ @ est.v_.T).collect())
    raise AssertionError(type(est))


# ---------------------------------------------------------------------------
# Contract: params round-trip, determinism, input formats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,factory,data", ESTIMATORS, ids=IDS)
def test_params_roundtrip(name, factory, data):
    est = factory()
    params = est.get_params()
    # every param is a constructor arg; a clone built from them is identical
    clone = type(est)(**params)
    assert clone.get_params() == params
    # set_params round-trips and chains
    assert est.set_params(**params) is est
    assert est.get_params() == params
    # fitted state is never a param
    assert not any(k.endswith("_") for k in params)
    with pytest.raises(ValueError):
        est.set_params(definitely_not_a_param=1)


@pytest.mark.parametrize("name,factory,data", ESTIMATORS, ids=IDS)
def test_deterministic_under_fixed_seed(name, factory, data):
    out = data()
    x, y = out if isinstance(out, tuple) else (out, None)
    a, xd = _fit(factory(), x, y)
    b, _ = _fit(factory(), x, y)
    np.testing.assert_array_equal(_fitted_signature(a, xd),
                                  _fitted_signature(b, xd))


@pytest.mark.parametrize("name,factory,data", ESTIMATORS, ids=IDS)
def test_accepts_dense_bcoo_and_ragged_grids(name, factory, data):
    out = data()
    x, y = out if isinstance(out, tuple) else (out, None)
    ref, ref_xd = _fit(factory(), x, y)
    ref_sig = _fitted_signature(ref, ref_xd)

    def check(sig, label):
        if name in ("kmeans", "csvm_linear", "csvm_rbf", "forest"):
            # discrete outputs: allow a sliver of boundary flips
            agree = (np.asarray(ref_sig) == np.asarray(sig)).mean()
            assert agree > 0.9, (name, label, agree)
        elif name == "als":
            # blocking changes the per-block random init, so compare each
            # factorization against the ratings matrix it reconstructs
            rmse = float(np.sqrt(((sig - x) ** 2).mean()))
            assert rmse < 0.1, (label, rmse)
        else:
            np.testing.assert_allclose(np.abs(ref_sig), np.abs(sig),
                                       rtol=5e-2, atol=5e-2,
                                       err_msg=f"{name}/{label}")

    # ragged block grid: same data, awkward blocking — same model
    bn, bm = 17, max(1, x.shape[1] - 1)
    xr = from_array(x, (bn, bm))
    rag = factory().fit(xr, y) if y is not None else factory().fit(xr)
    check(_fitted_signature(rag, ref_xd), "ragged")

    # bcoo input: fit must accept it and stay near the dense model
    xs = from_array(x, (32, x.shape[1])).tosparse()
    sp = factory().fit(xs, y) if y is not None else factory().fit(xs)
    check(_fitted_signature(sp, ref_xd), "bcoo")


def test_predict_before_fit_raises():
    for est, args in ((CascadeSVM(), (from_array(np.ones((4, 2)), (2, 2)),)),
                      (LinearRegression(),
                       (from_array(np.ones((4, 2)), (2, 2)),)),
                      (RandomForestClassifier(),
                       (from_array(np.ones((4, 2)), (2, 2)),)),
                      (KMeans(), (from_array(np.ones((4, 2)), (2, 2)),)),
                      (ALS(), (0, 0))):
        with pytest.raises(NotFittedError):
            est.predict(*args)


def test_validation_rejects_bad_inputs():
    x, y = two_blobs()
    xd = from_array(x, (32, 4))
    with pytest.raises(ValueError):
        CascadeSVM().fit(xd, y[:-3])          # length mismatch
    with pytest.raises(ValueError):
        CascadeSVM().fit(np.ones((4, 2, 2)), [1, 0, 1, 0])   # not 2-D
    with pytest.raises(ValueError):
        CascadeSVM().fit(xd, np.zeros_like(y))               # one class
    with pytest.raises(ValueError):
        CascadeSVM(kernel="poly").fit(xd, y)
    with pytest.raises(ValueError):
        LinearRegression(solver="qr").fit(xd, y.astype(np.float32))
    # raw ndarray x is accepted and blocked automatically
    est = LinearRegression().fit(x, y.astype(np.float32))
    assert est.coef_ is not None
    # predict returns the conventional (n, 1) ds-array
    out = est.predict(xd)
    assert isinstance(out, DsArray) and out.shape == (len(x), 1)


# ---------------------------------------------------------------------------
# Oracle agreement (sklearn optional)
# ---------------------------------------------------------------------------


def test_csvm_matches_sklearn_svc():
    svm = pytest.importorskip("sklearn.svm")
    x, y = two_blobs(seed=1)
    xd = from_array(x, (32, 4))
    for kernel in ("linear", "rbf"):
        ours = CascadeSVM(kernel=kernel, c=1.0, sv_cap=48).fit(xd, y)
        theirs = svm.SVC(kernel=kernel, C=1.0, gamma="scale").fit(x, y)
        pred = np.asarray(ours.predict(xd).collect()).ravel()
        agree = (pred == theirs.predict(x)).mean()
        assert agree >= 0.95, (kernel, agree)
        assert ours.score(xd, y) >= 0.95


def test_ridge_matches_sklearn():
    linear_model = pytest.importorskip("sklearn.linear_model")
    x, y, _ = regression_data(seed=2)
    ours = Ridge(alpha=2.0).fit(from_array(x, (32, 5)), y)
    theirs = linear_model.Ridge(alpha=2.0).fit(x, y)
    np.testing.assert_allclose(ours.coef_, theirs.coef_, atol=1e-4)
    assert abs(ours.intercept_ - theirs.intercept_) < 1e-4


def test_forest_accuracy_floor():
    x, y = three_blobs(seed=5, n_per=80)
    xtr, ytr = x[:180], y[:180]
    xte, yte = x[180:], y[180:]          # held-out rows of the SAME blobs
    f = RandomForestClassifier(n_estimators=8, max_depth=6, seed=0).fit(
        from_array(xtr, (32, 4)), ytr)
    assert f.score(from_array(xtr, (32, 4)), ytr) >= 0.95
    assert f.score(from_array(xte, (32, 4)), yte) >= 0.85


def test_linreg_tsqr_fallback_on_ill_conditioned():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(120, 3)).astype(np.float32)
    x = np.concatenate(
        [base, base + 1e-4 * rng.normal(size=base.shape).astype(np.float32)],
        axis=1)
    y = x.sum(axis=1).astype(np.float32)
    est = LinearRegression().fit(from_array(x, (32, 3)), y)
    assert est.solver_used_ == "tsqr"
    assert est.score(from_array(x, (32, 3)), y) > 0.999
    # well-conditioned input keeps the one-plan normal equations
    xw, yw, coef = regression_data(seed=3, noise=0.0)
    est2 = LinearRegression().fit(from_array(xw, (32, 5)), yw)
    assert est2.solver_used_ == "normal"
    np.testing.assert_allclose(est2.coef_, coef, atol=1e-4)
    # Ridge regularizes instead of falling back
    est3 = Ridge(alpha=1.0).fit(from_array(x, (32, 3)), y)
    assert est3.solver_used_ == "normal"


# ---------------------------------------------------------------------------
# Acceptance: sparse-native CSVM + cached fit-loop plan
# ---------------------------------------------------------------------------


from repro.analysis import (  # noqa: E402
    assert_no_densify, walk_eqns)


def test_csvm_sparse_fit_never_densifies_and_caches_plan(monkeypatch):
    """ISSUE-5 acceptance: on a bcoo input (1) no ``todense`` of the data
    matrix anywhere in fit, (2) the recorded kernel-block plan's jaxpr has
    no densified-x intermediate, (3) a 5-iteration fit optimizes its plan
    exactly ONCE and replays the compiled program."""
    x, y = sparse_two_blobs()
    xs = from_array(x, (16, 4)).tosparse()
    assert xs.block_format == "bcoo"

    densified = []
    real_todense = sparse_mod.todense

    def spy(a):
        if getattr(a, "is_sparse", False):
            densified.append(a.shape)
        return real_todense(a)

    monkeypatch.setattr(sparse_mod, "todense", spy)
    plan.clear_cache()
    est = CascadeSVM(kernel="rbf", sv_cap=32, max_iter=5, tol=-1.0)
    est.fit(xs, y)

    # (1) nothing was densified during fit — not the data matrix, not the
    # chunks (the per-node bases go through the O(nnz) rows_to_dense path)
    assert densified == [], densified
    assert est.n_iter_ == 5

    # (3) the per-iteration recorded plan: one optimizer run, 4 structural
    # skips, 4 compiled-plan hits — the PR-4 hot-loop property, now over a
    # whole estimator fit loop
    st = plan.cache_stats()
    assert st["opt_runs"] == 1, st
    assert st["opt_skips"] == 4, st
    assert st["misses"] == 1 and st["hits"] == 4, st

    # (2) the recorded kernel block never materializes dense x: no
    # intermediate in the plan jaxpr has the densified stacked shape
    sv_ds = from_array(jnp.asarray(est.sv_.T),
                       (xs.block_shape[1], est.sv_cap))
    kb = xs.lazy() @ sv_ds
    jx = plan.plan_for(kb).jaxpr()
    dense_shape = xs.blocks.shape
    assert_no_densify(jx, dense_shape)
    prims = {e.primitive.name for e in walk_eqns(jx)}
    assert "bcoo_dot_general" in prims, prims

    # ...and the model still separates the classes
    assert est.score(xs, y) >= 0.9


def test_csvm_sparse_chunks_stay_bcoo():
    """The cascade's row partition is a batch-dim slice of the stacked
    BCOO: chunks keep the bcoo format (no bcoo_todense on the way in)."""
    x, y = sparse_two_blobs(seed=3)
    xs = from_array(x, (16, 4)).tosparse()
    chunk = xs[0:16]
    assert chunk.block_format == "bcoo"
    chunk.check_invariants()
    # and rows_to_dense rebuilds exactly the chunk rows, O(nnz) on the host
    np.testing.assert_allclose(sparse_mod.rows_to_dense(chunk),
                               np.asarray(xs[0:16].todense().collect()))


def test_estimator_fit_predict_lazy_interop():
    """Fitting inside a repro.lazy() context must not corrupt recording
    state: eager driver code (validation, host solvers) runs under the
    recorder only where it records, and results match the eager fit."""
    x, y = two_blobs(seed=9)
    xd = from_array(x, (32, 4))
    eager = CascadeSVM(kernel="linear", sv_cap=32, max_iter=2).fit(xd, y)
    pred_e = np.asarray(eager.predict(xd).collect()).ravel()
    est = CascadeSVM(kernel="linear", sv_cap=32, max_iter=2)
    with repro.lazy():
        est.fit(xd, y)
        pred_l = est.predict(xd)
    np.testing.assert_array_equal(
        np.asarray(pred_l.collect()).ravel(), pred_e)


def test_base_estimator_is_shared_contract():
    """The refactored algorithms classes and the new subsystem share ONE
    base — the whole layer converges on a single estimator contract."""
    for cls in (CascadeSVM, LinearRegression, Ridge, RandomForestClassifier,
                KMeans, ALS, PCA):
        assert issubclass(cls, BaseEstimator), cls


# ---------------------------------------------------------------------------
# Review regressions
# ---------------------------------------------------------------------------


def test_csvm_feedback_loop_actually_iterates():
    """A positive tol must not declare convergence at iteration 1 (there is
    nothing to compare against yet): the cascade feedback loop runs at
    least twice before it may stop."""
    x, y = two_blobs(seed=4)
    est = CascadeSVM(kernel="linear", sv_cap=32, max_iter=4,
                     tol=1e-3).fit(from_array(x, (32, 4)), y)
    assert est.n_iter_ >= 2
    assert est.score(from_array(x, (32, 4)), y) >= 0.95


def test_linreg_tsqr_survives_small_blocks():
    """The tsqr path re-blocks rows when block rows < n_features instead of
    crashing — including when 'auto' picks it on the user's behalf."""
    rng = np.random.default_rng(0)
    base = rng.normal(size=(40, 3)).astype(np.float32)
    x = np.concatenate(
        [base, base + 1e-4 * rng.normal(size=base.shape).astype(np.float32)],
        axis=1)
    y = x.sum(axis=1).astype(np.float32)
    xd = from_array(x, (4, 3))            # block rows (4) < features (6)
    est = LinearRegression().fit(xd, y)
    assert est.solver_used_ == "tsqr"
    assert est.score(xd, y) > 0.999
    est2 = LinearRegression(solver="tsqr").fit(xd, y)
    assert est2.score(xd, y) > 0.999
    # wide inputs (n < m) never take tsqr
    xw = from_array(rng.normal(size=(4, 6)).astype(np.float32), (2, 3))
    yw = np.ones(4, np.float32)
    assert LinearRegression().fit(xw, yw).solver_used_ == "normal"


def test_classifiers_reject_string_labels():
    x, y = two_blobs(seed=2)
    labels = np.where(y == 0, "neg", "pos")
    for est in (CascadeSVM(), RandomForestClassifier()):
        with pytest.raises(ValueError, match="numeric"):
            est.fit(from_array(x, (32, 4)), labels)


def test_all_estimators_fit_inside_ambient_lazy():
    """Every estimator's driver glue masks an ambient repro.lazy() context
    (only the explicit .lazy() lifts record), so fitting inside the context
    manager works and matches the eager fit."""
    x3, y3 = three_blobs(seed=1)
    xd = from_array(x3, (32, 4))
    with repro.lazy():
        km = KMeans(n_clusters=3, max_iter=10, seed=0).fit(xd)
        pc = PCA(n_components=2, n_iter=10).fit(xd)
        _ = pc.transform(xd)
        fr = RandomForestClassifier(n_estimators=4, max_depth=4,
                                    seed=0).fit(xd, y3)
        _ = fr.predict(xd)
        rng = np.random.default_rng(3)
        r = (rng.normal(size=(48, 3)) @ rng.normal(size=(3, 40))
             ).astype(np.float32)
        al = ALS(n_factors=3, reg=1e-3, max_iter=4).fit(from_array(r, (16, 8)))
        _ = al.score(from_array(r, (16, 8)))
    assert km.centers_ is not None and pc.components_ is not None
    assert fr.feat_ is not None and al.u_ is not None


def test_pca_transform_uses_training_mean():
    """transform centers by the mean stored at fit, not the input's own —
    a single training row must project to its training score, not zero."""
    x, _ = three_blobs(seed=2)
    est = PCA(n_components=2, n_iter=30).fit(from_array(x, (32, 4)))
    full = np.asarray(est.transform(from_array(x, (32, 4))).collect())
    one = np.asarray(est.transform(from_array(x[:1], (1, 4))).collect())
    np.testing.assert_allclose(one.ravel(), full[0], rtol=1e-4, atol=1e-4)
    assert np.abs(one).max() > 1e-3          # not the all-zero artifact


def test_ridge_tsqr_keeps_regularization():
    """solver="tsqr" with alpha > 0 factors the augmented [X; sqrt(a)·I]
    system — the penalty is never silently dropped."""
    linear_model = pytest.importorskip("sklearn.linear_model")
    x, y, _ = regression_data(seed=4)
    ours = Ridge(alpha=50.0, solver="tsqr").fit(from_array(x, (32, 5)), y)
    ols = LinearRegression(solver="tsqr").fit(from_array(x, (32, 5)), y)
    sk = linear_model.Ridge(alpha=50.0).fit(x, y)
    np.testing.assert_allclose(ours.coef_, sk.coef_, atol=1e-4)
    # and it is genuinely different from the unregularized QR solve
    assert np.abs(ours.coef_ - ols.coef_).max() > 1e-3


def test_csvm_duplicate_samples_keep_combined_box():
    """Genuine repeated samples combine their box constraints (k·C, like a
    standard SVM); only feedback/merge COPIES are collapsed.  Verified
    against sklearn on a dataset where every row appears twice and C
    binds."""
    svm = pytest.importorskip("sklearn.svm")
    x, y = two_blobs(seed=8, sep=1.5)        # overlapping: C matters
    xd2 = np.repeat(x, 2, axis=0)            # every sample twice
    yd2 = np.repeat(y, 2)
    ours = CascadeSVM(kernel="linear", c=0.05, sv_cap=64,
                      max_iter=3).fit(from_array(xd2, (32, 4)), yd2)
    theirs = svm.SVC(kernel="linear", C=0.05).fit(xd2, yd2)
    pred = np.asarray(ours.predict(from_array(xd2, (32, 4))).collect())
    agree = (pred.ravel() == theirs.predict(xd2)).mean()
    assert agree >= 0.9, agree
    # the dedup really accumulated: some collapsed slot exceeds one C
    assert ours.dual_coef_.max() > 0.05 * (1 + 1e-6)


def test_linreg_rank_deficient_min_norm():
    """An all-zero (or exactly collinear) feature column must not crash the
    alpha=0 solvers: both the normal-equation path (sparse input) and the
    tsqr path (dense input) return the min-norm lstsq solution."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(60, 6)).astype(np.float32)
    x[:, 3] = 0.0                              # dead feature
    w = rng.normal(size=6).astype(np.float32)
    w[3] = 0.0
    y = (x @ w).astype(np.float32)
    for xd in (from_array(x, (16, 6)).tosparse(), from_array(x, (16, 6))):
        est = LinearRegression().fit(xd, y)
        assert np.isfinite(est.coef_).all(), est.solver_used_
        pred = np.asarray(est.predict(xd).collect()).ravel()
        np.testing.assert_allclose(pred, y, atol=1e-3,
                                   err_msg=est.solver_used_)
