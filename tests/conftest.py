import functools
import os
import sys
import types

# tests see ONE CPU device (the dry-run sets its own 512-device flag in a
# separate process); repo root on path so `benchmarks` imports resolve.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# Optional-hypothesis shim.
#
# The property tests use a small slice of the hypothesis API.  When the real
# package is available (see requirements-dev.txt) it is used untouched; when
# it is missing we install a deterministic stand-in: each @given test runs
# against a FIXED example corpus drawn from seeded numpy Generators, so the
# suite still exercises the same shape/seed diversity reproducibly.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as _np

    _SHIM_SEED = 20260725

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    def _integers(lo, hi):
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def _floats(lo, hi):
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def _lists(elem, min_size=0, max_size=10):
        def draw(rng):
            k = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(k)]
        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def _just(value):
        return _Strategy(lambda rng: value)

    def _builds(fn, *elems):
        return _Strategy(lambda rng: fn(*[e.draw(rng) for e in elems]))

    def _composite(fn):
        def make(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda s: s.draw(rng), *args, **kwargs)
            return _Strategy(draw_fn)
        return make

    class _Settings:
        _profiles = {"default": {"max_examples": 10}}
        _active = "default"

        def __init__(self, **kwargs):
            self._kwargs = kwargs

        def __call__(self, test):  # used as @settings(...) decorator
            n = self._kwargs.get("max_examples")
            if n is not None:
                test._shim_max_examples = n
            return test

        @classmethod
        def register_profile(cls, name, **kwargs):
            cls._profiles[name] = kwargs

        @classmethod
        def load_profile(cls, name):
            cls._active = name

        @classmethod
        def max_examples(cls):
            return cls._profiles.get(cls._active, {}).get("max_examples", 10)

    def _given(*strategies, **kw_strategies):
        import inspect

        def deco(test):
            @functools.wraps(test)
            def wrapper():
                n = getattr(test, "_shim_max_examples", None) or _Settings.max_examples()
                for i in range(n):
                    rng = _np.random.default_rng(_SHIM_SEED + i)
                    drawn = [s.draw(rng) for s in strategies]
                    kdrawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    test(*drawn, **kdrawn)
            # hide the strategy parameters from pytest's fixture resolution
            # (real hypothesis does the same signature rewrite)
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.booleans = _booleans
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples
    _st.just = _just
    _st.builds = _builds
    _st.composite = _composite

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow="too_slow",
                                             data_too_large="data_too_large")
    _hyp.assume = lambda cond: None
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# ---------------------------------------------------------------------------
# Shared jaxpr-walking helpers.
#
# The canonical versions moved into ``repro.analysis.jaxprs`` in PR 6 (the
# analyzer's jaxpr plane is built on them); these re-exports keep the
# long-standing `from conftest import walk_eqns` sites working and
# guarantee tests and analyzer can never drift apart.
# ---------------------------------------------------------------------------

from repro.analysis.jaxprs import (  # noqa: E402,F401
    dense_operand_intermediates, walk_eqns)


# ---------------------------------------------------------------------------
# Opt-in invariant lane: `pytest --repro-debug` sets REPRO_DEBUG=1 for the
# whole session, so every DsArray construction (and the sparse BCOO paths)
# re-validates `check_invariants()` — the CI debug lane runs the full
# tier-1 suite this way, and failures name the offending block coordinates.
# ---------------------------------------------------------------------------

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--repro-debug", action="store_true", default=False,
        help="run with REPRO_DEBUG=1: validate DsArray.check_invariants() "
             "at every construction")


def pytest_configure(config):
    if config.getoption("--repro-debug"):
        os.environ["REPRO_DEBUG"] = "1"


@pytest.fixture(autouse=True)
def _repro_debug_invariants(request):
    """Keep REPRO_DEBUG visible per-test when the lane is armed (tests that
    themselves mutate the env restore it afterwards)."""
    if request.config.getoption("--repro-debug"):
        prev = os.environ.get("REPRO_DEBUG")
        os.environ["REPRO_DEBUG"] = "1"
        yield
        if prev is None:
            os.environ["REPRO_DEBUG"] = "1"
        else:
            os.environ["REPRO_DEBUG"] = prev
    else:
        yield


# ---------------------------------------------------------------------------
# Telemetry hygiene: every test starts with zeroed counters and an empty
# trace buffer.  One obs.reset_all() replaces the per-module autouse
# fixtures that used to hand-reset serve/resilience stats in their own
# test files (plan compiled caches are storage, not telemetry — tests that
# need a cold cache still call plan.clear_cache() themselves).
# ---------------------------------------------------------------------------


@pytest.fixture(autouse=True)
def _obs_reset():
    from repro import obs
    obs.reset_all()
    yield
    obs.reset_all()
    obs.disable()
