import os
import sys

# tests see ONE CPU device (the dry-run sets its own 512-device flag in a
# separate process); repo root on path so `benchmarks` imports resolve.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
