"""End-to-end behaviour tests: the system reproduces the paper's claims and
the LM framework trains/serves correctly.
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dataset, costmodel, from_array
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_paper_claim_transpose_complexity():
    """Paper §5.2: Dataset transpose N^2+N tasks vs ds-array N tasks; both
    produce the same matrix."""
    x = np.random.default_rng(0).normal(size=(24, 24)).astype(np.float32)
    n = 4
    ds = Dataset.from_array(x, n)
    t0 = ds.counter.tasks
    baseline = ds.transpose()
    baseline_tasks = ds.counter.tasks - t0
    a = from_array(x, (6, 6))
    np.testing.assert_allclose(np.asarray(a.T.collect()), baseline.collect())
    assert baseline_tasks == n * n + n
    # ds-array: grid permutation + local transpose = one fused op,
    # modeled as N tasks (one per block row) on PyCOMPSs
    assert costmodel.dsarray_transpose_tasks(n, n) == n


def test_paper_claim_two_orders_of_magnitude():
    """§5.6 'two orders of magnitude faster in the best case' under the
    calibrated scheduler model at MareNostrum scale (1536 partitions)."""
    n, cores = 1536, 768
    t_dataset = costmodel.pycompss_time(
        costmodel.dataset_transpose_tasks(n), 0.01, cores)
    t_dsarray = costmodel.pycompss_time(
        costmodel.dsarray_transpose_tasks(n, 1), 0.01, cores)
    assert t_dataset / t_dsarray >= 100


def test_train_driver_loss_decreases(tmp_path):
    state = train_mod.main([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "40",
        "--batch", "8", "--seq", "64", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "20", "--lr", "3e-3", "--log-every", "100"])
    assert state is not None


def test_train_driver_restart_resumes(tmp_path):
    # crash at step 12, checkpoint every 10 -> must resume and finish
    train_mod.main([
        "--arch", "mamba2-370m", "--smoke", "--steps", "25",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path / "ck2"),
        "--ckpt-every", "10", "--crash-at", "12", "--log-every", "100"])
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path / "ck2")) == 24


def test_serve_driver_families():
    for arch in ["qwen1.5-0.5b", "mamba2-370m", "seamless-m4t-medium",
                 "zamba2-2.7b"]:
        gen = serve_mod.main(["--arch", arch, "--smoke", "--batch", "2",
                              "--prompt-len", "6", "--gen", "6"])
        assert gen.shape == (2, 6)
        assert not np.isnan(np.asarray(gen, dtype=np.float32)).any()


def test_grad_accumulation_equivalence():
    """accum_steps=2 must match accum_steps=1 up to fp tolerance."""
    from repro.configs import get_smoke_config
    from repro.models.model import build_model
    from repro.optim import make_optimizer
    from repro.train.step import init_state, make_train_step
    from repro.data import PipelineConfig, SyntheticPipeline

    cfg = get_smoke_config("yi-9b")
    model = build_model(cfg)
    opt = make_optimizer("adamw", peak_lr=1e-3)
    pipe = SyntheticPipeline(PipelineConfig(global_batch=8, seq_len=16,
                                            vocab_size=cfg.vocab_size))
    batch = pipe.batch_at(0)
    s0 = init_state(model, opt, jax.random.PRNGKey(0))
    _, m1 = make_train_step(model, opt, accum_steps=1)(s0, batch)
    _, m2 = make_train_step(model, opt, accum_steps=2)(s0, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
