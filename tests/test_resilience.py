"""Chaos suite for repro.resilience: every recovery path proven by a
deterministic injected fault.

* injector: counting/Bernoulli determinism, site/mode/where filtering
* run_resilient: retry-then-succeed, retry exhaustion, each rung of the
  fused → eager → einsum degradation ladder (results oracle-checked),
  deterministic errors raising immediately, clean-path zero stats
* guards: finite_report block coordinates (dense + bcoo), pad-state
  awareness (DIRTY pads never false-positive), guard_finite on poisoned
  plan outputs, require_finite_host
* checkpoint satellites: AsyncCheckpointer writer-thread error
  propagation, restore dtype-mismatch raise + allow_cast escape hatch
* estimator fits: CSVM / ALS / KMeans killed mid-fit resume from the
  newest committed iteration and match the uninterrupted fit;
  save_model/load_model round-trips through the registry
* run_with_restarts: deterministic failures stop immediately
"""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

import repro.resilience as R
from repro.core import expr as expr_mod
from repro.core import plan as plan_mod
from repro.core.dsarray import PAD_DIRTY, DsArray, from_array
from repro.resilience.inject import _Armed

pytestmark = pytest.mark.resilience

SEED = 20260808


def _lazy_chain(a, b):
    with expr_mod.lazy():
        return (a @ b) * 2.0 + 1.0


def _mats(rng, n=8, k=12, m=6, bs=((4, 4), (4, 3))):
    x = rng.normal(size=(n, k)).astype(np.float32)
    y = rng.normal(size=(k, m)).astype(np.float32)
    return (from_array(x, bs[0]), from_array(y, bs[1]),
            (x @ y) * 2.0 + 1.0)


# counter hygiene is the session-wide autouse obs.reset_all() fixture in
# conftest.py — no per-module reset needed


# ---------------------------------------------------------------------------
# Injector determinism
# ---------------------------------------------------------------------------

def test_injector_counting_determinism():
    spec = R.FaultSpec(kind="transient", site="s", at=3, times=2)
    for _ in range(2):               # identical behaviour on every arming
        with R.inject(spec) as (armed,):
            fired = []
            for i in range(1, 8):
                try:
                    R.maybe_fire("s")
                    fired.append(False)
                except R.TransientError:
                    fired.append(True)
            assert fired == [False, False, True, True,
                             False, False, False]
            assert armed.hits == 7 and armed.fired == 2


def test_injector_bernoulli_replay():
    spec = R.FaultSpec(kind="oom", site="s", p=0.5, seed=123)

    def draw():
        seq = []
        with R.inject(spec):
            for _ in range(32):
                try:
                    R.maybe_fire("s")
                    seq.append(0)
                except R.OOMError:
                    seq.append(1)
        return seq

    first = draw()
    assert first == draw()           # seeded: exact replay
    assert 0 < sum(first) < 32       # and actually Bernoulli, not constant
    # a different seed gives a different (deterministic) schedule
    other = _Armed(R.FaultSpec(kind="oom", site="s", p=0.5, seed=124))
    assert [other.arrive() for _ in range(32)] != [bool(v) for v in first]


def test_injector_site_mode_where_filters():
    with R.inject(
            R.FaultSpec(kind="transient", site="a", modes=("fused",)),
            R.FaultSpec(kind="crash", site="b",
                        where={"estimator": "X", "iteration": 2},
                        times=None)):
        R.maybe_fire("a", mode="eager")          # wrong mode: no fire
        R.maybe_fire("b", estimator="X", iteration=1)   # wrong where
        R.maybe_fire("b", estimator="Y", iteration=2)   # wrong where
        with pytest.raises(R.TransientError):
            R.maybe_fire("a", mode="fused")
        with pytest.raises(R.CrashError):
            R.maybe_fire("b", estimator="X", iteration=2)
    R.maybe_fire("a", mode="fused")              # disarmed outside the block


def test_classify_error_taxonomy():
    ce = R.classify_error
    assert ce(R.TransientError("x")) == R.TRANSIENT
    assert ce(R.OOMError("x")) == R.OOM
    assert ce(MemoryError()) == R.OOM
    assert ce(R.CrashError("x")) == R.DETERMINISTIC
    assert ce(R.NumericalDivergence("nan")) == R.DETERMINISTIC
    assert ce(ValueError("bad shape")) == R.DETERMINISTIC
    # opaque runtime errors classify by status message
    assert ce(RuntimeError("RESOURCE_EXHAUSTED: out of memory")) == R.OOM
    assert ce(RuntimeError("UNAVAILABLE: socket closed")) == R.TRANSIENT
    # unknowns take the caller's default
    assert ce(RuntimeError("boom")) == R.DETERMINISTIC
    assert ce(RuntimeError("boom"), default=R.TRANSIENT) == R.TRANSIENT


# ---------------------------------------------------------------------------
# run_resilient: retry + degradation ladder
# ---------------------------------------------------------------------------

def test_clean_path_zero_stats():
    rng = np.random.default_rng(SEED)
    a, b, want = _mats(rng)
    out = R.run_resilient(_lazy_chain(a, b), guard="finite")
    np.testing.assert_allclose(np.asarray(out.collect()), want, rtol=1e-5)
    s = R.stats()
    assert s["retries"] == 0 and s["degradations"] == 0
    assert s["recoveries"] == 0 and s["guard_failures"] == 0
    assert s["executions"] == 1


def test_transient_retry_then_succeed():
    rng = np.random.default_rng(SEED + 1)
    a, b, want = _mats(rng)
    with R.inject(R.FaultSpec(kind="transient", site="plan_execute", at=1)):
        out = R.run_resilient(_lazy_chain(a, b))
    np.testing.assert_allclose(np.asarray(out.collect()), want, rtol=1e-5)
    s = R.stats()
    assert s["retries"] == 1 and s["recoveries"] == 1
    assert s["degradations"] == 0


def test_transient_retry_exhaustion():
    rng = np.random.default_rng(SEED + 2)
    a, b, _ = _mats(rng)
    lz = _lazy_chain(a, b)
    with R.inject(R.FaultSpec(kind="transient", site="plan_execute",
                              times=None)):
        with pytest.raises(R.TransientError):
            R.run_resilient(lz, policy=R.RetryPolicy(max_retries=2))
    assert R.stats()["retries"] == 2


def test_retry_backoff_schedule():
    pol = R.RetryPolicy(backoff=0.1, backoff_factor=2.0, max_backoff=0.35)
    assert [pol.delay(i) for i in (1, 2, 3, 4)] == [0.1, 0.2, 0.35, 0.35]
    assert R.RetryPolicy().delay(1) == 0.0       # no sleeps by default


def test_deterministic_raises_immediately():
    rng = np.random.default_rng(SEED + 3)
    a, b, _ = _mats(rng)
    lz = _lazy_chain(a, b)
    with R.inject(R.FaultSpec(kind="crash", site="plan_execute",
                              times=None)):
        with pytest.raises(R.CrashError):
            R.run_resilient(lz)
    s = R.stats()
    assert s["retries"] == 0 and s["degradations"] == 0


def test_oom_degrades_to_eager():
    rng = np.random.default_rng(SEED + 4)
    a, b, want = _mats(rng)
    before = plan_mod.cache_stats()["eager_launches"]
    with R.inject(R.FaultSpec(kind="oom", site="plan_execute",
                              modes=("fused",), times=None)):
        out = R.run_resilient(_lazy_chain(a, b))
    np.testing.assert_allclose(np.asarray(out.collect()), want, rtol=1e-5)
    assert R.stats()["degradations"] == 1
    assert plan_mod.cache_stats()["eager_launches"] == before + 1


def test_oom_degrades_to_einsum():
    rng = np.random.default_rng(SEED + 5)
    a, b, want = _mats(rng)
    with R.inject(R.FaultSpec(kind="oom", site="plan_execute",
                              modes=("fused", "eager"), times=None)):
        out = R.run_resilient(_lazy_chain(a, b))
    np.testing.assert_allclose(np.asarray(out.collect()), want, rtol=1e-5)
    s = R.stats()
    assert s["degradations"] == 2 and s["recoveries"] == 1


def test_oom_ladder_exhausted():
    rng = np.random.default_rng(SEED + 6)
    a, b, _ = _mats(rng)
    lz = _lazy_chain(a, b)
    with R.inject(R.FaultSpec(kind="oom", site="plan_execute", times=None)):
        with pytest.raises(R.OOMError):
            R.run_resilient(lz)
    assert R.stats()["degradations"] == 2        # rode the ladder down first


def test_execute_eager_matches_fused():
    rng = np.random.default_rng(SEED + 7)
    a, b, want = _mats(rng)
    p = plan_mod.plan_for(_lazy_chain(a, b))
    fused = p.execute()[0]
    eager = p.execute_eager()[0]
    einsum = p.execute_eager(backend="einsum")[0]
    for got in (fused, eager, einsum):
        np.testing.assert_allclose(np.asarray(got.collect()), want,
                                   rtol=1e-5)
    assert os.environ.get("REPRO_GEMM") is None or \
        os.environ.get("REPRO_GEMM") != "einsum"   # override was scoped


def test_multi_root_and_prepared_plan():
    rng = np.random.default_rng(SEED + 8)
    a, b, _ = _mats(rng)
    with expr_mod.lazy():
        s1 = (a * 2.0).sum()
        s2 = (a * 2.0).mean()
    o1, o2 = R.run_resilient(s1, s2)
    assert np.isclose(float(o1), 2.0 * np.asarray(a.collect()).sum())
    assert np.isclose(float(o2), 2.0 * np.asarray(a.collect()).mean())


# ---------------------------------------------------------------------------
# Numerical guards
# ---------------------------------------------------------------------------

def test_finite_report_dense_coordinates():
    a = from_array(np.ones((5, 7), np.float32), (2, 3))
    assert a.finite_report().ok
    bad = R.poison_block(a, (1, 2))
    rep = bad.finite_report()
    assert not rep.ok and len(rep.bad_blocks) == 1
    bb = rep.bad_blocks[0]
    assert (bb.gi, bb.gj) == (1, 2) and bb.n_nan == 1 and bb.n_inf == 0
    assert "block (1, 2)" in rep.describe()
    inf_bad = R.poison_block(a, (0, 0), value=np.inf)
    assert inf_bad.finite_report().bad_blocks[0].n_inf == 1


def test_finite_report_dirty_pad_no_false_positive():
    # NaN strictly in the pad region of a DIRTY-pad array: not a divergence
    a = from_array(np.ones((3, 3), np.float32), (2, 2))
    blocks = np.asarray(a.blocks).copy()
    blocks[1, 1, 1, 1] = np.nan                  # pad corner (row 3, col 3)
    dirty = DsArray(jnp.asarray(blocks), a.grid, PAD_DIRTY)
    assert dirty.finite_report().ok
    assert R.all_finite(dirty)
    R.guard_finite(dirty)                        # no raise
    # ... but a NaN inside the logical shape still reports
    blocks[0, 0, 1, 0] = np.nan
    dirty2 = DsArray(jnp.asarray(blocks), a.grid, PAD_DIRTY)
    rep = dirty2.finite_report()
    assert [(b.gi, b.gj) for b in rep.bad_blocks] == [(0, 0)]
    assert rep.bad_blocks[0].first == (1, 0)


def test_finite_report_bcoo_slot():
    a = from_array(np.eye(6, dtype=np.float32), (3, 3)).tosparse()
    assert a.finite_report().ok
    bad = R.poison_block(a, (1, 1))
    rep = bad.finite_report()
    assert not rep.ok and rep.block_format == "bcoo"
    bb = rep.bad_blocks[0]
    assert (bb.gi, bb.gj) == (1, 1) and bb.sparse
    assert "slot" in bb.describe()


def test_guard_finite_on_poisoned_plan_output():
    rng = np.random.default_rng(SEED + 9)
    a, b, _ = _mats(rng)
    with R.inject(R.FaultSpec(kind="poison", site="plan_result",
                              block=(0, 1))):
        with pytest.raises(R.NumericalDivergence) as ei:
            R.run_resilient(_lazy_chain(a, b), guard="finite")
    assert "block (0, 1)" in str(ei.value)
    assert ei.value.report is not None
    assert R.stats()["guard_failures"] == 1


def test_require_finite_host():
    ok = np.arange(4.0)
    assert R.require_finite_host(ok, "x") is ok
    with pytest.raises(R.NumericalDivergence, match="1 nan"):
        R.require_finite_host(np.array([1.0, np.nan]), "solver out")
    # integer arrays pass trivially
    R.require_finite_host(np.arange(3), "ints")


def test_linear_solver_divergence_falls_back():
    # a singular system: solve() yields inf/nan or raises; the unified
    # guard must route both to the lstsq fallback, not crash the fit
    from repro.estimators import LinearRegression
    x = np.ones((12, 3), np.float32)             # rank-1: singular Gram
    y = np.arange(12.0)
    est = LinearRegression(alpha=0.0).fit(x, y)
    assert np.isfinite(np.asarray(est.coef_)).all()


def test_io_load_injection():
    import repro.core.io as rio
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "x.npy")
        np.save(p, np.ones((4, 4), np.float32))
        loaded = rio.load_npy_rows(p, (2, 2))
        assert loaded.shape == (4, 4)
        with R.inject(R.FaultSpec(kind="io", site="io_load")):
            with pytest.raises(R.IOLoadError):
                rio.load_npy_rows(p, (2, 2))
        # IOLoadError is an OSError: existing OSError handling catches it
        assert issubclass(R.IOLoadError, OSError)


# ---------------------------------------------------------------------------
# Checkpoint satellites
# ---------------------------------------------------------------------------

def test_async_checkpointer_error_propagates(tmp_path):
    from repro.checkpoint import AsyncCheckpointer, CheckpointWriteError
    bad_root = os.path.join(str(tmp_path), "afile")
    with open(bad_root, "w") as f:
        f.write("not a directory")               # save() will explode
    ac = AsyncCheckpointer(bad_root)
    ac.save(1, {"w": np.ones(3)})
    with pytest.raises(CheckpointWriteError):
        ac.wait()
    assert ac.last_committed is None             # never lied about a commit
    ac.wait()                                    # error consumed: no re-raise


def test_async_checkpointer_error_from_next_save(tmp_path):
    from repro.checkpoint import AsyncCheckpointer, CheckpointWriteError
    bad_root = os.path.join(str(tmp_path), "afile2")
    with open(bad_root, "w") as f:
        f.write("x")
    ac = AsyncCheckpointer(bad_root)
    ac.save(1, {"w": np.ones(3)})
    import time
    for _ in range(100):                         # let the writer die
        if ac._thread is not None and not ac._thread.is_alive():
            break
        time.sleep(0.01)
    with pytest.raises(CheckpointWriteError):
        ac.save(2, {"w": np.ones(3)})


def test_restore_dtype_mismatch_raises(tmp_path):
    from repro.checkpoint import restore, save
    root = str(tmp_path)
    save(root, 0, {"w": np.ones(4, np.int32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore(root, 0, {"w": np.ones(4, np.float32)})
    out = restore(root, 0, {"w": np.ones(4, np.float32)}, allow_cast=True)
    assert np.asarray(out["w"]).dtype == np.float32
    same = restore(root, 0, {"w": np.ones(4, np.int32)})
    assert np.asarray(same["w"]).dtype == np.int32


def test_run_with_restarts_stops_on_deterministic(tmp_path):
    from repro.distributed.fault_tolerance import run_with_restarts

    calls = []

    def step(state, i):
        calls.append(i)
        if i == 2:
            raise R.NumericalDivergence("loss went NaN")
        return state + 1, {"loss": float(state)}

    with pytest.raises(R.NumericalDivergence):
        run_with_restarts(init_state=lambda: 0, step_fn=step,
                          ckpt_root=str(tmp_path), total_steps=6,
                          ckpt_every=2, max_failures=3)
    # no restart loop: the NaN step ran exactly once
    assert calls.count(2) == 1


# ---------------------------------------------------------------------------
# Checkpointable fits + model registry
# ---------------------------------------------------------------------------

def _svm_data():
    rng = np.random.default_rng(SEED + 10)
    x = rng.normal(size=(96, 6)).astype(np.float32)
    w = rng.normal(size=6)
    y = (x @ w > 0).astype(np.float64)
    return from_array(x, (32, 3)), y


def test_csvm_crash_resume_matches_uninterrupted():
    from repro.estimators import CascadeSVM
    xd, y = _svm_data()
    ref = CascadeSVM(max_iter=5, tol=1e-12).fit(xd, y)
    pred_ref = np.asarray(ref.predict(xd).collect()).ravel()
    with tempfile.TemporaryDirectory() as d:
        interrupted = CascadeSVM(max_iter=5, tol=1e-12)
        with R.inject(R.FaultSpec(kind="crash", site="fit_iteration",
                                  where={"iteration": 3})):
            with pytest.raises(R.CrashError):
                interrupted.fit(xd, y, checkpoint_dir=d)
        resumed = CascadeSVM(max_iter=5, tol=1e-12)
        resumed.fit(xd, y, checkpoint_dir=d, resume=d)
        assert resumed.n_iter_ == ref.n_iter_
        assert resumed.n_sv_ == ref.n_sv_
        np.testing.assert_allclose(np.asarray(resumed.sv_),
                                   np.asarray(ref.sv_))
        np.testing.assert_allclose(np.asarray(resumed.dual_coef_),
                                   np.asarray(ref.dual_coef_))
        pred_res = np.asarray(resumed.predict(xd).collect()).ravel()
        assert (pred_ref == pred_res).all()


def test_als_crash_resume_matches_uninterrupted():
    from repro.algorithms import ALS
    rng = np.random.default_rng(SEED + 11)
    rd = from_array((rng.random((40, 24)) * 5).astype(np.float32), (16, 8))
    ref = ALS(n_factors=4, max_iter=4, tol=1e-12, seed=3).fit(rd)
    with tempfile.TemporaryDirectory() as d:
        interrupted = ALS(n_factors=4, max_iter=4, tol=1e-12, seed=3)
        with R.inject(R.FaultSpec(kind="crash", site="fit_iteration",
                                  where={"iteration": 3})):
            with pytest.raises(R.CrashError):
                interrupted.fit(rd, checkpoint_dir=d)
        resumed = ALS(n_factors=4, max_iter=4, tol=1e-12, seed=3)
        resumed.fit(rd, checkpoint_dir=d, resume=d)
        assert resumed.n_iter_ == ref.n_iter_
        np.testing.assert_allclose(np.asarray(resumed.u_.collect()),
                                   np.asarray(ref.u_.collect()), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(resumed.v_.collect()),
                                   np.asarray(ref.v_.collect()), rtol=1e-5)


def test_kmeans_crash_resume():
    from repro.algorithms import KMeans
    rng = np.random.default_rng(SEED + 12)
    x = rng.normal(size=(60, 5)).astype(np.float32)
    x[:30] += 4.0
    xd = from_array(x, (16, 5))
    ref = KMeans(n_clusters=3, max_iter=8, seed=7).fit(xd)
    with tempfile.TemporaryDirectory() as d:
        interrupted = KMeans(n_clusters=3, max_iter=8, seed=7)
        with R.inject(R.FaultSpec(kind="crash", site="fit_iteration",
                                  where={"iteration": 2})):
            with pytest.raises(R.CrashError):
                interrupted.fit(xd, checkpoint_dir=d)
        resumed = KMeans(n_clusters=3, max_iter=8, seed=7)
        resumed.fit(xd, checkpoint_dir=d, resume=d)
        assert resumed.n_iter_ == ref.n_iter_
        np.testing.assert_allclose(np.asarray(resumed.centers_),
                                   np.asarray(ref.centers_), rtol=1e-5)


def test_save_load_model_registry():
    from repro.estimators import CascadeSVM, load_model
    from repro.estimators.base import BaseEstimator, NotFittedError
    xd, y = _svm_data()
    svm = CascadeSVM(max_iter=3, tol=1e-12).fit(xd, y)
    pred_ref = np.asarray(svm.predict(xd).collect()).ravel()
    with tempfile.TemporaryDirectory() as d:
        svm.save_model(d)
        # registry dispatch (class name from the manifest)
        again = load_model(d)
        assert type(again) is CascadeSVM
        assert again.get_params() == svm.get_params()
        pred = np.asarray(again.predict(xd).collect()).ravel()
        assert (pred == pred_ref).all()
        # dtype fidelity through the manifest-derived protos
        assert np.asarray(again.sv_).dtype == np.asarray(svm.sv_).dtype
        # concrete-class load checks the manifest
        from repro.estimators import LinearRegression
        with pytest.raises(ValueError, match="CascadeSVM"):
            LinearRegression.load_model(d)
    with pytest.raises(NotFittedError):
        CascadeSVM().save_model("/tmp/never-written")


def test_save_load_model_algorithms_lazy_registry():
    # an algorithms-package estimator resolves through the lazy registry
    from repro.algorithms import KMeans
    from repro.estimators import load_model
    rng = np.random.default_rng(SEED + 13)
    xd = from_array(rng.normal(size=(30, 4)).astype(np.float32), (10, 4))
    km = KMeans(n_clusters=2, max_iter=5, seed=1).fit(xd)
    with tempfile.TemporaryDirectory() as d:
        km.save_model(d)
        back = load_model(d)
        assert type(back) is KMeans
        np.testing.assert_allclose(np.asarray(back.centers_),
                                   np.asarray(km.centers_))
        assert back.n_iter_ == km.n_iter_


def test_fit_checkpoint_wrong_estimator_rejected(tmp_path):
    from repro.estimators.base import _FitCheckpoint
    a = _FitCheckpoint(str(tmp_path), "CascadeSVM")
    a.save(1, {"w": np.ones(3, np.float32), "obj": 1.5})
    with pytest.raises(ValueError, match="CascadeSVM"):
        _FitCheckpoint(str(tmp_path), "ALS").load()
    it, st = a.load()
    assert it == 1 and st["obj"] == 1.5
    assert np.asarray(st["w"]).dtype == np.float32


def test_clean_fit_keeps_plan_cache_regression():
    # the checkpointing machinery must not disturb the hot-loop plan cache:
    # a clean CSVM fit still optimizes its kernel-block plan exactly once
    from repro.estimators import CascadeSVM
    xd, y = _svm_data()
    plan_mod.clear_cache()
    CascadeSVM(max_iter=5, tol=1e-12).fit(xd, y)
    st = plan_mod.cache_stats()
    assert st["opt_runs"] == 1
    assert st["eager_launches"] == 0             # ladder never engaged
    s = R.stats()
    assert s["retries"] == 0 and s["degradations"] == 0
