"""Per-arch smoke tests (reduced configs, CPU): one forward/train step with
shape + NaN asserts, plus decode-vs-teacher-forcing consistency per family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.models import encdec
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def make_inputs(cfg, b=2, s=16):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    patches = None
    if cfg.frontend == "vision":
        patches = jax.random.normal(
            KEY, (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend == "audio":
        patches = jax.random.normal(KEY, (b, 12, cfg.frontend_dim),
                                    jnp.float32)
    return tokens, patches


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    tokens, patches = make_inputs(cfg)
    b, s = tokens.shape

    logits, aux = model.forward(params, tokens, patches)
    t_expect = s + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, t_expect, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()

    loss = model.loss(params, tokens, tokens, patches)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, tokens, tokens, patches))(params)
    gnorm = sum(float(jnp.abs(g).sum())
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", all_arch_ids())
def test_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 12
    tokens, patches = make_inputs(cfg, b, s)

    kw = {}
    if cfg.family == "encdec":
        kw["enc_len"] = patches.shape[1]
    cache = model.init_cache(b, s, **kw)
    if cfg.family == "encdec":
        cache["enc_out"] = encdec.encode(params, cfg, patches)
        ref_logits, _ = model.forward(params, tokens, patches)
    elif cfg.frontend == "vision":
        pytest.skip("vlm decode starts after the patch prefix (prefill path)")
    else:
        ref_logits, _ = model.forward(params, tokens, patches)

    errs = []
    for i in range(s):
        lg, cache = model.decode_step(params, cache, tokens[:, i:i + 1])
        assert lg.shape == (b, 1, cfg.vocab_size)
        assert not np.isnan(np.asarray(lg)).any()
        errs.append(float(np.abs(np.asarray(lg[:, 0])
                                 - np.asarray(ref_logits[:, i])).max()))
    assert max(errs) < 5e-3, max(errs)


@pytest.mark.parametrize("arch", all_arch_ids())
def test_full_config_sanity(arch):
    """FULL configs: divisibility + published param counts (no allocation)."""
    cfg = get_config(arch)
    if cfg.family in ("dense", "moe", "vlm"):
        assert cfg.n_heads % cfg.n_kv_heads == 0
        from repro.models.transformer import group_size
        assert cfg.n_layers % group_size(cfg) == 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_dinner % cfg.ssm_headdim == 0
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.share_period == 0
    n = cfg.param_count()
    published = {
        "llava-next-mistral-7b": 7.3e9, "zamba2-2.7b": 2.7e9,
        "gemma2-2b": 2.6e9, "qwen1.5-0.5b": 0.46e9,
        "nemotron-4-15b": 15e9, "yi-9b": 8.8e9, "grok-1-314b": 314e9,
        "mixtral-8x7b": 46.7e9, "seamless-m4t-medium": 1.2e9,
        "mamba2-370m": 0.37e9,
    }[arch]
    assert 0.6 * published < n < 1.4 * published, (arch, n, published)


def test_moe_routing_properties():
    """Top-k dispatch: combine weights sum to 1; capacity drops are bounded."""
    from repro.models.moe import moe_apply, moe_init
    cfg = get_smoke_config("mixtral-8x7b")
    params = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert not np.isnan(np.asarray(y)).any()
    assert float(aux) > 0.0  # load-balance loss is positive


def test_gemma_local_global_alternation():
    from repro.models.transformer import sublayer_window
    cfg = get_config("gemma2-2b")
    assert sublayer_window(cfg, 0) == 4096  # local
    assert sublayer_window(cfg, 1) == 0     # global
