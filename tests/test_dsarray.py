"""Property + unit tests for the ds-array core (vs NumPy oracle)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (BlockGrid, DsArray, Dataset, eye, from_array,
                        random_array, zeros)
from repro.core import shuffle as sh

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def arr_and_blocks(draw):
    n = draw(st.integers(1, 40))
    m = draw(st.integers(1, 40))
    bn = draw(st.integers(1, 12))
    bm = draw(st.integers(1, 12))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    x = np.random.default_rng(seed).normal(size=(n, m)).astype(np.float32)
    return x, (bn, bm)


shapes = st.builds(lambda d: d, st.composite(arr_and_blocks)())


@st.composite
def case(draw):
    return arr_and_blocks(draw)


@given(case())
def test_roundtrip(data):
    x, bs = data
    a = from_array(x, bs)
    assert np.allclose(np.asarray(a.collect()), x)


@given(case())
def test_transpose(data):
    x, bs = data
    a = from_array(x, bs)
    assert np.allclose(np.asarray(a.T.collect()), x.T)
    # double transpose is identity
    assert np.allclose(np.asarray(a.T.T.collect()), x)


@pytest.mark.slow
@given(case())
def test_elementwise_and_reductions(data):
    x, bs = data
    a = from_array(x, bs)
    assert np.allclose(np.asarray((a + 1.5).collect()), x + 1.5, atol=1e-5)
    assert np.allclose(np.asarray((a * a).collect()), x * x, atol=1e-4)
    assert np.allclose(np.asarray((a ** 2).collect()), x ** 2, atol=1e-4)
    assert np.allclose(np.asarray(a.sum(axis=0).collect()),
                       x.sum(0, keepdims=True), atol=1e-3)
    assert np.allclose(np.asarray(a.sum(axis=1).collect()),
                       x.sum(1).reshape(-1, 1), atol=1e-3)
    assert np.allclose(np.asarray(a.mean(axis=0).collect()),
                       x.mean(0, keepdims=True), atol=1e-4)
    assert np.allclose(np.asarray(a.max(axis=1).collect()),
                       x.max(1).reshape(-1, 1))
    assert np.allclose(np.asarray(a.min(axis=0).collect()),
                       x.min(0, keepdims=True))
    assert np.allclose(float(a.sum()), x.sum(), atol=1e-2)
    assert np.allclose(np.asarray(a.norm(axis=1).collect()).ravel(),
                       np.linalg.norm(x, axis=1), atol=1e-3)


@given(case(), case())
def test_matmul(da, db):
    x, bsa = da
    y, bsb = db
    y = y[: x.shape[1] or 1].copy() if False else y
    # make shapes compatible: use x (n,m) @ x.T (m,n)
    a = from_array(x, bsa)
    b = from_array(x.T, (bsa[1], bsa[0]))
    c = a @ b
    assert np.allclose(np.asarray(c.collect()), x @ x.T, atol=1e-3)


@given(case())
def test_rechunk_preserves(data):
    x, bs = data
    a = from_array(x, bs)
    for nbs in [(1, 1), (5, 3), (x.shape[0], x.shape[1])]:
        assert np.allclose(np.asarray(a.rechunk(nbs).collect()), x)


@given(case())
def test_indexing(data):
    x, bs = data
    a = from_array(x, bs)
    n, m = x.shape
    r0, r1 = 0, max(1, n // 2)
    c0, c1 = 0, max(1, m // 2)
    assert np.allclose(np.asarray(a[r0:r1, c0:c1].collect()), x[r0:r1, c0:c1])
    rows = [i for i in range(0, n, 2)]
    assert np.allclose(np.asarray(a[rows].collect()), x[rows])


def test_matmul_rechunks_incompatible_blocks():
    x = np.random.default_rng(0).normal(size=(10, 12)).astype(np.float32)
    y = np.random.default_rng(1).normal(size=(12, 8)).astype(np.float32)
    a = from_array(x, (4, 5))
    b = from_array(y, (3, 4))  # inner block mismatch -> auto rechunk
    assert np.allclose(np.asarray((a @ b).collect()), x @ y, atol=1e-3)


def test_shape_errors():
    a = from_array(np.ones((4, 4), np.float32), (2, 2))
    b = from_array(np.ones((5, 4), np.float32), (2, 2))
    with pytest.raises(ValueError):
        _ = a @ b
    with pytest.raises(ValueError):
        _ = a + b
    with pytest.raises(ValueError):
        BlockGrid((4, 4), (0, 2))


def test_creation_routines():
    assert np.allclose(np.asarray(eye(10, (3, 3)).collect()), np.eye(10))
    assert np.asarray(zeros((5, 7), (2, 2)).collect()).sum() == 0
    r = random_array(jax.random.PRNGKey(0), (20, 10), (6, 4))
    g = np.asarray(r.collect())
    assert g.shape == (20, 10) and np.isfinite(g).all()
    # pad region must be zero (invariant)
    assert np.asarray(r.blocks).shape == (4, 3, 6, 4)


def test_shuffles_preserve_rows():
    x = np.random.default_rng(0).normal(size=(24, 5)).astype(np.float32)
    a = from_array(x, (6, 5))
    for fn in [sh.pseudo_shuffle, sh.exact_shuffle]:
        s = fn(jax.random.PRNGKey(1), a)
        assert np.allclose(np.sort(np.asarray(s.collect()), axis=0),
                           np.sort(x, axis=0))


def test_paper_expression():
    """The paper's §4.2.3 example: sqrt(norm(w^T, axis=1)^2)."""
    x = np.random.default_rng(0).normal(size=(13, 7)).astype(np.float32)
    w = from_array(x, (4, 3))
    expr = (w.transpose().norm(axis=1) ** 2).sqrt()
    assert np.allclose(np.asarray(expr.collect()).ravel(),
                       np.linalg.norm(x.T, axis=1), atol=1e-4)


def test_jit_composition():
    x = np.random.default_rng(0).normal(size=(16, 16)).astype(np.float32)
    a = from_array(x, (4, 4))

    @jax.jit
    def f(a):
        return ((a @ a.T) + 1.0).sum(axis=0)

    out = f(a)
    ref = (x @ x.T + 1.0).sum(0, keepdims=True)
    assert np.allclose(np.asarray(out.collect()), ref, atol=1e-2)
