"""Lazy expression plans: compute() ≡ eager, fusion/fold on the jaxpr+HLO,
plan-cache behaviour.

Four families of assertions:

* oracle equality — recorded plans must produce exactly what the eager ops
  produce, across dtypes, ragged grids, FILL pads, structural ops, matmul,
  reductions and shuffles (property sweep + fixed cases);
* the ISSUE-3 acceptance: a 6-op elementwise chain under ``repro.lazy()``
  lowers to ONE fused per-block body — single jit launch, ENTRY HLO whose
  only full-grid instructions are the parameter and the root fusion (zero
  intermediate full-grid HBM writes), and ≤1 remask in the trace;
* plan-structure: ``(A.T @ B)`` folds to ``transpose_a`` GEMM (no transpose
  of the input stacked tensor in the jaxpr; pallas_call when forced),
  sibling reductions share one operand evaluation;
* cache: structurally-identical plans on fresh data hit the compiled-plan
  cache; different structure misses.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.core import DsArray, concat_rows, from_array, plan
from repro.core import expr as expr_mod
from repro.core.expr import MatMul

settings.register_profile("lazy", max_examples=10, deadline=None)
settings.load_profile("lazy")

RNG = np.random.default_rng(23)


def mk(n=13, m=9, bn=4, bm=3, dtype=np.float32, shift=1.0):
    x = (RNG.normal(size=(n, m)) * 2 + shift)
    if np.issubdtype(np.dtype(dtype), np.integer):
        x = np.round(x * 10)
    x = x.astype(dtype)
    return x, from_array(x, (bn, bm))


def assert_claim_holds(a: DsArray, label=""):
    gn, gm, bn, bm = a.blocks.shape
    g = np.asarray(a.blocks, np.float64).transpose(0, 2, 1, 3)
    g = g.reshape(gn * bn, gm * bm)
    n, m = a.shape
    pad = np.concatenate([g[n:].ravel(), g[:n, m:].ravel()])
    if a.pad_state.kind == "zero":
        assert (pad == 0).all(), (label, a.pad_state)
    elif a.pad_state.kind == "fill":
        assert (pad == float(a.pad_state.fill)).all(), (label, a.pad_state)


# ---------------------------------------------------------------------------
# jaxpr / HLO helpers
# ---------------------------------------------------------------------------


# canonical versions live in repro.analysis (the analyzer's jaxpr plane):
# the tests and the lint rules share one traversal by construction
from repro.analysis import (  # noqa: E402
    count_selects as _count_selects,
    entry_full_grid_defs as _entry_full_grid_defs,
    jaxpr_primitives as _primitives,
    walk_eqns as _walk_eqns,
)


# ---------------------------------------------------------------------------
# Oracle equality
# ---------------------------------------------------------------------------


def test_chain_matches_eager_and_numpy():
    x, a = mk()
    y, b = mk()
    with repro.lazy():
        r = (((a + b) * 2.0 - b).abs() * 0.5 + 0.25)
    eager = (((a + b) * 2.0 - b).abs() * 0.5 + 0.25)
    out = r.compute()
    np.testing.assert_allclose(np.asarray(out.collect()),
                               np.asarray(eager.collect()), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out.collect()),
                               np.abs((x + y) * 2.0 - y) * 0.5 + 0.25,
                               rtol=1e-5)
    # plan-level pad propagation matches the eager claim, and it holds
    assert out.pad_state == eager.pad_state
    assert_claim_holds(out, "chain")


_FLOAT_OPS = {
    "add_s": lambda t, o: t + 1.5,
    "mul_s": lambda t, o: t * 2.0,
    "sub_b": lambda t, o: t - o,
    "add_b": lambda t, o: t + o,
    "rsub": lambda t, o: 3.0 - t,
    "neg": lambda t, o: -t,
    "abs": lambda t, o: t.abs(),
    "sqrt_abs": lambda t, o: t.abs().sqrt(),
    "div_s": lambda t, o: t / 2.0,
}

_INT_OPS = {
    "add_s": lambda t, o: t + 2,
    "mul_s": lambda t, o: t * 3,
    "sub_b": lambda t, o: t - o,
    "add_b": lambda t, o: t + o,
    "neg": lambda t, o: -t,
    "abs": lambda t, o: t.abs(),
}


@pytest.mark.slow
@given(st.integers(1, 40), st.integers(1, 17), st.integers(1, 8),
       st.integers(1, 8), st.sampled_from([np.float32, np.int32]),
       st.lists(st.sampled_from(sorted(_FLOAT_OPS)), min_size=1, max_size=6))
def test_property_lazy_equals_eager(n, m, bn, bm, dtype, op_names):
    ops = _FLOAT_OPS if dtype == np.float32 else _INT_OPS
    op_names = [o for o in op_names if o in ops] or ["add_s"]
    _, a = mk(n, m, bn, bm, dtype)
    _, b = mk(n, m, bn, bm, dtype)

    def chain(t, o):
        for name in op_names:
            t = ops[name](t, o)
        return t

    eager = chain(a, b)
    with repro.lazy():
        lazy_r = chain(a, b)
    out = lazy_r.compute()
    assert out.shape == eager.shape and out.block_shape == eager.block_shape
    np.testing.assert_allclose(np.asarray(out.collect()),
                               np.asarray(eager.collect()),
                               rtol=1e-5, atol=1e-5, err_msg=str(op_names))
    assert out.pad_state == eager.pad_state, op_names
    assert_claim_holds(out, str(op_names))


def test_structural_ops_lazy_equivalence():
    x, a = mk(17, 13, 4, 3)
    y, b = mk(17, 13, 4, 3)
    builders = {
        "transpose": lambda: (a + 1.0).T,
        "slice": lambda: (a * 2.0)[2:9, 1:7],
        "filter": lambda: a[[0, 5, 12, 3]],
        "rechunk": lambda: (a + b).rechunk((5, 2)),
        "concat": lambda: concat_rows([a, b]),
        "astype": lambda: (a * 2.5).astype(jnp.int32),
        "matmul": lambda: (a + 1.0) @ (b.T + 2.0),
        "mean0": lambda: a.mean(axis=0),
        "sum1": lambda: (a + 1.0).sum(axis=1),
        "max": lambda: a.max(axis=0),
        "norm1": lambda: a.norm(axis=1),
    }
    for label, build in builders.items():
        with repro.lazy():
            lazy_r = build()
        out = lazy_r.compute()
        want = build()                         # same expression, eager
        np.testing.assert_allclose(np.asarray(out.collect()),
                                   np.asarray(want.collect()),
                                   rtol=1e-4, atol=1e-4, err_msg=label)
        assert_claim_holds(out, label)


def test_scalar_reductions_and_mean():
    x, a = mk(11, 7, 3, 3)
    with repro.lazy():
        s = (a * a).sum()
        nrm = a.norm()
        mn = a.mean()
    assert float(s.compute()) == pytest.approx(float((a * a).sum()), rel=1e-5)
    assert float(nrm.compute()) == pytest.approx(float(a.norm()), rel=1e-5)
    assert float(mn.compute()) == pytest.approx(float(a.mean()), rel=1e-5)
    # integer mean promotes before summing, lazily too
    xi, ai = mk(9, 5, 4, 2, np.int32)
    with repro.lazy():
        mi = ai.mean(axis=0)
    np.testing.assert_allclose(np.asarray(mi.compute().collect()),
                               np.asarray(ai.mean(axis=0).collect()),
                               rtol=1e-6)


def test_lazy_shuffles_match_eager():
    from repro.core import exact_shuffle, pseudo_shuffle
    x, a = mk(16, 6, 4, 3)
    key = jax.random.PRNGKey(7)
    for fn in (exact_shuffle, pseudo_shuffle):
        with repro.lazy():
            lz = fn(key, a)
        np.testing.assert_allclose(np.asarray(lz.compute().collect()),
                                   np.asarray(fn(key, a).collect()))


def test_dsarray_interop_without_flag():
    """DsArray ∘ LazyDsArray records via the reflected ops (no context)."""
    x, a = mk()
    y, b = mk()
    r = a - b.lazy()           # DsArray.__sub__ -> NotImplemented -> __rsub__
    assert isinstance(r, expr_mod.LazyDsArray)
    np.testing.assert_allclose(np.asarray(r.compute().collect()), x - y,
                               rtol=1e-6, atol=1e-6)
    r2 = a @ b.lazy().T
    np.testing.assert_allclose(np.asarray(r2.compute().collect()), x @ y.T,
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# The acceptance assertion: 6-op chain -> one fused body
# ---------------------------------------------------------------------------


def test_six_op_chain_single_fused_body():
    _, a = mk(64, 48, 8, 8)
    with repro.lazy():
        r = (((a + a) * 2.0 - a).abs() * 0.5 + 0.25)  # add,mul,sub,abs,mul,add
    p = plan.plan_for(r)
    # whole chain fused: one Blockwise over one leaf
    assert p.stats["nodes_after"] == 2, p.stats           # leaf + fused node
    assert p.stats["fused_elementwise"] == 5, p.stats     # 6 ops -> 1 node
    jx = p.jaxpr()
    # one jit body: every elementwise primitive inline, no nested calls
    prims = _primitives(jx)
    assert "pjit" not in prims and "custom_jvp_call" not in prims, prims
    # ≤1 remask in the trace (this chain ends FILL-padded: bookkeeping only)
    assert _count_selects(jx) <= 1
    # zero intermediate full-grid HBM writes in the optimized HLO: the grid
    # shape appears only as the parameter and the ROOT fusion
    txt = p.lowered().compile().as_text()
    bad = _entry_full_grid_defs(txt, a.blocks.shape)
    assert not bad, bad
    # ...and executing it is exactly one plan launch
    before = plan.cache_stats()["launches"]
    r.compute()
    assert plan.cache_stats()["launches"] == before + 1


def test_zero_preserving_chain_into_reduce_no_remask():
    _, a = mk(64, 48, 8, 8)
    with repro.lazy():
        r = (-((a + a) * 2.0).abs()).sum()
    jx = plan.plan_for(r).jaxpr()
    assert _count_selects(jx) == 0
    # FILL chain into a 0-identity reduce pays exactly the one deferred pass
    with repro.lazy():
        r2 = ((a + 1.0) * 2.0 + 3.0).sum()
    assert _count_selects(plan.plan_for(r2).jaxpr()) == 1


# ---------------------------------------------------------------------------
# Transpose folding + sibling reductions
# ---------------------------------------------------------------------------


def test_matmul_transpose_folded(monkeypatch):
    x, a = mk(24, 16, 8, 8)
    y, b = mk(24, 32, 8, 8)
    with repro.lazy():
        r = a.T @ b
    p = plan.plan_for(r)
    root = p.roots[0]
    assert isinstance(root, MatMul) and root.transpose_a
    # the input stacked tensor is never transposed in the folded plan
    jx = p.jaxpr()
    in_shape = a.blocks.shape
    input_transposes = [e for e in _walk_eqns(jx)
                        if e.primitive.name == "transpose"
                        and tuple(e.invars[0].aval.shape) == in_shape]
    assert not input_transposes
    np.testing.assert_allclose(np.asarray(r.compute().collect()), x.T @ y,
                               rtol=1e-4, atol=1e-4)
    # ...and it still lowers through the Pallas kernel when forced
    monkeypatch.setenv("REPRO_GEMM", "interpret")
    with repro.lazy():
        r2 = a.T @ b
    assert "pallas_call" in _primitives(plan.plan_for(r2).jaxpr())
    np.testing.assert_allclose(np.asarray(r2.compute().collect()), x.T @ y,
                               rtol=1e-3, atol=1e-3)


def test_transpose_hoisted_through_elementwise():
    """(a.T * 2 + b.T) fuses below a single hoisted transpose, so the
    elementwise work still collapses to one node."""
    x, a = mk(12, 8, 4, 4)
    y, b = mk(12, 8, 4, 4)
    with repro.lazy():
        r = a.T * 2.0 + b.T
    p = plan.plan_for(r)
    kinds = [type(n).__name__ for n in p.roots]
    assert kinds == ["Transpose"], (kinds, p.stats)
    np.testing.assert_allclose(np.asarray(r.compute().collect()),
                               (x * 2.0 + y).T, rtol=1e-5)


def test_transpose_not_hoisted_over_position_dependent_map():
    """A position-dependent map_blocks fn does NOT commute with transpose:
    the hoist rule must not fire (user fns are not marked elementwise)."""
    from repro.core.dsarray import PAD_DIRTY
    x, a = mk(5, 4, 2, 2)
    fn = lambda b: b * jnp.arange(b.shape[-1], dtype=b.dtype)  # noqa: E731
    eager = a.T.map_blocks(fn, pad=PAD_DIRTY)
    with repro.lazy():
        lz = a.T.map_blocks(fn, pad=PAD_DIRTY)
    np.testing.assert_allclose(np.asarray(lz.compute().collect()),
                               np.asarray(eager.collect()), rtol=1e-6)


def test_explicit_dirty_pad_survives_plan_rewrites():
    """pad=PAD_DIRTY on a position-dependent map_blocks must not be replaced
    by a (wrong) probe during rebuild/fusion — the consuming reduction still
    has to refill the pad region."""
    from repro.core.dsarray import PAD_DIRTY
    x, a = mk(5, 4, 2, 2)
    fn = lambda b: b + jax.lax.broadcasted_iota(b.dtype, b.shape, 2)  # noqa: E731
    eager = float(a.map_blocks(fn, pad=PAD_DIRTY).sum())
    with repro.lazy():
        s = a.map_blocks(fn, pad=PAD_DIRTY).sum()
    assert float(s.compute()) == pytest.approx(eager, rel=1e-6)


def test_plan_cache_is_bounded(monkeypatch):
    plan.clear_cache()
    monkeypatch.setattr(plan, "_CACHE_MAX", 8)
    _, a = mk(8, 8, 4, 4)
    for i in range(12):
        with repro.lazy():
            r = (a.map_blocks(lambda b: b * 1.0) + float(i)).sum()
        r.compute()     # fresh lambda per iteration: every plan is a miss
    assert len(plan._CACHE) <= 8
    assert plan.cache_stats()["misses"] == 12


def test_sibling_reductions_share_operand():
    _, a = mk(32, 24, 8, 8)
    with repro.lazy():
        c = (a * 2.0 + 1.0)
        s0, m0 = c.sum(axis=0), c.max(axis=0)
    p = plan.plan_for(s0, m0)
    r1, r2 = p.roots
    assert r1.children[0] is r2.children[0]      # CSE: one shared operand
    got_s, got_m = plan.compute_multi(s0, m0)
    eager_c = (a * 2.0 + 1.0)
    np.testing.assert_allclose(np.asarray(got_s.collect()),
                               np.asarray(eager_c.sum(axis=0).collect()),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got_m.collect()),
                               np.asarray(eager_c.max(axis=0).collect()),
                               rtol=1e-5)
    # identical duplicate reductions collapse to ONE root computation
    with repro.lazy():
        d1, d2 = c.sum(axis=0), c.sum(axis=0)
    pd = plan.plan_for(d1, d2)
    assert pd.roots[0] is pd.roots[1]


# ---------------------------------------------------------------------------
# Compiled-plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_hits_on_fresh_data():
    plan.clear_cache()
    for i in range(3):
        _, a = mk(16, 12, 4, 4, shift=float(i))
        with repro.lazy():
            r = ((a + 1.0) * 2.0).sum(axis=0)
        r.compute()
    st_ = plan.cache_stats()
    assert st_["misses"] == 1 and st_["hits"] == 2, st_
    # different structure (extra op) is a new plan
    _, a = mk(16, 12, 4, 4)
    with repro.lazy():
        r = ((a + 1.0) * 2.0 + 3.0).sum(axis=0)
    r.compute()
    st_ = plan.cache_stats()
    assert st_["misses"] == 2, st_
    # different scalar constant is a different plan (constants are baked)
    with repro.lazy():
        r = ((a + 1.0) * 5.0).sum(axis=0)
    r.compute()
    assert plan.cache_stats()["misses"] == 3
    # different leaf geometry is a different plan
    _, a2 = mk(16, 12, 8, 4)
    with repro.lazy():
        r = ((a2 + 1.0) * 2.0).sum(axis=0)
    r.compute()
    assert plan.cache_stats()["misses"] == 4


def test_scalar_dtype_in_plan_key():
    """`a + 1` and `a + 1.0` are DIFFERENT plans: tuple keys hash 1 == 1.0,
    so the baked scalar's dtype must be part of the key or an int32 cached
    plan would answer the float recording."""
    plan.clear_cache()
    xi, ai = mk(8, 6, 4, 3, np.int32)
    with repro.lazy():
        ri = ai + 1
    with repro.lazy():
        rf = ai + 1.0
    out_i, out_f = ri.compute(), rf.compute()
    assert out_i.dtype == jnp.int32
    assert jnp.issubdtype(out_f.dtype, jnp.floating), out_f.dtype
    assert plan.cache_stats()["misses"] == 2


def test_optimizer_runs_once_across_recorded_hot_loop():
    """ROADMAP "lazy recording overhead": re-recording a structurally
    unchanged DAG must skip plan re-canonicalization — across a 10-iteration
    hot loop (the PCA power-iteration shape) the optimizer runs ONCE
    (counter-based) and the cached path lowers to the identical jaxpr."""
    plan.clear_cache()
    _, a = mk(24, 16, 8, 8)
    xl = a.lazy()
    q0 = np.asarray(RNG.normal(size=(16, 4)), np.float32)
    outs = []
    for i in range(10):
        qd = from_array(q0 + i, (8, 4))
        outs.append((xl.T @ (xl @ qd)).compute())
    st_ = plan.cache_stats()
    assert st_["opt_runs"] == 1, st_
    assert st_["opt_skips"] == 9, st_
    assert st_["misses"] == 1 and st_["hits"] == 9, st_
    # values stay right on the cached path (fresh leaf data each iteration)
    for i, out in enumerate(outs):
        want = np.asarray(a.collect()).T @ (np.asarray(a.collect()) @ (q0 + i))
        np.testing.assert_allclose(np.asarray(out.collect()), want,
                                   rtol=1e-3, atol=1e-3)
    # the jaxpr of a skipped-optimization plan is unchanged vs a fresh one
    # (same recording shape as the loop body: the shared xl leaf)
    r1 = xl.T @ (xl @ from_array(q0, (8, 4)))
    cached_plan = plan.plan_for(r1)          # optimizer-cache hit
    assert plan.cache_stats()["opt_skips"] == 10
    plan.clear_cache()
    r2 = xl.T @ (xl @ from_array(q0, (8, 4)))
    fresh_plan = plan.plan_for(r2)           # forced fresh optimization
    assert str(cached_plan.jaxpr()) == str(fresh_plan.jaxpr())


def test_optimizer_cache_distinguishes_leaf_aliasing():
    """`c + c` (one array used twice) and `c + d` (two equal-signature
    arrays) have the same node skeleton but different CSE outcomes — the
    pre-optimization key must separate them."""
    plan.clear_cache()
    _, c = mk(8, 6, 4, 3)
    _, d = mk(8, 6, 4, 3)
    with repro.lazy():
        r1 = c + c
    out1 = r1.compute()
    with repro.lazy():
        r2 = c + d
    out2 = r2.compute()
    st_ = plan.cache_stats()
    assert st_["opt_runs"] == 2, st_     # different aliasing: no false hit
    np.testing.assert_allclose(np.asarray(out1.collect()),
                               2 * np.asarray(c.collect()), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out2.collect()),
        np.asarray(c.collect()) + np.asarray(d.collect()), rtol=1e-6)


def test_lazy_mode_is_scoped_and_reentrant():
    _, a = mk()
    assert isinstance(a + 1.0, DsArray)
    with repro.lazy():
        with repro.lazy():
            assert isinstance(a + 1.0, expr_mod.LazyDsArray)
        assert isinstance(a + 1.0, expr_mod.LazyDsArray)
    assert isinstance(a + 1.0, DsArray)
