"""Fused stacked-block GEMM: kernel-vs-einsum oracle + lowering assertions.

Three families:

* property sweep: ``stacked_matmul`` (interpret mode) must match the stacked
  ``jnp.einsum`` reference across ragged grid/block shapes and dtypes,
  including the sub-tiling path;
* dispatcher policy: ``local_matmul``/``DsArray.__matmul__`` lower through
  the Pallas kernel when the backend is forced (``REPRO_GEMM=interpret``
  stands in for TPU on this CPU CI) and through einsum otherwise —
  asserted on the jaxpr;
* end-to-end: ds-array ``@`` through the kernel matches NumPy on ragged
  logical shapes (pad blocks contract exactly).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DsArray, from_array
from repro.kernels.matmul.kernel import stacked_matmul
from repro.kernels.matmul.ops import gemm_backend, local_matmul

settings.register_profile("gemm", max_examples=10, deadline=None)
settings.load_profile("gemm")

RNG = np.random.default_rng(3)


def _einsum_ref(a, b):
    return np.einsum("ikab,kjbc->ijac", np.asarray(a, np.float64),
                     np.asarray(b, np.float64))


@pytest.mark.slow
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
       st.integers(1, 9), st.integers(1, 9), st.integers(1, 9),
       st.sampled_from([np.float32, np.float16]))
def test_stacked_matmul_sweep(gi, gk, gj, bn, bk, bm, dtype):
    a = RNG.normal(size=(gi, gk, bn, bk)).astype(dtype)
    b = RNG.normal(size=(gk, gj, bk, bm)).astype(dtype)
    out = stacked_matmul(jnp.asarray(a), jnp.asarray(b), interpret=True)
    assert out.shape == (gi, gj, bn, bm)
    tol = 1e-4 * bk if dtype == np.float32 else 3e-2 * bk
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               _einsum_ref(a, b), atol=tol, rtol=tol)


@pytest.mark.parametrize("tiles", [(4, 4, 4), (8, 4, 2), (2, 8, 8)])
def test_stacked_matmul_subtiling(tiles):
    """block dims > tile targets split into Pallas grid steps when they divide."""
    tm, tn, tk = tiles
    a = RNG.normal(size=(2, 3, 8, 8)).astype(np.float32)
    b = RNG.normal(size=(3, 2, 8, 8)).astype(np.float32)
    out = stacked_matmul(jnp.asarray(a), jnp.asarray(b), block_m=tm,
                         block_n=tn, block_k=tk, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               _einsum_ref(a, b), atol=1e-3, rtol=1e-3)


def test_local_matmul_backends_agree():
    a = RNG.normal(size=(2, 2, 5, 7)).astype(np.float32)
    b = RNG.normal(size=(2, 3, 7, 4)).astype(np.float32)
    e = local_matmul(jnp.asarray(a), jnp.asarray(b), backend="einsum")
    p = local_matmul(jnp.asarray(a), jnp.asarray(b), backend="interpret")
    np.testing.assert_allclose(np.asarray(e), np.asarray(p), atol=1e-4)


def test_local_matmul_transpose_a_folded():
    """Aᵀ@B with ``a`` in UNtransposed layout (gk, gi, bk, bn): both
    backends must match the explicitly-transposed einsum reference."""
    a = RNG.normal(size=(3, 2, 8, 8)).astype(np.float32)   # A: (24, 16)
    b = RNG.normal(size=(3, 4, 8, 8)).astype(np.float32)   # B: (24, 32)
    at = np.transpose(a, (1, 0, 3, 2))                     # stacked Aᵀ
    want = _einsum_ref(at, b)
    for backend in ("einsum", "interpret"):
        out = local_matmul(jnp.asarray(a), jnp.asarray(b), backend=backend,
                           transpose_a=True)
        assert out.shape == (2, 4, 8, 8)
        np.testing.assert_allclose(np.asarray(out, np.float64), want,
                                   atol=1e-3, rtol=1e-3)


def test_matmul_ta_matches_dense():
    """The eager ``matmul_ta`` helper on ragged shapes + mixed blocks."""
    from repro.core import matmul_ta
    x = RNG.normal(size=(37, 21)).astype(np.float32)
    y = RNG.normal(size=(37, 18)).astype(np.float32)
    a = from_array(x, (8, 8))
    b = from_array(y, (5, 6))          # mismatched row blocks -> rechunk
    c = matmul_ta(a, b)
    assert c.shape == (21, 18)
    np.testing.assert_allclose(np.asarray(c.collect()), x.T @ y,
                               atol=2e-3, rtol=1e-3)
    assert c.pad_state.kind == "zero"


def test_gemm_backend_policy(monkeypatch):
    monkeypatch.delenv("REPRO_GEMM", raising=False)
    # off-TPU auto -> einsum, whatever the shapes
    assert gemm_backend(128, 128, 128, jnp.dtype(jnp.float32)) == "einsum"
    # forcing wins over auto
    monkeypatch.setenv("REPRO_GEMM", "interpret")
    assert gemm_backend(3, 5, 7, jnp.dtype(jnp.float32)) == "interpret"
    assert gemm_backend(3, 5, 7, jnp.dtype(jnp.float32),
                        backend="einsum") == "einsum"


# ---------------------------------------------------------------------------
# Lowering assertions: walk the jaxpr for the pallas_call primitive
# (canonical traversal lives in repro.analysis)
# ---------------------------------------------------------------------------

from repro.analysis import jaxpr_primitives as _primitives  # noqa: E402


def test_dsarray_matmul_lowers_through_pallas(monkeypatch):
    """The acceptance assertion: ds-array ``@`` hits the Pallas kernel when
    the MXU path is selected (here forced via interpret), and the einsum
    fallback contains no pallas_call."""
    x = RNG.normal(size=(24, 16)).astype(np.float32)
    a = from_array(x, (8, 8))

    def make_mm():
        # fresh function object per trace: jax caches traces by (fn, avals),
        # which would otherwise hide the env-var backend switch
        return lambda p, q: (DsArray(p, a.grid)
                             @ DsArray(q, a.grid).transpose()).blocks

    monkeypatch.setenv("REPRO_GEMM", "interpret")
    assert "pallas_call" in _primitives(
        jax.make_jaxpr(make_mm())(a.blocks, a.blocks))
    got = np.asarray((a @ from_array(x.T, (8, 8))).collect())
    np.testing.assert_allclose(got, x @ x.T, atol=1e-3)

    monkeypatch.setenv("REPRO_GEMM", "einsum")
    assert "pallas_call" not in _primitives(
        jax.make_jaxpr(make_mm())(a.blocks, a.blocks))


def test_dsarray_matmul_ragged_through_kernel(monkeypatch):
    """Ragged logical shapes: pad blocks contract exactly through the kernel."""
    monkeypatch.setenv("REPRO_GEMM", "interpret")
    x = RNG.normal(size=(37, 29)).astype(np.float32)
    y = RNG.normal(size=(29, 17)).astype(np.float32)
    c = from_array(x, (8, 8)) @ from_array(y, (8, 5))
    np.testing.assert_allclose(np.asarray(c.collect()), x @ y, atol=2e-3)
    # pad region of the product is exactly zero (claimed ZERO)
    assert c.pad_state.kind == "zero"
    gn, gm, bn, bm = c.blocks.shape
    g = np.asarray(c.blocks).transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)
    assert (g[37:] == 0).all() and (g[:, 17:] == 0).all()


def test_summa_local_gemm_fused(monkeypatch):
    """The shmap local GEMM goes through the same dispatcher (no per-grid-k
    Python loop): one pallas_call for the whole stacked contraction."""
    from repro.core.shmap_ops import _local_gemm
    monkeypatch.setenv("REPRO_GEMM", "interpret")
    a = jnp.asarray(RNG.normal(size=(2, 4, 8, 8)).astype(np.float32))
    b = jnp.asarray(RNG.normal(size=(4, 2, 8, 8)).astype(np.float32))
    jaxpr = jax.make_jaxpr(lambda p, q: _local_gemm(p, q))(a, b)
    prims = _primitives(jaxpr)
    assert "pallas_call" in prims
    np.testing.assert_allclose(np.asarray(_local_gemm(a, b)),
                               _einsum_ref(a, b), atol=1e-3, rtol=1e-3)
