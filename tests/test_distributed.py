"""Multi-device distribution tests.

These need >1 device, so each runs in a SUBPROCESS with
``--xla_force_host_platform_device_count`` (the main pytest process keeps 1
CPU device per the dry-run isolation rule).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(SRC), os.path.abspath(ROOT),
         env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_shmap_collective_ops():
    out = run_subprocess("""
        import numpy as np, jax
        from repro.core import from_array
        from repro.core.compat import make_mesh
        from repro.core.shmap_ops import (summa_matmul, cannon_matmul,
                                          transpose_pp, colsum_psum)
        mesh = make_mesh((2, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        x = rng.normal(size=(32, 48)).astype(np.float32)
        y = rng.normal(size=(48, 24)).astype(np.float32)
        A, B = from_array(x, (8, 8)), from_array(y, (8, 8))
        with mesh:
            assert np.allclose(summa_matmul(A, B, mesh).collect(), x @ y, atol=1e-3)
            assert np.allclose(cannon_matmul(A, B, mesh).collect(), x @ y, atol=1e-3)
            assert np.allclose(transpose_pp(A, mesh).collect(), x.T)
            assert np.allclose(colsum_psum(A, mesh).collect(),
                               x.sum(0, keepdims=True), atol=1e-3)
            # FILL-pad operands: matmul must re-zero, transpose must carry
            # the pad state (regression: a dropped state let reductions skip
            # the refill and count pad cells)
            Af, Bf = A + 1.0, B - 2.0
            assert np.allclose(summa_matmul(Af, Bf, mesh).collect(),
                               (x + 1) @ (y - 2), atol=1e-3)
            t = transpose_pp(Af, mesh)
            assert t.pad_state == Af.pad_state
            assert abs(float(t.sum()) - (x + 1).sum()) < 1e-2
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_compressed_psum_unbiased():
    out = run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.compat import make_mesh, shard_map
        from repro.distributed import compressed_psum
        mesh = make_mesh((4,), ("pod",))
        x = np.random.default_rng(0).normal(size=(4, 64)).astype(np.float32)

        def body(xs, key):
            return compressed_psum(xs[0], "pod", key[0], 4)

        f = shard_map(body, mesh=mesh, in_specs=(P("pod", None), P("pod")),
                      out_specs=P(None), check_vma=False)
        keys = jax.random.split(jax.random.PRNGKey(0), 4)
        errs = []
        for trial in range(5):
            keys = jax.random.split(jax.random.PRNGKey(trial), 4)
            got = np.asarray(f(jnp.asarray(x), keys))
            errs.append(got - x.sum(0))
        err = np.stack(errs)
        scale = np.abs(x.sum(0)).max()
        assert np.abs(err).max() < 0.1 * scale + 0.2, np.abs(err).max()
        # stochastic rounding -> near-zero mean error across trials
        assert abs(err.mean()) < 0.05 * scale
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_sharded_train_step_runs_and_matches():
    """Distributed train step on a 2x2 mesh == single-device step (loss)."""
    out = run_subprocess("""
        import numpy as np, jax
        from repro.configs import get_smoke_config
        from repro.models.model import build_model
        from repro.models import common as cm
        from repro.optim import make_optimizer
        from repro.train.step import init_state, make_train_step
        from repro.data import SyntheticPipeline, PipelineConfig
        from repro.distributed import sharding as shlib

        cfg = get_smoke_config("yi-9b")
        model = build_model(cfg)
        opt = make_optimizer("adamw", peak_lr=1e-3)
        pipe = SyntheticPipeline(PipelineConfig(global_batch=8, seq_len=16,
                                                vocab_size=cfg.vocab_size))
        batch = pipe.batch_at(0)
        state = init_state(model, opt, jax.random.PRNGKey(0))

        # single-device reference
        _, m_ref = make_train_step(model, opt)(state, batch)

        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        env = cm.ShardEnv(mesh=mesh, dp=("data",), tp="model")
        ps = shlib.param_shardings(state.params, mesh)
        osh = shlib.opt_state_shardings(state.opt_state, state.params, mesh)
        from repro.train.step import TrainState
        ss = TrainState(params=ps, opt_state=osh)
        step = jax.jit(make_train_step(model, opt, env),
                       in_shardings=(ss, shlib.to_shardings(
                           shlib.batch_specs(batch, mesh, ("data",)), mesh)),
                       out_shardings=(ss, None))
        with mesh:
            state2, m = step(state, batch)
        assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-2, (
            float(m["loss"]), float(m_ref["loss"]))
        print("OK", float(m["loss"]))
    """, devices=4)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a 4-device mesh, restore onto a 2-device mesh."""
    out = run_subprocess("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save, restore
        from repro.core.compat import make_mesh
        mesh4 = make_mesh((4,), ("data",))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh4, P("data", None)))
        with tempfile.TemporaryDirectory() as d:
            save(d, 0, {"x": xs})
            mesh2 = make_mesh((2,), ("data",), devices=jax.devices()[:2])
            sh = {"x": NamedSharding(mesh2, P(None, "data"))}
            out = restore(d, 0, {"x": jnp.zeros((8, 8))}, sh)
            assert np.allclose(np.asarray(out["x"]), np.asarray(x))
            assert out["x"].sharding.spec == P(None, "data")
        print("OK")
    """, devices=4)
    assert "OK" in out


def test_sharding_rules_sanitize():
    from jax.sharding import PartitionSpec as P
    from repro.core.compat import make_mesh
    from repro.distributed.sharding import sanitize_spec
    mesh = make_mesh((1,), ("model",))
    # 7 not divisible by any mesh>1 — with size-1 mesh everything divides
    assert sanitize_spec(P("model", None), (7, 3), mesh) == P("model", None)


def test_structural_ops_preserve_sharding():
    """Block-native slice/rechunk/concat keep blocks on the mesh they lived on
    (the seed materialize path silently collapsed to single-device)."""
    out = run_subprocess("""
        import numpy as np, jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import concat_rows, from_array
        from repro.core.compat import make_mesh

        mesh = make_mesh((2, 2), ("data", "model"))
        x = np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32)
        A = from_array(x, (8, 8)).distribute(mesh)
        want = NamedSharding(mesh, P("data", "model", None, None))
        assert A.blocks.sharding == want

        s = A[16:48, 0:32]                       # block-aligned grid slice
        assert np.allclose(np.asarray(s.collect()), x[16:48, 0:32])
        assert s.blocks.sharding == want, s.blocks.sharding

        r = A.rechunk((4, 4))                    # evenly-dividing regroup
        assert np.allclose(np.asarray(r.collect()), x)
        assert r.blocks.sharding == want, r.blocks.sharding

        c = concat_rows([A, A])                  # grid stack
        assert np.allclose(np.asarray(c.collect()),
                           np.concatenate([x, x], axis=0))
        assert c.blocks.sharding == want, c.blocks.sharding

        f = A[np.arange(1, 64, 2)]               # gather filtering
        assert np.allclose(np.asarray(f.collect()), x[1::2])
        assert f.blocks.sharding == want, f.blocks.sharding
        print("OK")
    """, devices=4)
    assert "OK" in out
