"""Pad-state tracking: claim soundness + remask elision on the jaxpr.

Two families of assertions:

* soundness — for every public op, the static claim must hold on the actual
  pad region: ``pad_state == ZERO`` ⇒ pad exactly 0, ``FILL(v)`` ⇒ pad
  exactly v (DIRTY claims nothing);
* elision — an eager chain of 4 zero-preserving elementwise ops must emit
  at most 1 mask/select pass (the seed emitted one per op), reductions on
  identity-pad inputs emit none, and a non-identity pad costs exactly one
  deferred pass at the consumer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DsArray, concat_rows, eye, from_array, full,
                        pseudo_shuffle, random_array, zeros)
from repro.core.dsarray import PAD_DIRTY, PAD_ZERO

RNG = np.random.default_rng(11)


def mk(n=13, m=9, bn=4, bm=3, shift=1.5):
    x = (RNG.normal(size=(n, m)) + shift).astype(np.float32)
    return x, from_array(x, (bn, bm))


def assert_claim_holds(a: DsArray, label=""):
    """pad_state == ZERO ⇒ pad region actually zero; FILL(v) ⇒ actually v."""
    gn, gm, bn, bm = a.blocks.shape
    g = np.asarray(a.blocks, np.float64).transpose(0, 2, 1, 3)
    g = g.reshape(gn * bn, gm * bm)
    n, m = a.shape
    pad = np.concatenate([g[n:].ravel(), g[:n, m:].ravel()])
    if a.pad_state.kind == "zero":
        assert (pad == 0).all(), (label, a.pad_state, pad)
    elif a.pad_state.kind == "fill":
        assert (pad == float(a.pad_state.fill)).all(), (label, a.pad_state, pad)
    # dirty claims nothing


def test_every_public_op_keeps_its_claim():
    x, a = mk()
    y, b = mk()
    idx = [0, 5, 12, 3]
    cases = {
        "from_array": a,
        "add_ds": a + b,
        "add_scalar": a + 1.5,          # FILL(1.5), no remask
        "sub": a - b,
        "rsub": 2.0 - a,
        "mul": a * b,
        "mul_scalar": a * 3.0,
        "div_scalar": a / 2.0,
        "rdiv": 3.0 / a,                # pad 3/0 = inf -> FILL(inf)
        "pow": a ** 2,
        "rpow": 2.0 ** a,               # FILL(1)
        "neg": -a,
        "sqrt": a.abs().sqrt(),
        "exp": a.exp(),                 # FILL(1)
        "abs": a.abs(),
        "astype": (a + 1.0).astype(jnp.int32),
        "transpose": (a + 1.0).T,
        "sum0": a.sum(axis=0),
        "sum1": (a + 1.0).sum(axis=1),  # deferred remask at the reduction
        "max1": a.max(axis=1),          # FILL(-inf) result pad
        "min0": a.min(axis=0),
        "mean0": a.mean(axis=0),
        "norm1": a.norm(axis=1),
        "slice": (a + 1.0)[2:9, 1:7],
        "filter": (a + 1.0)[idx],
        "rechunk": (a + 1.0).rechunk((5, 2)),
        "concat": concat_rows([a + 1.0, b]),
        "matmul": (a + 1.0) @ (b.T + 2.0),
        "map_blocks": a.map_blocks(lambda t: t * 2.0 + 1.0),   # FILL(1)
        "shuffle": pseudo_shuffle(jax.random.PRNGKey(0),
                                  from_array(x[:12], (4, 3)) + 1.0),
        "zeros": zeros((7, 5), (3, 3)),
        "full": full((7, 5), (3, 3), 4.5),
        "eye": eye(7, (3, 3)),
        "random": random_array(jax.random.PRNGKey(1), (11, 6), (4, 4)),
    }
    for label, res in cases.items():
        if isinstance(res, DsArray):
            assert_claim_holds(res, label)


def test_fill_states_track_constants():
    _, a = mk()
    assert a.pad_state == PAD_ZERO
    assert (a + 1.5).pad_state.fill == 1.5
    assert (a + 1.5 - 1.5).pad_state.kind == "zero"
    assert ((a + 2.0) * (a + 3.0)).pad_state.fill == 6.0
    assert a.exp().pad_state.fill == 1.0
    # nan pad (0/0) is unusable -> DIRTY
    assert (a / a).pad_state == PAD_DIRTY
    # a traced scalar operand cannot be probed -> DIRTY
    seen = []

    def f(t, s):
        r = DsArray(t, a.grid) + s
        seen.append(r.pad_state.kind)
        return r.blocks

    jax.make_jaxpr(f)(a.blocks, jnp.float32(2.0))
    assert seen == ["dirty"]


def test_dirty_chain_still_correct():
    x, a = mk()
    d = a / a                                    # DIRTY (nan pad)
    s = np.asarray((d * 2.0 + 1.0).sum(axis=0).collect())
    np.testing.assert_allclose(s, (x / x * 2.0 + 1.0).sum(0, keepdims=True),
                               rtol=1e-5)
    assert np.isfinite(s).all()


def test_max_of_negative_data_refills():
    """All-negative data: a zero pad would win max without the refill."""
    x = -np.abs(RNG.normal(size=(10, 7))).astype(np.float32) - 1.0
    a = from_array(x, (4, 3))
    np.testing.assert_allclose(np.asarray(a.max(axis=1).collect()).ravel(),
                               x.max(1), rtol=1e-6)
    assert float(a.max()) == pytest.approx(float(x.max()))


# ---------------------------------------------------------------------------
# Remask elision, asserted on the jaxpr
# ---------------------------------------------------------------------------


from repro.analysis import count_selects as _count_selects  # noqa: E402


def test_four_op_chain_has_at_most_one_mask_pass():
    """The acceptance assertion: 4 zero-preserving elementwise ops, ≤1
    select/mask pass in the trace (the seed emitted 4)."""
    _, a = mk(64, 48, 8, 8)

    def chain(p, q):
        u = DsArray(p, a.grid)
        v = DsArray(q, a.grid)
        return (-((u + v) * 2.0 - v).abs()).blocks   # add, mul, sub, abs, neg

    n_sel = _count_selects(jax.make_jaxpr(chain)(a.blocks, a.blocks))
    assert n_sel <= 1, f"{n_sel} mask passes in a zero-preserving chain"


def test_reduce_on_zero_pad_emits_no_mask_pass():
    _, a = mk(64, 48, 8, 8)
    jaxpr = jax.make_jaxpr(lambda p: DsArray(p, a.grid).sum())(a.blocks)
    assert _count_selects(jaxpr) == 0


def test_chain_into_reduce_pays_exactly_one_pass():
    """FILL pad reaching a 0-identity reduction costs one deferred remask —
    not one per op."""
    _, a = mk(64, 48, 8, 8)

    def f(p):
        u = DsArray(p, a.grid)
        return ((u + 1.0) * 2.0 + 3.0).sum()

    assert _count_selects(jax.make_jaxpr(f)(a.blocks)) == 1


def test_matmul_on_zero_pads_emits_no_mask_pass():
    _, a = mk(64, 48, 8, 8)
    _, b = mk(48, 32, 8, 8)

    def f(p, q):
        return (DsArray(p, a.grid) @ DsArray(q, b.grid)).blocks

    assert _count_selects(jax.make_jaxpr(f)(a.blocks, b.blocks)) == 0


def test_chain_values_match_numpy():
    x, a = mk()
    y, b = mk()
    got = np.asarray((-((a + b) * 2.0 - b).abs()).collect())
    np.testing.assert_allclose(got, -np.abs((x + y) * 2.0 - y), rtol=1e-5)
    got2 = np.asarray(((a + 1.5) * 2.0).sum(axis=0).collect())
    np.testing.assert_allclose(got2, ((x + 1.5) * 2.0).sum(0, keepdims=True),
                               rtol=1e-5)
