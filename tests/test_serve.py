"""Serving suite for repro.serve: exactness, cache discipline, chaos.

* micro-batch padding exactness: served predictions bit-identical to the
  direct ``estimator.predict`` on every bucket boundary — exact fit,
  one-row tail, ragged last block — dense AND bcoo
* steady-state plan-cache discipline: after warm, a request stream adds
  ZERO plan-cache misses / opt runs / AOT compiles, and the serve
  cache-hit counter equals the request count
* degradation ladder under injected ``serve_dispatch`` faults: transient
  retry, batch shed -> unbatched recovery, plan-level OOM absorbed by
  run_resilient, per-request failure isolation
* registry: versioned save_model/load round-trips, device pinning,
  eager-fallback serving for estimators without a recordable plan
* server mechanics: oversized/overdense fallbacks, payload validation,
  threaded serve_forever smoke
"""

import os
import tempfile
import threading

import jax.numpy as jnp
import numpy as np
import pytest

import repro.serve as serve
from repro.core import plan as plan_mod
from repro.core import sparse as sparse_mod
from repro.core.dsarray import from_array
from repro.estimators import LinearRegression, RandomForestClassifier, Ridge
from repro.resilience import FaultSpec, RetryPolicy, inject
from repro.serve.batching import (BucketSpec, GeometryBucket, assemble,
                                  normalize_payload, split_rows)

pytestmark = pytest.mark.serve

SEED = 20260808
N_FEATURES = 12

try:
    import scipy.sparse as sp
    HAVE_SCIPY = True
except ImportError:                                    # pragma: no cover
    HAVE_SCIPY = False

needs_scipy = pytest.mark.skipif(not HAVE_SCIPY, reason="scipy not installed")


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


def _fit_ridge(seed=SEED, n=256, m=N_FEATURES, alpha=0.1):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m)).astype(np.float32)
    w = rng.normal(size=(m,)).astype(np.float32)
    y = (X @ w + 0.25).reshape(-1, 1).astype(np.float32)
    est = Ridge(alpha=alpha)
    est.fit(from_array(jnp.asarray(X), (64, m)),
            from_array(jnp.asarray(y), (64, 1)))
    return est


@pytest.fixture(scope="module")
def ridge():
    return _fit_ridge()


# counter hygiene is the session-wide autouse obs.reset_all() fixture in
# conftest.py — no per-module reset needed


def _registry(est, **kw):
    # a 1-row bucket (as in the module default) keeps lone requests at
    # their natural (1, m) geometry — see the exactness note in batching
    kw.setdefault("batch_sizes", (1, 4, 16))
    kw.setdefault("block_rows", 4)
    reg = serve.ModelRegistry()
    reg.register("m", est, **kw)
    return reg


def _rows(n, seed=1, m=N_FEATURES):
    return np.random.default_rng(seed).normal(size=(n, m)).astype(np.float32)


def _sparse_rows(n, seed=1, m=N_FEATURES, density=0.3):
    return sp.random(n, m, density=density, format="csr",
                     random_state=np.random.default_rng(seed),
                     dtype=np.float32)


def _direct_dense(est, rows):
    return np.asarray(est.predict(rows).collect())


def _direct_sparse(est, mat):
    x = sparse_mod.from_scipy(mat, (mat.shape[0], mat.shape[1]))
    return np.asarray(est.predict(x).collect())


# ---------------------------------------------------------------------------
# micro-batch padding exactness at every bucket boundary
# ---------------------------------------------------------------------------

# with buckets (4, 16) and block_rows=4: exact smallest fit, smallest+1
# (pad 11 into the big bucket), exact largest fit, one-row tail, and a
# ragged last block (13 = 3 full blocks + 1 row)
BOUNDARY_TOTALS = [4, 5, 16, 15, 13, 1]


@pytest.mark.parametrize("total", BOUNDARY_TOTALS)
def test_dense_served_equals_direct(ridge, total):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    rows = _rows(total, seed=total)
    # split the batch over several requests so concat+pad is exercised
    sizes = [1] * total if total <= 2 else [2, total - 3, 1]
    futs, off = [], 0
    for s in sizes:
        futs.append(srv.submit("m", rows[off:off + s]))
        off += s
    assert srv.pump() == len(sizes)
    got = np.concatenate([f.result() for f in futs], axis=0)
    direct = _direct_dense(ridge, rows)
    assert got.shape == (total, 1)
    assert np.array_equal(got, direct)


@needs_scipy
@pytest.mark.parametrize("total", BOUNDARY_TOTALS)
def test_bcoo_served_equals_direct(ridge, total):
    reg = _registry(ridge, formats=("dense", "bcoo"), nse=4 * N_FEATURES)
    srv = serve.PredictServer(reg)
    mat = _sparse_rows(total, seed=total)
    sizes = [1] * total if total <= 2 else [2, total - 3, 1]
    futs, off = [], 0
    for s in sizes:
        futs.append(srv.submit("m", mat[off:off + s]))
        off += s
    srv.pump()
    got = np.concatenate([f.result() for f in futs], axis=0)
    direct = _direct_sparse(ridge, mat)
    assert np.array_equal(got, direct)
    assert serve.stats()["eager_requests"] == 0   # stayed on the plan path


@pytest.mark.parametrize("sizes", [(2, 3, 1), (8,), (3, 3, 3, 3, 1),
                                   (1, 1, 1)])
def test_served_rows_equal_predict_on_padded_batch(ridge, sizes):
    """The structural guarantee (geometry-independent): each request's
    served rows are EXACTLY the corresponding rows of ``predict`` on the
    padded bucket batch — same compiled program, same values; padding and
    slicing are bitwise-neutral."""
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    payloads = [_rows(s, seed=40 + i) for i, s in enumerate(sizes)]
    futs = [srv.submit("m", p) for p in payloads]
    srv.pump()
    model = reg.get("m")
    bucket = model.spec.bucket_for(sum(sizes), "dense")
    batch = assemble(payloads, bucket)
    direct = np.asarray(ridge.predict(batch).collect())
    off = 0
    for f, s in zip(futs, sizes):
        assert np.array_equal(f.result(), direct[off:off + s])
        off += s


def test_one_row_requests_batch_together(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    rows = _rows(4, seed=7)
    futs = [srv.submit("m", rows[i]) for i in range(4)]   # 1-D payloads
    srv.pump()
    st = serve.stats()
    assert st["batches"] == 1 and st["batched_requests"] == 4
    got = np.concatenate([f.result() for f in futs], axis=0)
    assert np.array_equal(got, _direct_dense(ridge, rows))


# ---------------------------------------------------------------------------
# steady-state plan-cache discipline (the zero-recompile acceptance)
# ---------------------------------------------------------------------------


def test_steady_state_zero_recompiles(ridge):
    plan_mod.clear_cache()
    reg = _registry(ridge, formats=("dense", "bcoo") if HAVE_SCIPY
                    else ("dense",), nse=4 * N_FEATURES if HAVE_SCIPY
                    else None)
    srv = serve.PredictServer(reg)
    warm = plan_mod.cache_stats()
    assert warm["aot_compiles"] == (6 if HAVE_SCIPY else 3)

    n_requests = 0
    for i in range(6):                       # rotate through both buckets
        futs = [srv.submit("m", _rows(1 + (i % 3), seed=i))
                for _ in range(3)]
        if HAVE_SCIPY:
            futs.append(srv.submit("m", _sparse_rows(2 + (i % 3), seed=i)))
        srv.pump()
        for f in futs:
            f.result()
        n_requests += len(futs)

    after = plan_mod.cache_stats()
    # the serving stream NEVER re-optimized or re-compiled a plan
    assert after["misses"] == warm["misses"]
    assert after["opt_runs"] == warm["opt_runs"]
    assert after["aot_compiles"] == warm["aot_compiles"]
    st = serve.stats()
    assert st["cache_hits"] == n_requests == st["requests"]
    assert st["cache_misses"] == 0
    assert st["batch_sheds"] == 0 and st["failures"] == 0
    lat = st["latency"]
    assert lat["count"] == n_requests and lat["p99_ms"] >= lat["p50_ms"] > 0


def test_warm_is_idempotent(ridge):
    plan_mod.clear_cache()
    reg = _registry(ridge)
    model = reg.get("m")
    assert model.cache.warm() == 0            # already warmed on register
    assert reg.warm_all() == 0
    before = plan_mod.cache_stats()["aot_compiles"]
    plan_mod.clear_cache()
    assert reg.warm_all() == 3                # cold cache -> every bucket
    assert plan_mod.cache_stats()["aot_compiles"] == 3
    assert before == 3


def test_clean_run_recovery_counters_zero(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    f = srv.submit("m", _rows(3))
    srv.pump()
    f.result()
    st = serve.stats()
    for k in ("batch_sheds", "dispatch_retries", "bucket_fallbacks",
              "cache_misses", "failures", "single_dispatches"):
        assert st[k] == 0, k
    assert st["requests"] == st["responses"] == 1
    assert st["queue_depth"] == 0 and st["queue_depth_peak"] == 1


# ---------------------------------------------------------------------------
# fault-injected serving: the degradation ladder
# ---------------------------------------------------------------------------


def test_transient_dispatch_retries_and_recovers(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg, policy=RetryPolicy(max_retries=2))
    rows = _rows(5, seed=3)
    with inject(FaultSpec(kind="transient", site="serve_dispatch", times=1)):
        f = srv.submit("m", rows)
        srv.pump()
    assert np.array_equal(f.result(), _direct_dense(ridge, rows))
    st = serve.stats()
    assert st["dispatch_retries"] == 1
    assert st["batch_sheds"] == 0
    assert st["batches"] == 1


def test_batched_fault_sheds_to_unbatched(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    rows = _rows(6, seed=4)
    # every BATCHED dispatch crashes; single-mode dispatch is clean
    with inject(FaultSpec(kind="crash", site="serve_dispatch", times=None,
                          where={"mode": "batched"})):
        f1 = srv.submit("m", rows[:4])
        f2 = srv.submit("m", rows[4:])
        srv.pump()
    got = np.concatenate([f1.result(), f2.result()], axis=0)
    assert np.array_equal(got, _direct_dense(ridge, rows))
    st = serve.stats()
    assert st["batch_sheds"] == 1
    assert st["single_dispatches"] == 2
    assert st["failures"] == 0


def test_oom_dispatch_sheds_to_unbatched(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    rows = _rows(3, seed=5)
    with inject(FaultSpec(kind="oom", site="serve_dispatch", times=1,
                          where={"mode": "batched"})):
        f = srv.submit("m", rows)
        srv.pump()
    assert np.array_equal(f.result(), _direct_dense(ridge, rows))
    st = serve.stats()
    assert st["batch_sheds"] == 1 and st["failures"] == 0


def test_plan_level_oom_absorbed_by_resilience_ladder(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    rows = _rows(4, seed=6)
    with inject(FaultSpec(kind="oom", site="plan_execute", times=1)):
        f = srv.submit("m", rows)
        srv.pump()
    # run_resilient degraded INSIDE the batched dispatch: no shed at all
    assert np.array_equal(f.result(), _direct_dense(ridge, rows))
    st = serve.stats()
    assert st["batch_sheds"] == 0 and st["batches"] == 1


def test_retry_exhaustion_then_shed_recovers(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg, policy=RetryPolicy(max_retries=1))
    rows = _rows(2, seed=8)
    with inject(FaultSpec(kind="transient", site="serve_dispatch", times=3,
                          where={"mode": "batched"})):
        f = srv.submit("m", rows)
        srv.pump()
    assert np.array_equal(f.result(), _direct_dense(ridge, rows))
    st = serve.stats()
    assert st["dispatch_retries"] == 1       # exhausted, then shed
    assert st["batch_sheds"] == 1


def test_single_mode_failure_is_isolated(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg)
    rows = _rows(3, seed=9)
    # batched always crashes; the SECOND single dispatch also crashes ->
    # exactly one request fails, its neighbours still get exact answers
    with inject(FaultSpec(kind="crash", site="serve_dispatch", times=None,
                          where={"mode": "batched"}),
                FaultSpec(kind="crash", site="serve_dispatch", at=2, times=1,
                          where={"mode": "single"})):
        futs = [srv.submit("m", rows[i]) for i in range(3)]
        srv.pump()
    # each recovered response is exact vs direct predict of ITS OWN rows
    assert np.array_equal(futs[0].result(), _direct_dense(ridge, rows[:1]))
    with pytest.raises(Exception):
        futs[1].result()
    assert np.array_equal(futs[2].result(), _direct_dense(ridge, rows[2:3]))
    st = serve.stats()
    assert st["failures"] == 1 and st["responses"] == 2


def test_no_fallback_propagates_batch_error(ridge):
    reg = _registry(ridge)
    srv = serve.PredictServer(reg, unbatched_fallback=False)
    with inject(FaultSpec(kind="crash", site="serve_dispatch", times=1)):
        f = srv.submit("m", _rows(2))
        srv.pump()
    with pytest.raises(Exception):
        f.result()
    assert serve.stats()["failures"] == 1


# ---------------------------------------------------------------------------
# out-of-bucket fallbacks
# ---------------------------------------------------------------------------


def test_oversized_request_falls_back_unbatched(ridge):
    reg = _registry(ridge)                       # max bucket: 16 rows
    srv = serve.PredictServer(reg)
    rows = _rows(33, seed=10)
    f = srv.submit("m", rows)
    srv.pump()
    assert np.array_equal(f.result(), _direct_dense(ridge, rows))
    st = serve.stats()
    assert st["bucket_fallbacks"] == 1
    assert st["single_dispatches"] == 1 and st["batches"] == 0


@needs_scipy
def test_bcoo_nse_overflow_falls_back_unbatched(ridge):
    # nse capacity of 4 entries/block, but a nearly-dense request: packing
    # would truncate entries, so the server must go unbatched instead
    reg = _registry(ridge, formats=("dense", "bcoo"), nse=4)
    srv = serve.PredictServer(reg)
    mat = _sparse_rows(4, seed=11, density=0.9)
    assert sparse_mod.max_block_nnz(mat, (4, N_FEATURES)) > 4
    f = srv.submit("m", mat)
    srv.pump()
    assert np.array_equal(f.result(), _direct_sparse(ridge, mat))
    st = serve.stats()
    assert st["bucket_fallbacks"] == 1 and st["failures"] == 0


# ---------------------------------------------------------------------------
# payload validation / batching unit behaviour
# ---------------------------------------------------------------------------


def test_submit_rejects_bad_payloads(ridge):
    srv = serve.PredictServer(_registry(ridge))
    with pytest.raises(ValueError, match="does not match"):
        srv.submit("m", np.zeros((2, N_FEATURES + 1), np.float32))
    with pytest.raises(ValueError, match="empty"):
        srv.submit("m", np.zeros((0, N_FEATURES), np.float32))
    with pytest.raises(KeyError):
        srv.submit("nope", np.zeros((1, N_FEATURES), np.float32))


def test_bucket_spec_selection():
    spec = BucketSpec(8, batch_sizes=(4, 16), block_rows=4)
    assert spec.bucket_for(1, "dense").rows == 4
    assert spec.bucket_for(4, "dense").rows == 4
    assert spec.bucket_for(5, "dense").rows == 16
    assert spec.bucket_for(17, "dense") is None
    assert spec.bucket_for(3, "bcoo") is None      # format not declared
    assert spec.max_rows("dense") == 16
    with pytest.raises(ValueError):
        BucketSpec(8, formats=("bcoo",))           # bcoo without nse
    with pytest.raises(ValueError):
        GeometryBucket(4, 4, 8, "bcoo")


def test_assemble_pads_with_zeros_and_split_inverts():
    bucket = GeometryBucket(rows=8, block_rows=4, n_features=3, fmt="dense")
    a, b = _rows(2, seed=1, m=3), _rows(3, seed=2, m=3)
    x = assemble([a, b], bucket)
    assert x.shape == (8, 3) and x.block_shape == (4, 3)
    dense = np.asarray(x.collect())
    np.testing.assert_array_equal(dense[:2], a)
    np.testing.assert_array_equal(dense[2:5], b)
    np.testing.assert_array_equal(dense[5:], 0.0)
    parts = split_rows(dense, [2, 3])
    np.testing.assert_array_equal(parts[0], a)
    np.testing.assert_array_equal(parts[1], b)


def test_normalize_payload_shapes():
    arr, n, fmt = normalize_payload(np.zeros(5, np.float32), 5)
    assert (n, fmt) == (1, "dense") and arr.shape == (1, 5)
    with pytest.raises(ValueError):
        normalize_payload(np.zeros((2, 3, 4), np.float32), 5)


# ---------------------------------------------------------------------------
# registry: versions, checkpoint round-trip, eager fallback
# ---------------------------------------------------------------------------


def test_registry_versioned_load_roundtrip():
    est1 = _fit_ridge(seed=1)
    est2 = _fit_ridge(seed=2)
    rows = _rows(3, seed=12)
    with tempfile.TemporaryDirectory() as d:
        mdir = os.path.join(d, "ridge")
        est1.save_model(mdir, version=1)
        est2.save_model(mdir, version=2)
        reg = serve.ModelRegistry()
        reg.load("ridge", mdir, version=1, batch_sizes=(4,), block_rows=4)
        reg.load("ridge", mdir, batch_sizes=(4,), block_rows=4)  # newest
        assert reg.versions("ridge") == [1, 2]
        assert reg.get("ridge").version == 2          # latest by default
        srv = serve.PredictServer(reg)
        f1 = srv.submit("ridge", rows, version=1)
        f2 = srv.submit("ridge", rows)
        srv.pump()
        assert np.array_equal(f1.result(), _direct_dense(est1, rows))
        assert np.array_equal(f2.result(), _direct_dense(est2, rows))
        assert not np.array_equal(f1.result(), f2.result())


def test_registry_lists_models(ridge):
    reg = serve.ModelRegistry()
    reg.register("a", ridge, batch_sizes=(4,), warm=False)
    reg.register("a", ridge, version=3, batch_sizes=(4,), warm=False)
    reg.register("b", ridge, batch_sizes=(4,), warm=False)
    assert reg.models() == [("a", 0), ("a", 3), ("b", 0)]
    with pytest.raises(KeyError, match="versions"):
        reg.get("a", version=7)


def test_eager_fallback_estimator_serves_exactly():
    rng = np.random.default_rng(SEED)
    X = rng.normal(size=(96, 6)).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.int32).reshape(-1, 1)
    est = RandomForestClassifier(n_estimators=4, max_depth=3, seed=0)
    est.fit(from_array(jnp.asarray(X), (32, 6)),
            from_array(jnp.asarray(y), (32, 1)))
    assert not est.has_predict_plan()
    reg = serve.ModelRegistry()
    reg.register("forest", est, batch_sizes=(4, 8), block_rows=4)
    srv = serve.PredictServer(reg)
    rows = X[:5]
    f = srv.submit("forest", rows)
    srv.pump()
    assert np.array_equal(f.result(), _direct_dense(est, rows))
    st = serve.stats()
    assert st["eager_requests"] == 1 and st["cache_hits"] == 0


def test_predict_plan_unsupported_raises():
    est = RandomForestClassifier(n_estimators=2, max_depth=2)
    with pytest.raises(NotImplementedError):
        est._predict_expr(None)


# ---------------------------------------------------------------------------
# threaded server
# ---------------------------------------------------------------------------


def test_threaded_serve_forever_smoke(ridge):
    reg = _registry(ridge)
    rows = _rows(6, seed=13)
    direct = _direct_dense(ridge, rows)
    with serve.PredictServer(reg) as srv:
        futs = [srv.submit("m", rows[i * 2:(i + 1) * 2]) for i in range(3)]
        got = np.concatenate([f.result(timeout=30) for f in futs], axis=0)
    assert np.array_equal(got, direct)
    assert serve.stats()["responses"] == 3


def test_future_timeout():
    f = serve.PredictFuture()
    with pytest.raises(TimeoutError):
        f.result(timeout=0.01)
    assert not f.done()


# ---------------------------------------------------------------------------
# AOT input donation (Plan.compile_aot donate_argnums)
# ---------------------------------------------------------------------------


def test_compile_aot_accepts_donate_argnums(ridge):
    """Regression: ``Plan.compile_aot`` had no ``donate_argnums`` — the
    serve warm path could not mark the packed request batch donatable, so
    on accelerators every predict paid an extra output allocation."""
    plan_mod.clear_cache()
    x = from_array(jnp.asarray(_rows(4)), (4, N_FEATURES))
    p = ridge.predict_plan(x)
    donate = tuple(i for i, leaf in enumerate(p.leaves)
                   if getattr(leaf, "value", None) is x)
    assert donate, "the batch leaf must appear in the plan's leaves"
    assert p.compile_aot(donate_argnums=donate) is True
    # idempotent: the donated executable is cached under the same key
    assert p.compile_aot(donate_argnums=donate) is False


def test_donated_warm_serving_output_unchanged(ridge):
    """With ``donate_inputs=True`` (the register default), the warmed
    executables consume the packed batch — served outputs stay bitwise
    equal to direct predict and the steady-state stream still adds zero
    plan-cache misses / opt runs / AOT compiles."""
    from repro.serve.compilecache import representative_input

    plan_mod.clear_cache()
    reg = _registry(ridge)
    model = reg.get("m")
    assert model.cache.donate_inputs
    # the donation map finds the batch leaf for every declared bucket
    for bucket in model.cache.spec.buckets():
        x = representative_input(bucket)
        p = ridge.predict_plan(x)
        assert model.cache._donate_argnums(p, x) != ()
        # never the fitted-parameter leaves: only leaves holding x itself
        for i in model.cache._donate_argnums(p, x):
            assert p.leaves[i].value is x

    srv = serve.PredictServer(reg)
    warm = plan_mod.cache_stats()
    batches, served = [], []
    for i in range(5):
        rows = _rows(1 + (i % 3), seed=40 + i)
        f = srv.submit("m", rows)
        srv.pump()
        batches.append(rows)
        served.append(f.result())
    after = plan_mod.cache_stats()
    assert after["misses"] == warm["misses"]
    assert after["opt_runs"] == warm["opt_runs"]
    assert after["aot_compiles"] == warm["aot_compiles"]
    # direct predict runs at natural geometry (own plans), so only after
    # the frozen-stats window closes
    for rows, got in zip(batches, served):
        assert np.array_equal(got, _direct_dense(ridge, rows))


def test_donation_opt_out_warms_without_aliasing(ridge):
    from repro.serve.compilecache import (PredictCompileCache,
                                          representative_input)

    plan_mod.clear_cache()
    spec = BucketSpec(N_FEATURES, batch_sizes=(4,), block_rows=4)
    cache = PredictCompileCache(ridge, spec, donate_inputs=False)
    bucket = spec.buckets()[0]
    x = representative_input(bucket)
    p = ridge.predict_plan(x)
    assert cache._donate_argnums(p, x) == ()
    assert cache.warm() == 1
    assert cache.warm() == 0
