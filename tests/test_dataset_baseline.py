"""Dataset baseline: semantics + the paper's task-complexity laws."""

import numpy as np

from repro.core import Dataset, TaskCounter, costmodel


def test_transpose_semantics_and_task_law():
    x = np.random.default_rng(0).normal(size=(30, 30)).astype(np.float32)
    for n in [2, 3, 5, 6]:
        ds = Dataset.from_array(x, n)
        before = ds.counter.tasks
        t = ds.transpose()
        assert np.allclose(t.collect(), x.T)
        used = ds.counter.tasks - before
        assert used == costmodel.dataset_transpose_tasks(n), (n, used)


def test_shuffle_semantics_and_task_law():
    x = np.random.default_rng(0).normal(size=(40, 3)).astype(np.float32)
    for n in [2, 4, 5]:
        ds = Dataset.from_array(x, n)
        before = ds.counter.tasks
        s = ds.shuffle(np.random.default_rng(1))
        assert np.allclose(np.sort(s.collect(), 0), np.sort(x, 0))
        used = ds.counter.tasks - before
        size = x.shape[0] // n
        assert used <= costmodel.dataset_shuffle_tasks(n, size + 1)
        assert used >= n + n  # at least one split + one merge per Subset


def test_rowsum_reduction_tree():
    x = np.random.default_rng(0).normal(size=(16, 4)).astype(np.float32)
    ds = Dataset.from_array(x, 4)
    before = ds.counter.tasks
    s = ds.sum_rows()
    assert np.allclose(s, x.sum(0, keepdims=True), atol=1e-4)
    assert ds.counter.tasks - before == costmodel.dataset_rowsum_tasks(4)


def test_task_law_separation():
    """The paper's headline: ds-array transpose is O(N) vs O(N^2+N)."""
    for n in [16, 64, 256, 1536]:
        assert costmodel.dsarray_transpose_tasks(n, 1) == n
        assert costmodel.dataset_transpose_tasks(n) == n * n + n
        assert costmodel.dsarray_shuffle_tasks(n) == 2 * n
    # modeled PyCOMPSs wall-time reproduces the paper's collapse (Fig. 6):
    t_ds = costmodel.pycompss_time(costmodel.dataset_transpose_tasks(1536),
                                   0.05, 768)
    t_da = costmodel.pycompss_time(costmodel.dsarray_transpose_tasks(1536, 1),
                                   0.05, 768)
    assert t_ds / t_da > 100  # two orders of magnitude (paper: 4.5h -> 7s)


def test_counter_bytes():
    c = TaskCounter()
    c.task(np.zeros((4, 4), np.float32))
    assert c.tasks == 1 and c.bytes_moved == 64
