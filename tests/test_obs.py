"""Observability suite for repro.obs: tracing, metrics, profiling.

* metrics registry: typed get-or-create, snapshot/reset, histogram
  percentile math bit-identical to the old serve reservoir
* tracing: zero allocations while disabled (the serve-p50 guard),
  Chrome trace-event JSON round-trip through json.load for a warmed
  serve stream and a CascadeSVM fit, span coverage for plan launches /
  fit iterations / resilience rungs / ingest chunks, @traced, summary tree
* migration contract: plan.cache_stats() / resilience.stats() /
  serve.stats() bitwise-unchanged whether tracing is on or off
* thread safety: exact counts from a threaded hammer over the locked
  registry increments (the bare `+=` these counters replaced lost updates
  under PredictServer worker threads)
* profiler: predicted == measured bytes per node on the 6-op fused chain,
  and the costmodel-drift rule clean on main / provably firing when the
  byte law is broken
"""

import json
import os
import sys
import tempfile
import threading

import numpy as np
import pytest

import repro.resilience as R
import repro.serve as serve
from repro import analysis, obs
from repro.core import expr as expr_mod
from repro.core import plan as plan_mod
from repro.core.dsarray import from_array
from repro.estimators import CascadeSVM, Ridge

pytestmark = pytest.mark.obs

SEED = 20260808


# ---------------------------------------------------------------------------
# workload helpers
# ---------------------------------------------------------------------------


def _six_op_chain(seed=0, shape=(64, 48), bs=(8, 8)):
    rng = np.random.default_rng(seed)
    a = from_array(rng.normal(size=shape).astype(np.float32), bs).lazy()
    return (((a + a) * 2.0 - a).abs() * 0.5 + 0.25)


def _fit_ridge(n=64, m=8):
    rng = np.random.default_rng(SEED)
    x = rng.normal(size=(n, m)).astype(np.float32)
    y = (x @ rng.normal(size=(m, 1))).astype(np.float32)
    return Ridge(alpha=0.1).fit(from_array(x, (16, m)),
                                from_array(y, (16, 1)))


def _serve_stream(est, n_requests=6, m=8):
    reg = serve.ModelRegistry()
    reg.register("m", est, batch_sizes=(4, 16), block_rows=4)
    srv = serve.PredictServer(reg)
    rng = np.random.default_rng(1)
    futs = [srv.submit("m", rng.normal(size=(2, m)).astype(np.float32))
            for _ in range(n_requests)]
    srv.pump()
    return [f.result() for f in futs]


def _names(events):
    return {e["name"] for e in events}


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    c = obs.registry.counter("t.c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert obs.registry.counter("t.c") is c          # get-or-create
    g = obs.registry.gauge("t.g")
    g.set(3)
    g.set_max(7)
    g.set_max(2)                                     # lower: no-op
    assert g.value == 7
    h = obs.registry.histogram("t.h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["max"] == 4.0 and s["mean"] == 2.5
    with pytest.raises(TypeError):
        obs.registry.gauge("t.c")                    # typed: no shadowing


def test_snapshot_prefix_and_reset_all():
    obs.registry.counter("sn.a").inc(2)
    obs.registry.counter("sn.b").inc(3)
    obs.registry.counter("other.c").inc(1)
    snap = obs.snapshot("sn")
    assert snap == {"sn.a": 2, "sn.b": 3}
    full = obs.snapshot()
    assert full["other.c"] == 1
    obs.reset_all()
    assert obs.snapshot("sn") == {"sn.a": 0, "sn.b": 0}


def test_histogram_percentile_is_nearest_rank():
    # the exact index law the serve latency reservoir has always used:
    # i = min(len-1, round(q * (len-1)))
    h = obs.registry.histogram("t.lat")
    vals = [float(v) for v in range(1, 11)]          # 1..10
    for v in vals:
        h.observe(v)
    s = h.summary()
    srt = sorted(vals)
    for q, key in ((0.50, "p50"), (0.99, "p99")):
        i = min(len(srt) - 1, int(round(q * (len(srt) - 1))))
        assert s[key] == srt[i]


def test_stats_views_are_plain_int_dicts():
    chain = _six_op_chain()
    plan_mod.clear_cache()
    chain.compute()
    cs = plan_mod.cache_stats()
    assert list(cs) == ["hits", "misses", "launches", "opt_runs",
                        "opt_skips", "eager_launches", "aot_compiles"]
    assert all(type(v) is int for v in cs.values())
    assert cs["misses"] == 1 and cs["launches"] == 1
    rs = R.stats()
    assert list(rs) == ["executions", "retries", "degradations",
                        "recoveries", "guard_failures"]
    assert all(type(v) is int for v in rs.values())


# ---------------------------------------------------------------------------
# tracing: the zero-overhead-disabled contract
# ---------------------------------------------------------------------------


def test_disabled_tracing_allocates_no_spans():
    chain = _six_op_chain()
    plan_mod.clear_cache()
    chain.compute()                                  # compile once
    assert not obs.enabled()
    base = obs.span_allocations()
    for _ in range(100):
        chain.compute()                              # hot cached launches
    assert obs.span_allocations() == base == 0
    assert obs.events() == []
    # and the null span really is one shared object, not per-call garbage
    assert obs.span("x") is obs.span("y", a=1)


def test_span_records_chrome_event_and_error_attr():
    obs.enable()
    with obs.span("unit.ok", k=1) as sp:
        sp.set(extra="v")
    with pytest.raises(RuntimeError):
        with obs.span("unit.bad"):
            raise RuntimeError("boom")
    obs.disable()
    evts = obs.events()
    assert [e["name"] for e in evts] == ["unit.ok", "unit.bad"]
    ok, bad = evts
    assert ok["ph"] == "X" and ok["dur"] >= 0 and ok["args"]["extra"] == "v"
    assert bad["args"]["error"] == "RuntimeError"


def test_traced_decorator():
    @obs.traced
    def plain(x):
        return x + 1

    @obs.traced(name="custom.label", tag="t")
    def named(x):
        return x * 2

    assert plain(1) == 2 and named(2) == 4           # disabled: no events
    assert obs.events() == []
    obs.enable()
    plain(1)
    named(2)
    obs.disable()
    names = [e["name"] for e in obs.events()]
    assert "custom.label" in names
    assert any(n.endswith("plain") for n in names)


def test_trace_to_writes_valid_json_and_restores_state():
    assert not obs.enabled()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.json")
        with obs.trace_to(path):
            assert obs.enabled()
            with obs.span("a.b"):
                pass
        assert not obs.enabled()                     # prior state restored
        with open(path) as f:
            trace = json.load(f)
    assert trace["displayTimeUnit"] == "ms"
    assert [e["name"] for e in trace["traceEvents"]] == ["a.b"]


def test_summary_tree_aggregates_by_name():
    obs.enable()
    for _ in range(3):
        with obs.span("plan.launch"):
            pass
    with obs.span("plan.optimize"):
        pass
    obs.disable()
    text = obs.summary()
    assert "plan" in text and "launch" in text and "optimize" in text
    assert "3" in text                               # the launch count


# ---------------------------------------------------------------------------
# span coverage: plan / fit / resilience / serve / ingest
# ---------------------------------------------------------------------------


def test_trace_covers_warmed_serve_stream():
    est = _fit_ridge()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "serve.json")
        with obs.trace_to(path):
            _serve_stream(est)
        with open(path) as f:
            trace = json.load(f)
    events = trace["traceEvents"]
    names = _names(events)
    assert {"serve.submit", "serve.batch", "serve.dispatch",
            "serve.slice", "plan.launch"} <= names
    assert all(e["ph"] == "X" and "ts" in e and "dur" in e for e in events)
    # every dispatch span names its mode; clean run = attempt 0 throughout
    dispatches = [e for e in events if e["name"] == "serve.dispatch"]
    assert dispatches and all(e["args"]["attempt"] == 0 for e in dispatches)


def test_trace_covers_csvm_fit_iterations():
    rng = np.random.default_rng(3)
    xa = rng.normal(size=(64, 8)).astype(np.float32)
    y = (xa[:, 0] > 0).astype(np.float32)
    x = from_array(xa, (16, 8))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "fit.json")
        with obs.trace_to(path):
            CascadeSVM(max_iter=2, solver_iters=10, sv_cap=16).fit(x, y)
        with open(path) as f:
            trace = json.load(f)
    events = trace["traceEvents"]
    iters = [e for e in events if e["name"] == "fit.iteration"]
    assert [e["args"]["iteration"] for e in iters] == [1, 2]
    assert all(e["args"]["estimator"] == "CascadeSVM" for e in iters)
    assert "plan.launch" in _names(events)
    # iteration spans ENCLOSE their launches (the tree nests in a viewer)
    launches = [e for e in events if e["name"] == "plan.launch"]
    i0 = iters[0]
    assert any(i0["ts"] <= e["ts"] and
               e["ts"] + e["dur"] <= i0["ts"] + i0["dur"] + 1
               for e in launches)


def test_trace_covers_resilience_retry_rungs():
    rng = np.random.default_rng(4)
    a = from_array(rng.normal(size=(8, 12)).astype(np.float32), (4, 4))
    b = from_array(rng.normal(size=(12, 6)).astype(np.float32), (4, 3))
    with expr_mod.lazy():
        lz = (a @ b) * 2.0 + 1.0
    obs.enable()
    with R.inject(R.FaultSpec(kind="transient", site="plan_execute", at=1)):
        R.run_resilient(lz)
    obs.disable()
    rungs = [e for e in obs.events() if e["name"] == "resilience.rung"]
    assert len(rungs) == 2                           # failed attempt + win
    assert rungs[0]["args"]["attempt"] == 0
    assert rungs[0]["args"]["error"] == "TransientError"
    assert rungs[1]["args"]["attempt"] == 1
    assert "error" not in rungs[1]["args"]
    assert R.stats()["retries"] == 1


def test_trace_covers_ingest_chunks():
    from repro.core.io import load_txt_file
    rng = np.random.default_rng(5)
    ref = rng.normal(size=(32, 6)).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.csv")
        np.savetxt(path, ref, delimiter=",", fmt="%.6f")
        obs.enable()
        x = load_txt_file(path, (8, 6), chunk_bytes=256)
        obs.disable()
    assert np.allclose(np.asarray(x.collect()), ref, atol=1e-5)
    names = _names(obs.events())
    assert {"ingest.load", "ingest.chunk"} <= names
    chunks = [e for e in obs.events() if e["name"] == "ingest.chunk"]
    assert len(chunks) > 1                           # actually streamed
    assert all(e["args"]["chunk_bytes"] > 0 for e in chunks)


# ---------------------------------------------------------------------------
# migration contract: identical stats traced vs untraced
# ---------------------------------------------------------------------------


def _stats_workload():
    plan_mod.clear_cache()
    est = _fit_ridge()
    _serve_stream(est)
    rng = np.random.default_rng(6)
    a = from_array(rng.normal(size=(8, 12)).astype(np.float32), (4, 4))
    b = from_array(rng.normal(size=(12, 6)).astype(np.float32), (4, 3))
    with expr_mod.lazy():
        lz = (a @ b) * 2.0 + 1.0
    with R.inject(R.FaultSpec(kind="transient", site="plan_execute", at=1)):
        R.run_resilient(lz)
    return (plan_mod.cache_stats(), R.stats(), serve.stats())


def test_stats_identical_with_and_without_tracing():
    untraced = _stats_workload()
    obs.reset_all()
    obs.enable()
    try:
        traced = _stats_workload()
    finally:
        obs.disable()
    for off, on, which in zip(untraced, traced,
                              ("plan", "resilience", "serve")):
        # latency timings differ run to run; counter values must not
        off = dict(off)
        on = dict(on)
        off.pop("latency", None)
        on.pop("latency", None)
        assert off == on, f"{which} stats changed under tracing"


# ---------------------------------------------------------------------------
# thread safety: the locked increments count exactly
# ---------------------------------------------------------------------------


def test_threaded_hammer_counts_exactly():
    import importlib
    from repro.resilience import execute as rex
    # repro.serve re-exports the stats FUNCTION under the same name, so
    # reach the module through importlib
    serve_stats = importlib.import_module("repro.serve.stats")
    n_threads, n_incs = 8, 2500
    c = obs.registry.counter("hammer.c")

    def work():
        for _ in range(n_incs):
            c.inc()
            serve_stats.bump("requests")
            rex._STATS.inc("retries")
            plan_mod._STATS.inc("hits")

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)                      # force contention
    try:
        threads = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sys.setswitchinterval(old)
    want = n_threads * n_incs
    assert c.value == want
    assert serve.stats()["requests"] == want
    assert R.stats()["retries"] == want
    assert plan_mod.cache_stats()["hits"] == want


# ---------------------------------------------------------------------------
# profiler + costmodel-drift rule
# ---------------------------------------------------------------------------


def test_profile_six_op_chain_matches_costmodel():
    chain = _six_op_chain()
    plan_mod.clear_cache()
    rep = obs.profile(chain)
    assert rep.nodes                                 # fused body profiled
    for rec in rep.nodes:
        assert rec.measured_bytes == rec.predicted_bytes, rec.site
        assert rec.time_s >= 0.0
    assert rep.drifting() == []
    assert rep.fused_time_s is not None and rep.fused_time_s > 0.0
    text = str(rep)
    assert "within drift tolerance" in text and "fused" in text


def test_profile_accepts_plan_and_skips_fused():
    p = plan_mod.plan_for(_six_op_chain(seed=1))
    rep = obs.profile(p, fused=False, compiled=False)
    assert rep.fused_time_s is None and rep.compiled == {}
    assert rep.eager_total_s == sum(n.time_s for n in rep.nodes)


def test_costmodel_drift_rule_clean_on_real_plans():
    p = plan_mod.plan_for(_six_op_chain(seed=2))
    rep = analysis.check(p, rules=["costmodel-drift"])
    assert rep.ok and rep.findings == []


def test_costmodel_drift_rule_fires_when_law_is_broken(monkeypatch):
    from repro.core import costmodel
    real = costmodel.node_live_bytes
    # a 2x-wrong byte law: every prediction is half reality — well beyond
    # the 1.25x tolerance, so every non-leaf node must be flagged
    monkeypatch.setattr(costmodel, "node_live_bytes",
                        lambda *a, **k: real(*a, **k) / 2.0)
    p = plan_mod.plan_for(_six_op_chain(seed=3))
    rep = analysis.check(p, rules=["costmodel-drift"], fail_on="warn")
    assert not rep.ok
    assert rep.findings and all(f.rule == "costmodel-drift"
                                for f in rep.findings)
    assert "2.00x" in str(rep.findings[0])
