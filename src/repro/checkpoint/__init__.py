from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_step,
                                         manifest_extra, restore, save)
