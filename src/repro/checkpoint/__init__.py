from repro.checkpoint.checkpoint import (AsyncCheckpointer,
                                         CheckpointWriteError, latest_step,
                                         manifest_extra, restore, save)
