from repro.checkpoint.checkpoint import (AsyncCheckpointer,
                                         CheckpointWriteError, latest_step,
                                         list_steps, manifest_extra, restore,
                                         save)
