"""Sharded checkpointing with elastic resharding and async save.

Layout (one directory per step)::

    <root>/step_000100.tmp/       # written first
        manifest.json             # tree structure, shapes, dtypes, step
        leaf_00000.npy ...        # one file per pytree leaf
    <root>/step_000100/           # atomic rename == commit

Restore may target a DIFFERENT mesh than the save (elastic up/down-scaling):
leaves are read on host and ``jax.device_put`` re-shards them to the
requested sharding tree.  On a real multi-host pod each host writes only its
addressable shards (per-shard files keyed by shard index — the layout keeps a
``shards`` field for that; in this single-process container every leaf has
one shard).

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes in a background thread so the train step is never blocked on disk.
"""

from __future__ import annotations

import json
import os
import shutil
import sys
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


class CheckpointWriteError(RuntimeError):
    """A background checkpoint write failed; raised from ``wait()`` / the
    next ``save()`` on the driver thread (``__cause__`` is the original)."""


def _fire(site: str, **info) -> None:
    """Fault-injection hook: consult ``repro.resilience.inject`` only when a
    chaos test already imported it (one sys.modules lookup otherwise)."""
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(root: str, step: int, tree: Params,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous checkpoint write with atomic commit."""
    paths, leaves, _ = _flatten_with_paths(tree)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "path": p, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "shards": 1,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # commit
    return final


def list_steps(root: str) -> List[int]:
    """All committed steps in ``root``, ascending.  Only fully-committed
    checkpoints count (a ``.tmp`` dir from a crashed writer is invisible) —
    this is the model registry's version enumeration: ``save_model``
    versions are checkpoint steps, so the serving layer lists a model
    directory's available versions with one readdir."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(root, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(root: str) -> Optional[int]:
    steps = list_steps(root)
    return steps[-1] if steps else None


def restore(root: str, step: int, like: Params,
            shardings: Optional[Params] = None,
            allow_cast: bool = False) -> Params:
    """Restore into the structure of ``like``; if ``shardings`` (a pytree of
    NamedSharding / None) is given, leaves are placed accordingly — this is
    the elastic-resharding path (the saved mesh is irrelevant).

    A dtype mismatch between a saved leaf and its ``like`` proto raises
    (like shape mismatches always have) — a silent ``astype`` turns a
    float64-trained model restored into a float32 program into a precision
    loss nobody asked for.  ``allow_cast=True`` is the explicit escape
    hatch for elastic restores that intentionally re-precision (e.g. a
    mixed-precision downscale).
    """
    _fire("io_load", source="checkpoint", step=step)
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    paths, like_leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: x is None) if shardings is not None
        else [None] * len(like_leaves))
    out = []
    for p, proto, sh in zip(paths, like_leaves, shard_leaves):
        entry = by_path.get(p)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {p}")
        arr = np.load(os.path.join(d, entry["file"]))
        if list(arr.shape) != list(proto.shape):
            raise ValueError(f"shape mismatch for {p}: {arr.shape} vs "
                             f"{proto.shape}")
        proto_dtype = np.dtype(proto.dtype)
        if arr.dtype != proto_dtype:
            if not allow_cast:
                raise ValueError(
                    f"dtype mismatch for {p}: checkpoint has {arr.dtype}, "
                    f"restore target wants {proto_dtype} (pass "
                    f"allow_cast=True to cast explicitly)")
            arr = arr.astype(proto_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jnp.asarray(arr))
    return treedef.unflatten(out)


def manifest_extra(root: str, step: int) -> Dict[str, Any]:
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)["extra"]


class AsyncCheckpointer:
    """Snapshot-on-call, write-in-background.  ``wait()`` joins the writer
    (call before process exit and before reading the checkpoint back).

    A writer-thread failure (disk full, unwritable root) is captured and
    re-raised — wrapped in :class:`CheckpointWriteError` — from ``wait()``
    or the next ``save()``, whichever comes first; silently swallowing it
    would let training run on believing in checkpoints that do not exist.
    ``last_committed`` only ever advances past a completed atomic commit
    and is read/written under a lock (the writer thread publishes it, the
    train loop polls it).
    """

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None
        self._last_committed: Optional[int] = None

    @property
    def last_committed(self) -> Optional[int]:
        with self._lock:
            return self._last_committed

    def save(self, step: int, tree: Params,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()                 # also re-raises a prior writer failure
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.root, step, host_tree, extra)
                with self._lock:
                    self._last_committed = step
                self._gc()
            except BaseException as exc:    # noqa: BLE001 — published, not
                with self._lock:            # swallowed: re-raised from the
                    self._error = exc       # driver thread in wait()
                return

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise CheckpointWriteError(
                f"background checkpoint write failed: {err}") from err

    def _gc(self) -> None:
        if not os.path.isdir(self.root):
            return
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)
