"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``.

One module per assigned architecture; each exports FULL (the exact published
config) and SMOKE (same family, tiny dims, CPU-runnable).
"""

import importlib

ARCHS = (
    "llava_next_mistral_7b",
    "zamba2_2p7b",
    "gemma2_2b",
    "qwen1p5_0p5b",
    "nemotron_4_15b",
    "yi_9b",
    "grok_1_314b",
    "mixtral_8x7b",
    "seamless_m4t_medium",
    "mamba2_370m",
)

# dashes/dots in CLI ids map to underscores in module names
_ALIASES = {
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "zamba2-2.7b": "zamba2_2p7b",
    "gemma2-2b": "gemma2_2b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "nemotron-4-15b": "nemotron_4_15b",
    "yi-9b": "yi_9b",
    "grok-1-314b": "grok_1_314b",
    "mixtral-8x7b": "mixtral_8x7b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "mamba2-370m": "mamba2_370m",
}


def canonical(arch_id: str) -> str:
    return _ALIASES.get(arch_id, arch_id)


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{canonical(arch_id)}")


def get_config(arch_id: str):
    return _module(arch_id).FULL


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE


def all_arch_ids():
    return list(_ALIASES.keys())
