"""grok-1-314b [moe] — 8-expert top-2 MoE with attention logit softcap.

[hf:xai-org/grok-1; unverified]
64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072; MoE 8e top-2;
attn logit softcap 30 (grok "attn_output_multiplier"-style tanh capping).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    capacity_factor=1.25,
    attn_softcap=30.0,
    final_softcap=30.0,
    mlp_type="geglu",
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    capacity_factor=4.0,  # = n_experts: dropless (decode==teacher-forcing)
    attn_softcap=30.0,
    final_softcap=30.0,
    mlp_type="geglu",
    dtype="float32",
)
