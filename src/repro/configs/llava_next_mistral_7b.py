"""llava-next-mistral-7b [vlm] — Mistral-7B backbone + anyres vision prefix.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000; sliding window 4096
(Mistral-7B-v0.1).  Vision frontend is a STUB: input_specs supplies
precomputed CLIP-ViT-L/14 patch embeddings (dim 1024); anyres tiling at
672x672 gives 576 base + 4x576 tile patches — we use one 576-token tile
(the backbone cost model is unchanged by tile count).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    attn_window=4096,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_dim=1024,
    frontend_tokens=576,
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    attn_window=16,
    mlp_type="swiglu",
    frontend="vision",
    frontend_dim=48,
    frontend_tokens=8,
    dtype="float32",
)
