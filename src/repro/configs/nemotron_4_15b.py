"""nemotron-4-15b [dense] — GQA + squared-ReLU MLP (no gate).

[arXiv:2402.16819; unverified]
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000; squared-ReLU.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_type="relu2",
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    mlp_type="relu2",
    dtype="float32",
)
