"""seamless-m4t-medium [audio] — encoder-decoder, multimodal backbone.

[arXiv:2308.11596; hf]
12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206; enc-dec (12+12); the
speech frontend is a STUB (input_specs supplies precomputed frame embeddings
of dim 1024, i.e. the w2v-BERT output the published model consumes).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=24,          # 12 enc + 12 dec
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    enc_layers=12,
    dec_layers=12,
    mlp_type="gelu",
    frontend="audio",
    frontend_dim=1024,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke",
    family="encdec",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    enc_layers=2,
    dec_layers=2,
    mlp_type="gelu",
    frontend="audio",
    frontend_dim=48,
    dtype="float32",
)
