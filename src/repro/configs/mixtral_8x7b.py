"""mixtral-8x7b [moe] — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000; 8 experts top-2;
sliding window 4096 (SWA).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    capacity_factor=1.25,
    attn_window=4096,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    capacity_factor=4.0,  # = n_experts: dropless (decode==teacher-forcing)
    attn_window=16,
    mlp_type="swiglu",
    dtype="float32",
)
