"""qwen1.5-0.5b [dense] — MHA with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]
24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936; QKV bias; tied embeds.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    mlp_type="swiglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=512,
    qkv_bias=True,
    mlp_type="swiglu",
    tie_embeddings=True,
    dtype="float32",
)
