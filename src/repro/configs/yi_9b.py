"""yi-9b [dense] — llama-architecture GQA.

[arXiv:2403.04652; hf]
48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    mlp_type="swiglu",
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=160,
    vocab_size=256,
    mlp_type="swiglu",
    dtype="float32",
)
