"""mamba2-370m [ssm] — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]
48L d_model=1024 (attn-free) vocab=50280, ssm_state=128, expand=2,
headdim=64 (=> 32 SSD heads), 1 B/C group, chunk 128; tied embeddings
(GPT-NeoX tokenizer vocab rounded to 50280 as published).
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_chunk=128,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_ngroups=1,
    ssm_chunk=16,
    tie_embeddings=True,
    dtype="float32",
)
