"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf]
54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Simplification noted in DESIGN.md: the shared transformer block (one set of
weights, applied every ``share_period=6`` mamba layers => 9 applications)
omits the per-application LoRA deltas and the concatenated-embedding input
of the published model; head_dim 80 = 2560/32.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    mlp_type="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_chunk=128,
    share_period=6,
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=256,
    mlp_type="swiglu",
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_ngroups=1,
    ssm_chunk=16,
    share_period=2,
    dtype="float32",
)
