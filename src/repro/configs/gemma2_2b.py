"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]
26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; sliding window 4096 on
local layers, global every 2nd layer; attn softcap 50, final softcap 30;
sandwich (pre+post) RMSNorm; tied embeddings; head_dim 256.
"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_window=4096,
    local_global_period=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_type="swiglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-2b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    attn_window=16,
    local_global_period=2,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_type="swiglu",
    tie_embeddings=True,
    dtype="float32",
)
