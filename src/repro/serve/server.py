"""The predict server: synchronous submit API over a threaded dispatcher.

``PredictServer.submit(name, payload)`` enqueues one request and returns a
:class:`PredictFuture`; a dispatcher (either the background thread started
by ``start()``/``serve_forever()``, or a deterministic synchronous
``pump()`` — what the tests and benchmarks drive) drains the queue, groups
requests by (model, block format), micro-batches each group into the
model's declared geometry buckets (``repro.serve.batching``) and launches
the AOT-warmed predict plan through ``resilience.run_resilient`` — so
plan-level transients retry and OOM walks the fused -> eager -> einsum
ladder exactly as everywhere else in the repo.

Above the plan layer sits the SERVING recovery ladder, provable through
the ``serve_dispatch`` fault site (see ``resilience.inject``):

1. a transient at dispatch retries the whole batched dispatch (bounded by
   the policy's ``max_retries``);
2. anything else — OOM the plan ladder could not absorb, a deterministic
   error, retry exhaustion — SHEDS BATCHING: the batch's requests re-serve
   one by one through unbatched eager ``predict`` at natural geometry, so
   one poisoned request fails alone instead of failing its neighbours;
3. a request that still fails gets the error on its future; the rest of
   the batch completes.

Every request updates the ``serve.stats()`` counters (queue depth, batch
sizes, cache hits, sheds/retries/fallbacks) and the per-request latency
reservoir — the observability loop the ROADMAP's production story needs.
"""

from __future__ import annotations

import dataclasses
import sys
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import tracing as _tracing
from repro.resilience.execute import RetryPolicy, TRANSIENT, run_resilient
from repro.serve import batching as _batching
from repro.serve import stats as _stats
from repro.serve.compilecache import record_cache_outcome
from repro.serve.registry import ModelRegistry, ServedModel


def _fire(site: str, **info) -> None:
    """Fault-injection hook (``serve_dispatch`` site): one sys.modules
    lookup on the clean path, same idiom as ``core.plan``."""
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


class PredictFuture:
    """Handle for one submitted request; ``result()`` blocks until served."""

    __slots__ = ("_event", "_value", "_error", "submitted_at", "latency")

    def __init__(self):
        self._event = threading.Event()
        self._value: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None
        self.submitted_at = time.perf_counter()
        self.latency: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """The ``(r, 1)`` prediction rows for this request (blocks)."""
        if not self._event.wait(timeout):
            raise TimeoutError("prediction not ready")
        if self._error is not None:
            raise self._error
        return self._value

    def _finish(self, value: np.ndarray) -> None:
        self.latency = time.perf_counter() - self.submitted_at
        self._value = value
        _stats.record_latency(self.latency)
        _stats.bump("responses")
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self.latency = time.perf_counter() - self.submitted_at
        self._error = error
        _stats.bump("failures")
        self._event.set()


@dataclasses.dataclass
class _Pending:
    model: ServedModel
    payload: object
    n_rows: int
    fmt: str
    future: PredictFuture


class PredictServer:
    """Micro-batching predict server over a :class:`ModelRegistry`.

    Synchronous API: ``submit`` returns a future, ``pump()`` serves
    everything currently queued (deterministic — what tests drive), and
    ``start()``/``serve_forever()`` run the same loop on a thread for
    concurrent callers.  ``policy`` is the shared
    :class:`~repro.resilience.execute.RetryPolicy` for both the plan
    executions and the dispatch-level transient retry.
    """

    def __init__(self, registry: ModelRegistry,
                 policy: Optional[RetryPolicy] = None,
                 unbatched_fallback: bool = True):
        self.registry = registry
        self.policy = policy or RetryPolicy()
        self.unbatched_fallback = unbatched_fallback
        self._queue: "deque[_Pending]" = deque()
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- request intake ------------------------------------------------------
    def submit(self, name: str, payload, *,
               version: Optional[int] = None) -> PredictFuture:
        """Enqueue one request (rows for ``name``) and return its future.
        Payload validation happens here — a malformed request raises at
        submit instead of poisoning a batch."""
        with _tracing.span("serve.submit", model=name):
            model = self.registry.get(name, version)
            payload, n, fmt = model.normalize(payload)
            pend = _Pending(model=model, payload=payload, n_rows=n, fmt=fmt,
                            future=PredictFuture())
            with self._wake:
                self._queue.append(pend)
                _stats.bump("requests")
                _stats.observe_queue_depth(len(self._queue))
                self._wake.notify()
            return pend.future

    # -- dispatch loop -------------------------------------------------------
    def pump(self) -> int:
        """Serve everything queued right now, synchronously; returns the
        number of requests completed.  The dispatcher thread calls this in
        a loop; tests call it directly for deterministic scheduling."""
        with self._lock:
            pending = list(self._queue)
            self._queue.clear()
            _stats.observe_queue_depth(0)
        if not pending:
            return 0
        groups: Dict[Tuple[int, str], List[_Pending]] = {}
        for p in pending:
            groups.setdefault((id(p.model), p.fmt), []).append(p)
        for (_, fmt), group in groups.items():
            self._dispatch_group(group[0].model, fmt, group)
        return len(pending)

    def serve_forever(self, poll: float = 0.05) -> None:
        """Run the dispatch loop until :meth:`stop` (blocking)."""
        while not self._stop.is_set():
            with self._wake:
                if not self._queue:
                    self._wake.wait(timeout=poll)
            self.pump()

    def start(self) -> "PredictServer":
        """Run :meth:`serve_forever` on a daemon thread."""
        if self._thread is not None and self._thread.is_alive():
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self.serve_forever,
                                        name="repro-serve", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        self._stop.set()
        with self._wake:
            self._wake.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def __enter__(self) -> "PredictServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- serving internals ---------------------------------------------------
    def _dispatch_group(self, model: ServedModel, fmt: str,
                        group: List[_Pending]) -> None:
        """Chunk one (model, format) group by the largest declared bucket
        and serve each chunk batched; oversized single requests go straight
        to the unbatched path (there is no bucket that fits them)."""
        cap = model.spec.max_rows(fmt)
        chunk: List[_Pending] = []
        rows = 0
        for p in group:
            if p.n_rows > cap:
                _stats.bump("bucket_fallbacks")
                self._serve_single(model, [p])
                continue
            if chunk and rows + p.n_rows > cap:
                self._serve_chunk(model, fmt, chunk)
                chunk, rows = [], 0
            chunk.append(p)
            rows += p.n_rows
        if chunk:
            self._serve_chunk(model, fmt, chunk)

    def _serve_chunk(self, model: ServedModel, fmt: str,
                     chunk: List[_Pending]) -> None:
        outs = None
        attempts = 0
        shed = False
        while True:
            try:
                # one span per dispatch ATTEMPT — transient retries each
                # leave their own (error-tagged) span in the trace
                with _tracing.span("serve.dispatch", mode="batched",
                                   model=model.name, requests=len(chunk),
                                   attempt=attempts):
                    _fire("serve_dispatch", mode="batched", model=model.name,
                          requests=len(chunk))
                    outs = self._predict_batched(model, fmt, chunk)
                break
            except Exception as exc:                     # noqa: BLE001
                if self.policy.classify(exc) == TRANSIENT \
                        and attempts < self.policy.max_retries:
                    attempts += 1
                    _stats.bump("dispatch_retries")
                    time.sleep(self.policy.delay(attempts))
                    continue
                if not self.unbatched_fallback:
                    for p in chunk:
                        p.future._fail(exc)
                    return
                shed = True
                break
        if shed:
            _stats.bump("batch_sheds")
        if outs is None:                  # shed OR no bucket fit the batch
            if not shed:
                _stats.bump("bucket_fallbacks")
            self._serve_single(model, chunk)
            return
        _stats.bump("batches")
        _stats.bump("batched_requests", len(chunk))
        for p, rows in zip(chunk, outs):
            p.future._finish(rows)

    def _predict_batched(self, model: ServedModel, fmt: str,
                         chunk: List[_Pending]) -> Optional[List[np.ndarray]]:
        """One padded, bucket-shaped plan launch for the whole chunk ->
        per-request result rows; None when no declared bucket fits (size or
        bcoo nse overflow) and the caller should fall back."""
        total = sum(p.n_rows for p in chunk)
        bucket = model.spec.bucket_for(total, fmt)
        if bucket is None:
            return None
        with _tracing.span("serve.batch", model=model.name,
                           requests=len(chunk), rows=total):
            x = _batching.assemble([p.payload for p in chunk], bucket)
        if x is None:                                   # nse overflow
            return None
        if model.plan_backed:
            plan, warmed = model.cache.plan_for(x, bucket)
            out = run_resilient(plan, policy=self.policy)
            record_cache_outcome(warmed, len(chunk))
        else:
            out = model.estimator.predict(x)
            _stats.bump("eager_requests", len(chunk))
        with _tracing.span("serve.slice", requests=len(chunk)):
            rows = np.asarray(out.collect())
            return _batching.split_rows(rows, [p.n_rows for p in chunk])

    def _serve_single(self, model: ServedModel,
                      chunk: List[_Pending]) -> None:
        """Unbatched fallback: each request served alone at natural
        geometry, transient-retried, failures isolated per request."""
        for p in chunk:
            attempts = 0
            while True:
                try:
                    with _tracing.span("serve.dispatch", mode="single",
                                       model=model.name, requests=1,
                                       attempt=attempts):
                        _fire("serve_dispatch", mode="single",
                              model=model.name, requests=1)
                        rows = model.predict_direct(p.payload)
                    _stats.bump("single_dispatches")
                    p.future._finish(rows)
                    break
                except Exception as exc:                 # noqa: BLE001
                    if self.policy.classify(exc) == TRANSIENT \
                            and attempts < self.policy.max_retries:
                        attempts += 1
                        _stats.bump("dispatch_retries")
                        time.sleep(self.policy.delay(attempts))
                        continue
                    p.future._fail(exc)
                    break
