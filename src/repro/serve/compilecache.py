"""Per-(model, geometry) AOT compilation of predict plans.

D2O's lesson (arXiv 1606.05385) is that a data-object layer pays for itself
when expensive preparation amortizes over many cheap downstream uses; here
the expensive part of a predict request is first-call XLA compilation of
its plan, and the amortization is explicit: at model-LOAD time the cache
records the estimator's predict plan on a representative zero input for
every declared geometry bucket and pushes it through
``Plan.compile_aot()`` — ``jit(body).lower().compile()`` into the shared
structural plan cache — so the FIRST real request of any warmed geometry
replays an existing executable.

Steady-state contract (asserted by ``tests/test_serve.py`` and reported in
``BENCH_serve.json``): across a request stream of warmed geometries,
``plan.cache_stats()`` shows ``opt_runs`` frozen after warmup (every
request's re-recording hits ``_OPT_CACHE``), zero new compiled-cache
misses, and ``serve.stats()["cache_hits"] == requests``.

Estimators that cannot record predict as a plan (``has_predict_plan()``
False — e.g. the forest's host-driven vote or CSVM's host decision) still
get geometry bucketing: ``warm`` runs one eager predict per bucket so
XLA's own jit caches are primed, and dispatch routes through eager
``predict`` at bucket geometry — cold-start is still hidden, there is just
no plan-level cache accounting.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core import plan as _plan
from repro.core.dsarray import DsArray
from repro.serve import stats as _stats
from repro.serve.batching import BucketSpec, GeometryBucket, \
    representative_input


class PredictCompileCache:
    """AOT-warmed predict plans for ONE estimator across its bucket set.

    ``donate_inputs`` marks the request-batch leaf of each warmed plan as
    donatable (``Plan.compile_aot(donate_argnums=...)``): the packed batch
    is a per-request temporary the dispatcher never reuses, so on
    accelerators XLA may alias its HBM for the output.  Model-parameter
    leaves are never donated — they are the fitted state every later
    request re-binds.  CPU ignores donation, so behavior there is
    unchanged.
    """

    def __init__(self, estimator, spec: BucketSpec,
                 donate_inputs: bool = True):
        self.estimator = estimator
        self.spec = spec
        self.donate_inputs = donate_inputs
        self.plan_backed = estimator.has_predict_plan()
        #: bucket -> structural key of the warmed plan (the cache-hit oracle)
        self.warmed_keys: Dict[GeometryBucket, tuple] = {}
        #: bucket -> the warmed Plan (kept for analysis linting / tests)
        self.plans: Dict[GeometryBucket, _plan.Plan] = {}

    def _donate_argnums(self, p: _plan.Plan, x: DsArray) -> tuple:
        """Leaf positions holding the representative batch ``x`` — the only
        buffers a warmed predict executable may consume."""
        if not self.donate_inputs:
            return ()
        return tuple(i for i, leaf in enumerate(p.leaves)
                     if getattr(leaf, "value", None) is x)

    def warm(self) -> int:
        """Record + AOT-compile the predict plan for every declared bucket
        (idempotent).  Returns the number of fresh XLA compilations — a
        steady-state re-warm returns 0."""
        compiled = 0
        for bucket in self.spec.buckets():
            x = representative_input(bucket)
            if not self.plan_backed:
                # no recordable plan: one eager predict primes the jit
                # caches inside the estimator's own predict path
                if bucket not in self.warmed_keys:
                    self.estimator.predict(x)
                    self.warmed_keys[bucket] = ()
                continue
            p = self.estimator.predict_plan(x)
            if p.compile_aot(donate_argnums=self._donate_argnums(p, x)):
                compiled += 1
            self.warmed_keys[bucket] = p.key
            self.plans[bucket] = p
        return compiled

    def plan_for(self, x: DsArray,
                 bucket: GeometryBucket) -> Tuple[Optional[_plan.Plan], bool]:
        """The predict plan for a bucket-shaped batch ``x`` -> ``(plan,
        warmed)``.  ``warmed`` is True when the plan's structural key
        matches the bucket's AOT entry — the per-request cache-hit counter
        the acceptance asserts equals the request count."""
        if not self.plan_backed:
            return None, False
        p = self.estimator.predict_plan(x)
        return p, p.key == self.warmed_keys.get(bucket)

    def warmed_plans(self) -> List[_plan.Plan]:
        """The distinct warmed plans (for ``python -m repro.analysis``)."""
        seen, out = set(), []
        for p in self.plans.values():
            if p.key not in seen:
                seen.add(p.key)
                out.append(p)
        return out


def record_cache_outcome(warmed: bool, n_requests: int) -> None:
    """Account one batched plan dispatch against the serve counters."""
    _stats.bump("cache_hits" if warmed else "cache_misses", n_requests)
