"""Request-level serving observability: counters + latency percentiles.

The observability surface ``resilience.stats()`` established, extended to
the serving loop — and, since the ``repro.obs`` registry landed, a VIEW
over it: every counter here is an ``obs`` Counter registered as
``"serve.<name>"``, the queue watermarks are Gauges, and the bounded
per-request latency reservoir is a Histogram (``"serve.latency_s"``).
``stats()``/``latency_summary()`` keep their exact historical shapes
(key order, plain ints, nearest-rank percentile math), so every test and
benchmark asserting the steady-state contract — ``cache_hits ==
requests`` for in-bucket geometries, zero ``batch_sheds``/
``dispatch_retries`` on clean runs, every recovery path bumping exactly
its own counter under injected faults — reads the same numbers as before
the migration.  All increments take the registry lock: these paths run on
``PredictServer.start()`` worker threads.
"""

from __future__ import annotations

from typing import Dict

from repro.obs import metrics as _metrics

_COUNTER_NAMES = (
    # request lifecycle
    "requests",            # submitted
    "responses",           # completed successfully
    "failures",            # completed with an error
    # micro-batching
    "batches",             # batched dispatches executed
    "batched_requests",    # requests served via a batched dispatch
    "single_dispatches",   # requests served via the unbatched fallback
    # plan-cache discipline (the zero-recompile acceptance)
    "cache_hits",          # requests whose plan hit a warmed compiled entry
    "cache_misses",        # requests whose plan had to compile at serve time
    "eager_requests",      # requests served by estimators without a plan
    # resilience / degradation
    "bucket_fallbacks",    # no declared bucket fit (size or nse overflow)
    "batch_sheds",         # batched dispatch abandoned -> unbatched path
    "dispatch_retries",    # transient serve_dispatch retries
)

_COUNTERS = _metrics.CounterGroup("serve", _COUNTER_NAMES)
_QUEUE_DEPTH = _metrics.registry.gauge("serve.queue_depth")
_QUEUE_PEAK = _metrics.registry.gauge("serve.queue_depth_peak")
_LATENCY = _metrics.registry.histogram("serve.latency_s", maxlen=4096)


def bump(name: str, n: int = 1) -> None:
    _COUNTERS.inc(name, n)


def observe_queue_depth(depth: int) -> None:
    _QUEUE_DEPTH.set(depth)
    _QUEUE_PEAK.set_max(depth)


def record_latency(seconds: float) -> None:
    _LATENCY.observe(seconds)


def latency_summary() -> Dict[str, float]:
    """p50/p99/mean/max over the latency reservoir, in milliseconds."""
    s = _LATENCY.summary(scale=1e3)
    return {"count": s["count"], "p50_ms": s["p50"], "p99_ms": s["p99"],
            "mean_ms": s["mean"], "max_ms": s["max"]}


def stats() -> Dict[str, object]:
    """Counters since the last :func:`reset_stats`, plus the latency
    summary under ``"latency"`` — the serving analogue of
    ``resilience.stats()`` / ``plan.cache_stats()``."""
    out: Dict[str, object] = _COUNTERS.as_dict()
    out["queue_depth"] = _QUEUE_DEPTH.value
    out["queue_depth_peak"] = _QUEUE_PEAK.value
    out["latency"] = latency_summary()
    return out


def reset_stats() -> None:
    _COUNTERS.reset()
    _QUEUE_DEPTH.reset()
    _QUEUE_PEAK.reset()
    _LATENCY.reset()
