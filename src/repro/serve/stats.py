"""Request-level serving observability: counters + latency percentiles.

The observability surface ``resilience.stats()`` established, extended to
the serving loop: module-level counters that stay ALL ZERO until a server
runs, a bounded per-request latency reservoir, and a ``stats()`` snapshot
combining both.  Tests and benchmarks assert the steady-state contract on
these numbers — ``cache_hits == requests`` for in-bucket geometries, zero
``batch_sheds``/``dispatch_retries`` on clean runs, every recovery path
bumping exactly its own counter under injected faults.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict

_COUNTERS = {
    # request lifecycle
    "requests": 0,            # submitted
    "responses": 0,           # completed successfully
    "failures": 0,            # completed with an error
    # micro-batching
    "batches": 0,             # batched dispatches executed
    "batched_requests": 0,    # requests served via a batched dispatch
    "single_dispatches": 0,   # requests served via the unbatched fallback
    # plan-cache discipline (the zero-recompile acceptance)
    "cache_hits": 0,          # requests whose plan hit a warmed compiled entry
    "cache_misses": 0,        # requests whose plan had to compile at serve time
    "eager_requests": 0,      # requests served by estimators without a plan
    # resilience / degradation
    "bucket_fallbacks": 0,    # no declared bucket fit (size or nse overflow)
    "batch_sheds": 0,         # batched dispatch abandoned -> unbatched path
    "dispatch_retries": 0,    # transient serve_dispatch retries
    # queue gauges
    "queue_depth": 0,
    "queue_depth_peak": 0,
}

_LOCK = threading.Lock()
_LATENCIES = deque(maxlen=4096)   # seconds, per completed request


def bump(name: str, n: int = 1) -> None:
    with _LOCK:
        _COUNTERS[name] += n


def observe_queue_depth(depth: int) -> None:
    with _LOCK:
        _COUNTERS["queue_depth"] = depth
        if depth > _COUNTERS["queue_depth_peak"]:
            _COUNTERS["queue_depth_peak"] = depth


def record_latency(seconds: float) -> None:
    with _LOCK:
        _LATENCIES.append(seconds)


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def latency_summary() -> Dict[str, float]:
    """p50/p99/mean/max over the latency reservoir, in milliseconds."""
    with _LOCK:
        vals = sorted(_LATENCIES)
    if not vals:
        return {"count": 0, "p50_ms": 0.0, "p99_ms": 0.0,
                "mean_ms": 0.0, "max_ms": 0.0}
    return {
        "count": len(vals),
        "p50_ms": _percentile(vals, 0.50) * 1e3,
        "p99_ms": _percentile(vals, 0.99) * 1e3,
        "mean_ms": sum(vals) / len(vals) * 1e3,
        "max_ms": vals[-1] * 1e3,
    }


def stats() -> Dict[str, object]:
    """Counters since the last :func:`reset_stats`, plus the latency
    summary under ``"latency"`` — the serving analogue of
    ``resilience.stats()`` / ``plan.cache_stats()``."""
    with _LOCK:
        out: Dict[str, object] = dict(_COUNTERS)
    out["latency"] = latency_summary()
    return out


def reset_stats() -> None:
    with _LOCK:
        for k in _COUNTERS:
            _COUNTERS[k] = 0
        _LATENCIES.clear()
