"""Geometry-bucketed request micro-batching for the predict server.

The plan-cache contract (``core.plan``) keys compiled programs by leaf
GEOMETRY — shape, block grid, dtype, pad state, and (for BCOO) stored-entry
capacity — never by data.  Serving therefore gets zero-recompile
steady-state for free *iff* every dispatched batch lands on one of a small
declared set of geometries.  This module is that quantization:

* a :class:`BucketSpec` declares, per model, the batch-row buckets (and for
  sparse inputs the per-block ``nse`` capacity) predict plans are AOT-warmed
  for at model-load time;
* :func:`assemble` concatenates queued request payloads, pads the tail rows
  with zeros up to the chosen bucket — ``from_array``/``from_scipy`` then
  construct the block tensor with the usual zero edge padding, so the
  result carries ``PAD_ZERO`` and dispatch stays on the fused path — and
  returns a ds-array of EXACTLY the bucket's geometry;
* :func:`split_rows` slices the ``(bucket_rows, 1)`` result back into
  per-request row groups (pad rows are simply dropped).

Exactness note: padding and result-slicing are bitwise-neutral — each
request's served rows are EXACTLY the corresponding rows of
``estimator.predict`` on the padded bucket batch (same compiled program,
same values; pad rows only add exact +0.0 terms).  Equality with a
direct predict of the same rows at a DIFFERENT geometry is a separate,
weaker property: XLA's f32 accumulation can vary with block shape, so it
is structural only when the geometries coincide — which is why ``1``
belongs in ``batch_sizes`` (the default keeps it): a lone request then
serves at its natural ``(1, m)`` geometry, the exact program a direct
single-row ``predict`` runs.  BCOO batches are geometry-stable either
way (per-entry accumulation in index order).

Dense payloads are NumPy ``(r, m)`` arrays; sparse payloads are
scipy.sparse matrices and stay sparse end-to-end (``scipy.sparse.vstack``
-> :func:`repro.core.sparse.from_scipy` at the bucket's fixed ``nse`` —
no densification anywhere).  A batch whose densest block exceeds the
declared ``nse`` capacity must NOT be packed (entries would truncate):
``assemble`` returns ``None`` and the server falls back to unbatched
predicts at natural geometry.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dsarray import DsArray, from_array
from repro.core import sparse as _sparse

FORMAT_DENSE = "dense"
FORMAT_BCOO = "bcoo"

#: default block-row size for bucketed batches — matches the
#: ``BaseEstimator._validate_x`` convention so served and direct predicts
#: share column blocking (one column block of all m features) and differ
#: only in the row count, which per-row ops never observe.
DEFAULT_BLOCK_ROWS = 128


@dataclasses.dataclass(frozen=True)
class GeometryBucket:
    """One declared input geometry: the static half of a predict plan key."""

    rows: int                 # padded batch rows (the plan's n)
    block_rows: int           # row blocking of the batch dimension
    n_features: int
    fmt: str                  # "dense" | "bcoo"
    dtype: str = "float32"
    nse: Optional[int] = None  # bcoo: stored entries per block (capacity)

    def __post_init__(self):
        if self.fmt not in (FORMAT_DENSE, FORMAT_BCOO):
            raise ValueError(f"unknown block format {self.fmt!r}")
        if self.fmt == FORMAT_BCOO and self.nse is None:
            raise ValueError("bcoo buckets need an explicit nse capacity")


class BucketSpec:
    """The declared serving geometries for one model.

    ``batch_sizes`` are the padded batch-row buckets (ascending);
    ``formats`` selects which block formats get warmed plans.  ``nse`` is
    the per-block stored-entry capacity for bcoo buckets — declare it from
    the expected request density (e.g. ``ceil(block_rows * n_features *
    max_density)``); denser batches fall back to unbatched predict.
    """

    def __init__(self, n_features: int,
                 batch_sizes: Sequence[int] = (1, 8, 32),
                 formats: Sequence[str] = (FORMAT_DENSE,),
                 block_rows: Optional[int] = None,
                 dtype: str = "float32",
                 nse: Optional[int] = None):
        if not batch_sizes:
            raise ValueError("need at least one batch-size bucket")
        if any(b <= 0 for b in batch_sizes):
            raise ValueError(f"batch sizes must be positive: {batch_sizes}")
        self.n_features = int(n_features)
        self.batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
        self.formats = tuple(formats)
        self.block_rows = block_rows
        self.dtype = dtype
        self.nse = nse
        for f in self.formats:
            if f not in (FORMAT_DENSE, FORMAT_BCOO):
                raise ValueError(f"unknown block format {f!r}")
        if FORMAT_BCOO in self.formats and nse is None:
            raise ValueError("serving bcoo inputs needs an nse= capacity")

    def _bucket(self, rows: int, fmt: str) -> GeometryBucket:
        br = self.block_rows if self.block_rows is not None \
            else min(rows, DEFAULT_BLOCK_ROWS)
        return GeometryBucket(rows=rows, block_rows=min(br, rows),
                              n_features=self.n_features, fmt=fmt,
                              dtype=self.dtype,
                              nse=self.nse if fmt == FORMAT_BCOO else None)

    def buckets(self) -> List[GeometryBucket]:
        """Every declared geometry (format x batch size) — the warm set."""
        return [self._bucket(b, f) for f in self.formats
                for b in self.batch_sizes]

    def bucket_for(self, rows: int, fmt: str) -> Optional[GeometryBucket]:
        """Smallest declared bucket holding ``rows`` rows of ``fmt`` input
        (the tail-padding target), or None when out of the declared range."""
        if fmt not in self.formats or rows <= 0:
            return None
        for b in self.batch_sizes:
            if rows <= b:
                return self._bucket(b, fmt)
        return None

    def max_rows(self, fmt: str) -> int:
        return self.batch_sizes[-1] if fmt in self.formats else 0


# ---------------------------------------------------------------------------
# Payload normalization
# ---------------------------------------------------------------------------


def payload_format(payload) -> str:
    """``"bcoo"`` for scipy.sparse payloads, ``"dense"`` for array-likes."""
    return FORMAT_BCOO if hasattr(payload, "tocoo") else FORMAT_DENSE


def normalize_payload(payload, n_features: int) -> Tuple[object, int, str]:
    """Validate one request payload -> ``(payload, n_rows, fmt)``.

    Dense: any array-like coerced to a NumPy ``(r, m)`` (a 1-D vector is
    one row).  Sparse: a scipy.sparse matrix, kept sparse.  The feature
    count must match the model's declared geometry — a mismatched request
    fails at submit, not deep inside a batch.
    """
    if payload_format(payload) == FORMAT_BCOO:
        if payload.shape[1] != n_features:
            raise ValueError(
                f"request has {payload.shape[1]} features, model serves "
                f"{n_features}")
        if payload.shape[0] < 1:
            raise ValueError("empty request (0 rows)")
        return payload, int(payload.shape[0]), FORMAT_BCOO
    arr = np.asarray(payload)
    if arr.ndim == 1:
        arr = arr.reshape(1, -1)
    if arr.ndim != 2 or arr.shape[1] != n_features:
        raise ValueError(
            f"request shape {arr.shape} does not match (r, {n_features})")
    if arr.shape[0] < 1:
        raise ValueError("empty request (0 rows)")
    return arr, int(arr.shape[0]), FORMAT_DENSE


# ---------------------------------------------------------------------------
# Batch assembly / result splitting
# ---------------------------------------------------------------------------


def representative_input(bucket: GeometryBucket) -> DsArray:
    """An all-zero ds-array of exactly the bucket's geometry — what the
    compile cache records + AOT-compiles the predict plan on at warm time.
    Plan keys never include leaf data, so the zero warm input and every
    real request batch share one compiled program."""
    if bucket.fmt == FORMAT_DENSE:
        z = np.zeros((bucket.rows, bucket.n_features), dtype=bucket.dtype)
        return from_array(jnp.asarray(z), (bucket.block_rows,
                                           bucket.n_features))
    import scipy.sparse as sp
    empty = sp.csr_matrix((bucket.rows, bucket.n_features),
                          dtype=np.dtype(bucket.dtype))
    return _sparse.from_scipy(empty, (bucket.block_rows, bucket.n_features),
                              nse=bucket.nse)


def assemble(payloads: Sequence, bucket: GeometryBucket) -> Optional[DsArray]:
    """Concatenate request payloads, pad the tail to the bucket's rows, and
    build the ds-array at the bucket's exact geometry.  Returns None when a
    bcoo batch's densest block exceeds the bucket's ``nse`` capacity (the
    caller falls back; packing would silently drop entries)."""
    total = sum(int(p.shape[0]) for p in payloads)
    if total > bucket.rows:
        raise ValueError(f"{total} rows exceed the {bucket.rows}-row bucket")
    pad = bucket.rows - total
    dt = np.dtype(bucket.dtype)
    if bucket.fmt == FORMAT_DENSE:
        parts = [np.asarray(p, dtype=dt) for p in payloads]
        if pad:
            parts.append(np.zeros((pad, bucket.n_features), dtype=dt))
        batch = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        return from_array(jnp.asarray(batch),
                          (bucket.block_rows, bucket.n_features))
    import scipy.sparse as sp
    mats = [p.astype(dt, copy=False) for p in payloads]
    if pad:
        mats.append(sp.csr_matrix((pad, bucket.n_features), dtype=dt))
    batch = mats[0] if len(mats) == 1 else sp.vstack(mats)
    shape = (bucket.block_rows, bucket.n_features)
    if _sparse.max_block_nnz(batch, shape) > bucket.nse:
        return None
    # capacity just verified above — skip from_scipy's own overflow guard
    return _sparse.from_scipy(batch, shape, nse=bucket.nse, check_nse=False)


def split_rows(rows: np.ndarray, sizes: Sequence[int]) -> List[np.ndarray]:
    """Slice the collected ``(bucket_rows, 1)`` prediction column back into
    per-request results; trailing pad rows fall off the end."""
    out, off = [], 0
    for s in sizes:
        out.append(np.asarray(rows[off:off + s]))
        off += s
    return out
