"""repro.serve: low-latency predict serving over the plan layer.

The fit side of the estimator subsystem is throughput work; serving is
latency work.  This package closes the gap with four pieces, each leaning
on machinery the repo already has:

* :class:`ModelRegistry` (``registry``) — named + versioned fitted models,
  loaded from ``save_model`` checkpoints, params pinned on device;
* :class:`~repro.serve.compilecache.PredictCompileCache`
  (``compilecache``) — per-(model, geometry) AOT compilation of predict
  plans at model-load time, so steady-state serving replays warmed
  executables with zero XLA recompiles;
* ``batching`` — request micro-batching into declared geometry buckets:
  payloads concatenate along the block-aligned batch dim, tails pad with
  zeros, results slice back per request (dense and BCOO, no densifying);
* :class:`PredictServer` (``server``) — submit/pump/serve_forever dispatch
  that routes plan launches through ``resilience.run_resilient``, degrades
  batched -> unbatched under injected ``serve_dispatch`` faults, and feeds
  the :func:`stats` counters + latency percentiles.

    reg = ModelRegistry()
    reg.register("ridge", fitted, batch_sizes=(1, 8, 32))
    srv = PredictServer(reg)
    fut = srv.submit("ridge", rows)      # (r, n_features) ndarray or scipy
    srv.pump()                           # or srv.start() for a thread
    y = fut.result()                     # (r, 1), exact vs direct predict
"""

from repro.serve.batching import (BucketSpec, FORMAT_BCOO, FORMAT_DENSE,
                                  GeometryBucket)
from repro.serve.compilecache import PredictCompileCache
from repro.serve.registry import ModelRegistry, ServedModel
from repro.serve.server import PredictFuture, PredictServer
from repro.serve.stats import latency_summary, reset_stats, stats

__all__ = [
    "BucketSpec",
    "FORMAT_BCOO",
    "FORMAT_DENSE",
    "GeometryBucket",
    "ModelRegistry",
    "PredictCompileCache",
    "PredictFuture",
    "PredictServer",
    "ServedModel",
    "latency_summary",
    "reset_stats",
    "stats",
]
