"""Named + versioned model registry for the predict server.

``fit`` happens somewhere with time to spare; ``serve`` happens millions of
times with a latency budget.  The registry is the seam between them: it
loads fitted estimators from ``BaseEstimator.save_model`` manifests (the
``repro-model-v1`` checkpoint format — registry dispatch reconstructs the
concrete class from the manifest, versions are checkpoint steps), pins
their fitted parameters on device, declares the geometry buckets each model
serves, and AOT-warms every (model, bucket) predict plan through
:mod:`repro.serve.compilecache` so the server never pays load-time work on
a request.

``register`` serves an already-fitted in-process estimator; ``load`` goes
through the checkpoint manifest.  Both return the :class:`ServedModel`
handle the server dispatches on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import checkpoint as _ckpt
from repro.core import sparse as _sparse
from repro.core.dsarray import from_array
from repro.serve.batching import BucketSpec, FORMAT_DENSE, normalize_payload
from repro.serve.compilecache import PredictCompileCache


def _infer_n_features(est) -> Optional[int]:
    """Feature count from the fitted state, for specs that omit it."""
    n = getattr(est, "n_features_in_", 0)
    if n:
        return int(n)
    coef = getattr(est, "coef_", None)
    if coef is not None:
        return int(np.asarray(coef).shape[0])
    edges = getattr(est, "edges_", None)          # forest: (m, bins-1)
    if edges is not None:
        return int(np.asarray(edges).shape[0])
    sv = getattr(est, "sv_", None)                # csvm: (k, m)
    if sv is not None:
        return int(np.asarray(sv).shape[1])
    return None


def _pin_device(est) -> None:
    """Commit the fitted jax-array state to device and wait for it, so the
    first request never overlaps a lazy host->device transfer."""
    for k, v in est._fitted_state().items():
        if isinstance(v, jax.Array):
            setattr(est, k, jax.block_until_ready(jax.device_put(v)))


@dataclasses.dataclass
class ServedModel:
    """One (name, version) entry: estimator + geometry spec + warm cache."""

    name: str
    version: int
    estimator: object
    spec: BucketSpec
    cache: PredictCompileCache

    @property
    def plan_backed(self) -> bool:
        return self.cache.plan_backed

    def normalize(self, payload) -> Tuple[object, int, str]:
        return normalize_payload(payload, self.spec.n_features)

    def predict_direct(self, payload) -> np.ndarray:
        """Unbatched predict of ONE request payload at natural geometry —
        the shed-batching fallback and the out-of-bucket path.  Collects to
        a host ``(r, 1)`` array, exactly what ``estimator.predict`` on the
        same rows returns."""
        payload, n, fmt = self.normalize(payload)
        if fmt == FORMAT_DENSE:
            x = payload
        else:
            x = _sparse.from_scipy(
                payload, (min(n, 128) or 1, self.spec.n_features))
        return np.asarray(self.estimator.predict(x).collect())


class ModelRegistry:
    """Name -> version -> :class:`ServedModel`, with AOT warm on entry."""

    def __init__(self):
        self._models: Dict[str, Dict[int, ServedModel]] = {}

    # -- registration --------------------------------------------------------
    def register(self, name: str, estimator, *,
                 version: int = 0,
                 batch_sizes: Sequence[int] = (1, 8, 32),
                 formats: Sequence[str] = (FORMAT_DENSE,),
                 n_features: Optional[int] = None,
                 block_rows: Optional[int] = None,
                 dtype: str = "float32",
                 nse: Optional[int] = None,
                 warm: bool = True) -> ServedModel:
        """Serve a fitted estimator under ``name``/``version``.

        Declares the geometry buckets (``batch_sizes`` x ``formats``; bcoo
        needs ``nse``), pins fitted params on device, and (by default)
        warms the per-bucket AOT predict plans right here — model load is
        where compilation cost belongs, not the first request.
        """
        if n_features is None:
            n_features = _infer_n_features(estimator)
        if n_features is None:
            raise ValueError(
                f"cannot infer n_features for {type(estimator).__name__}; "
                "pass n_features= explicitly")
        spec = BucketSpec(n_features, batch_sizes=batch_sizes,
                          formats=formats, block_rows=block_rows,
                          dtype=dtype, nse=nse)
        _pin_device(estimator)
        model = ServedModel(name=name, version=int(version),
                            estimator=estimator, spec=spec,
                            cache=PredictCompileCache(estimator, spec))
        if warm:
            model.cache.warm()
        self._models.setdefault(name, {})[int(version)] = model
        return model

    def load(self, name: str, directory: str, *,
             version: Optional[int] = None, **spec_kw) -> ServedModel:
        """Load a ``save_model`` checkpoint and serve it.  ``version=None``
        serves the newest committed version in the directory; the registry
        entry keeps the on-disk version number either way."""
        from repro.estimators import load_model
        if version is None:
            steps = _ckpt.list_steps(directory)
            if not steps:
                raise FileNotFoundError(f"no model checkpoint in {directory!r}")
            version = steps[-1]
        est = load_model(directory, version=version)
        return self.register(name, est, version=version, **spec_kw)

    # -- lookup --------------------------------------------------------------
    def get(self, name: str, version: Optional[int] = None) -> ServedModel:
        """The served model for ``name`` (newest version by default)."""
        versions = self._models.get(name)
        if not versions:
            raise KeyError(f"no model registered under {name!r}")
        if version is None:
            return versions[max(versions)]
        if version not in versions:
            raise KeyError(
                f"model {name!r} has versions {sorted(versions)}, "
                f"not {version}")
        return versions[version]

    def versions(self, name: str) -> List[int]:
        return sorted(self._models.get(name, {}))

    def models(self) -> List[Tuple[str, int]]:
        """Every (name, version) pair currently registered."""
        return [(n, v) for n, vs in sorted(self._models.items())
                for v in sorted(vs)]

    def warm_all(self) -> int:
        """(Re-)warm every registered model; returns fresh compilations."""
        return sum(m.cache.warm() for _, vs in self._models.items()
                   for m in vs.values())

    def warmed_plans(self) -> List:
        """Distinct warmed predict plans across the registry (the analysis
        CLI's served-predict scenario lints exactly these)."""
        seen, out = set(), []
        for _, vs in sorted(self._models.items()):
            for v in sorted(vs):
                for p in vs[v].cache.warmed_plans():
                    if p.key not in seen:
                        seen.add(p.key)
                        out.append(p)
        return out
