from repro.data.pipeline import (Batch, PipelineConfig, SyntheticPipeline,
                                 pipeline_for_model)
