"""Deterministic, sharded, synthetic data pipeline (ds-array-backed).

Paper alignment: dislib "loads data in parallel … Subsets are not stored in
local memory but remotely" (§3.2.1).  Here every global batch is generated
SPMD-sharded — each device materializes only its own (B/dp, S) block, exactly
the ds-array creation discipline (one task per block; see
``DsArray.random_array``).  ``as_dsarray`` exposes the batch as a ds-array so
the algorithm layer (K-means/ALS over activations etc.) composes.

Determinism/fault tolerance: batch ``i`` depends only on (seed, i), so
restart-at-step-k needs no replay — the cursor is one integer in the
checkpoint.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dsarray import DsArray, from_array
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab_size: int = 256
    frontend: str = "none"          # none | vision | audio
    frontend_dim: int = 0
    frontend_tokens: int = 0


import functools


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["tokens", "labels", "patches"],
                   meta_fields=[])
@dataclasses.dataclass
class Batch:
    tokens: jnp.ndarray                    # (B, S) int32
    labels: jnp.ndarray                    # (B, S) int32  (next-token)
    patches: Optional[jnp.ndarray] = None  # (B, P, F) frontend embeddings

    def as_dsarray(self, block_rows: Optional[int] = None) -> DsArray:
        br = block_rows or max(1, self.tokens.shape[0] // 8)
        return from_array(self.tokens, (br, self.tokens.shape[1]))


def _gen_batch(key, cfg: PipelineConfig) -> Batch:
    """Markov-ish synthetic tokens: mixes a random walk with noise so the
    next-token task is learnable (loss visibly decreases in the examples)."""
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    base = jax.random.randint(k1, (b, 1), 0, v, jnp.int32)
    steps = jax.random.randint(k2, (b, s), -3, 4, jnp.int32)
    tokens = jnp.mod(base + jnp.cumsum(steps, axis=1), v)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    patches = None
    if cfg.frontend != "none":
        patches = jax.random.normal(
            k3, (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    return Batch(tokens=tokens, labels=labels, patches=patches)


class SyntheticPipeline:
    """Stateless-per-step pipeline; ``state`` is just the step cursor."""

    def __init__(self, cfg: PipelineConfig, mesh: Optional[Mesh] = None,
                 dp_axes: Tuple[str, ...] = ("data",)):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        out_shardings = None
        if mesh is not None:
            spec2 = NamedSharding(mesh, P(dp_axes, None))
            spec3 = NamedSharding(mesh, P(dp_axes, None, None))
            out_shardings = Batch(
                tokens=spec2, labels=spec2,
                patches=spec3 if cfg.frontend != "none" else None)
        self._gen = jax.jit(lambda k: _gen_batch(k, cfg),
                            out_shardings=out_shardings) \
            if mesh is not None else jax.jit(lambda k: _gen_batch(k, cfg))

    def batch_at(self, step: int) -> Batch:
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), step)
        return self._gen(key)

    def iterate(self, start_step: int = 0) -> Iterator[Tuple[int, Batch]]:
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1


def pipeline_for_model(mcfg: ModelConfig, global_batch: int, seq_len: int,
                       mesh: Optional[Mesh] = None,
                       dp_axes: Tuple[str, ...] = ("data",),
                       seed: int = 0) -> SyntheticPipeline:
    ft = mcfg.frontend
    f_tokens = mcfg.frontend_tokens
    if ft == "audio":
        f_tokens = seq_len  # encoder frames track the shape cell's seq_len
        seq_len = min(seq_len, 4096)  # decoder text length (DESIGN.md note)
    if ft == "vision":
        seq_len = max(8, seq_len - f_tokens)  # patch prefix + text = cell seq
    pcfg = PipelineConfig(seed=seed, global_batch=global_batch,
                          seq_len=seq_len, vocab_size=mcfg.vocab_size,
                          frontend=ft, frontend_dim=mcfg.frontend_dim,
                          frontend_tokens=f_tokens)
    return SyntheticPipeline(pcfg, mesh, dp_axes)
