"""Training step builder: loss → grad → clip → optimizer, with optional
gradient accumulation (microbatching) and activation sharding env.

The returned step is a pure function (state, batch) -> (state, metrics) and
is what launch/dryrun.py lowers for the roofline.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import Batch
from repro.models import common as cm
from repro.models.model import Model


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["params", "opt_state"], meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any

    @property
    def step(self):
        return self.opt_state["count"]


def init_state(model: Model, optimizer, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt_state=optimizer.init(params))


def make_train_step(model: Model, optimizer, env: cm.ShardEnv = cm.NO_SHARD,
                    accum_steps: int = 1, banded: bool = True,
                    accum_dtype: str = "float32"
                    ) -> Callable[[TrainState, Batch],
                                  Tuple[TrainState, Dict[str, jnp.ndarray]]]:

    def loss_fn(params, batch: Batch):
        return model.loss(params, batch.tokens, batch.labels, batch.patches,
                          env, banded)

    def train_step(state: TrainState, batch: Batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            b = batch.tokens.shape[0]
            assert b % accum_steps == 0

            def reshape(x):
                return (x.reshape((accum_steps, b // accum_steps)
                                  + x.shape[1:]) if x is not None else None)

            micro = jax.tree_util.tree_map(reshape, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                return (loss_acc + l, jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(a.dtype), grad_acc, g)), None

            adt = jnp.dtype(accum_dtype)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, adt), state.params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zeros), micro)
            inv = 1.0 / accum_steps
            loss = loss * inv
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        new_params, new_opt, metrics = optimizer.update(
            grads, state.opt_state, state.params)
        return (TrainState(params=new_params, opt_state=new_opt),
                {"loss": loss, **metrics})

    return train_step
