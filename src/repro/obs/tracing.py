"""Structured tracing: spans, Chrome trace-event export, summary tree.

Design center: **zero overhead when disabled**.  Tracing is off by
default; ``span(...)`` then returns one shared :data:`_NULL_SPAN`
singleton — no object allocation, no clock read, no lock — so the plan
launch hot path pays a single module-flag check (the same discipline as
``plan._fire``'s one-dict-lookup fault hook).  The overhead-guard test
asserts this literally: a 100-launch hot loop with tracing off leaves the
span-allocation counter at exactly 0.

When enabled, each ``with span(name, **attrs):`` block records one Chrome
trace-event "complete" record (``ph: "X"`` — name, microsecond ``ts`` /
``dur``, pid/tid, ``args``) into a lock-protected buffer.  Instrumented
call sites additionally fence device work (``jax.block_until_ready``)
*inside* their spans — only on the enabled path — so a span over a plan
launch measures execution, not async dispatch.

Exports: :func:`trace_to` writes the events captured inside its block as
a ``{"traceEvents": [...]}`` JSON file loadable by ``chrome://tracing`` /
Perfetto; :func:`summary` renders an aggregated tree over the
dot-separated span namespace (``plan.launch``, ``serve.dispatch``...).

Imports nothing from ``repro`` — every subsystem imports this module.
"""

from __future__ import annotations

import contextlib
import functools
import json
import os
import threading
import time
from typing import Dict, List, Optional

_lock = threading.Lock()
_enabled = False
_events: List[dict] = []       # finished Chrome "X" records, append-only
_span_allocs = 0               # Span objects created since last clear()
_MAX_EVENTS = 1_000_000        # hard buffer bound; beyond it, events drop
_dropped = 0


def enabled() -> bool:
    """True while spans are being recorded (the one flag hot paths check)."""
    return _enabled


def enable() -> None:
    global _enabled
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def span_allocations() -> int:
    """Span objects allocated since the last :func:`clear` — the
    disabled-overhead guard asserts this stays 0 with tracing off."""
    return _span_allocs


def clear() -> None:
    """Drop all buffered events and zero the allocation counter."""
    global _span_allocs, _dropped
    with _lock:
        _events.clear()
        _span_allocs = 0
        _dropped = 0


def events() -> List[dict]:
    """A snapshot copy of the buffered trace events."""
    with _lock:
        return list(_events)


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return repr(v)


class _NullSpan:
    """The disabled path: one shared, stateless, allocation-free span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    """One timed block -> one Chrome "X" event.  Only ever constructed on
    the enabled path; ``set(**attrs)`` attaches late-known attributes
    (e.g. cache-hit status discovered mid-block)."""

    __slots__ = ("name", "args", "_t0")

    def __init__(self, name: str, args: Optional[dict] = None):
        global _span_allocs
        _span_allocs += 1
        self.name = name
        self.args = args or {}
        self._t0 = 0

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0
        evt = {
            "name": self.name,
            "cat": self.name.split(".", 1)[0],
            "ph": "X",
            "ts": self._t0 / 1e3,          # microseconds, trace-event unit
            "dur": dur_ns / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        if self.args:
            evt["args"] = {k: _jsonable(v) for k, v in self.args.items()}
        global _dropped
        with _lock:
            if _enabled:
                if len(_events) < _MAX_EVENTS:
                    _events.append(evt)
                else:
                    _dropped += 1
        return False


def span(name: str, **attrs):
    """``with span("plan.launch", plan=key): ...`` — records one trace
    event when tracing is enabled, returns the shared no-op singleton
    otherwise."""
    if not _enabled:
        return _NULL_SPAN
    return Span(name, attrs)


def traced(fn=None, *, name: Optional[str] = None, **static_attrs):
    """Decorator form: ``@traced`` or ``@traced(name="ingest.load")``.
    The disabled path is a flag check + direct call — no span object."""
    def deco(f):
        label = name or f"{f.__module__.rsplit('.', 1)[-1]}.{f.__qualname__}"

        @functools.wraps(f)
        def wrapper(*a, **kw):
            if not _enabled:
                return f(*a, **kw)
            with Span(label, dict(static_attrs)):
                return f(*a, **kw)
        return wrapper
    if fn is not None:                       # bare @traced
        return deco(fn)
    return deco


@contextlib.contextmanager
def trace_to(path: str):
    """Enable tracing for the block, then write the events captured inside
    it to ``path`` as Chrome trace-event JSON (``chrome://tracing`` /
    Perfetto load it directly).  Nesting under an already-enabled tracer
    captures the inner window without disabling the outer one."""
    was_enabled = _enabled
    with _lock:
        start = len(_events)
    enable()
    try:
        yield
    finally:
        if not was_enabled:
            disable()
        with _lock:
            captured = list(_events[start:])
        with open(path, "w") as f:
            json.dump({"traceEvents": captured, "displayTimeUnit": "ms"},
                      f, indent=1)


# ---------------------------------------------------------------------------
# Terminal summary tree
# ---------------------------------------------------------------------------


def summary(evts: Optional[List[dict]] = None) -> str:
    """Aggregate spans by their dot-separated names into a tree::

        plan                    12x     38.21ms
          launch                10x     33.90ms
          optimize               2x      4.31ms

    Parent rows aggregate their subtree (a bare ``plan`` span and the
    rollup of ``plan.*`` children both land on the ``plan`` row)."""
    if evts is None:
        evts = events()
    agg: Dict[tuple, List[float]] = {}     # name-path -> [count, total_us]
    for e in evts:
        parts = tuple(e["name"].split("."))
        dur = float(e.get("dur", 0.0))
        for i in range(1, len(parts) + 1):
            node = agg.setdefault(parts[:i], [0, 0.0])
            if i == len(parts):
                node[0] += 1
            node[1] += dur
    if not agg:
        return "(no spans recorded)"
    lines = []
    for path in sorted(agg):
        count, total_us = agg[path]
        label = "  " * (len(path) - 1) + path[-1]
        n = count if count else sum(
            agg[p][0] for p in agg if p[:len(path)] == path)
        lines.append(f"{label:<32}{n:>6}x{total_us / 1e3:>12.2f}ms")
    if _dropped:
        lines.append(f"(+{_dropped} events dropped at buffer bound)")
    return "\n".join(lines)
