"""``repro.obs`` — unified telemetry: tracing, metrics, profiling.

One import surface over three concerns:

* **tracing** (:mod:`repro.obs.tracing`) — ``span``/``traced``,
  off-by-default and allocation-free while off; ``trace_to(path)`` exports
  Chrome trace-event JSON, ``summary()`` renders the aggregated tree.
* **metrics** (:mod:`repro.obs.metrics`) — the process-wide
  :data:`registry` of Counter/Gauge/Histogram objects that
  ``plan.cache_stats()``, ``resilience.stats()`` and ``serve.stats()``
  are now views over; ``snapshot()``/``reset_all()`` replace hand-resetting
  three modules.
* **profiling** (:mod:`repro.obs.profiler`) — ``profile(plan)`` pairs each
  plan node's measured wall time and bytes against the ``costmodel`` laws
  (the data behind the ``costmodel-drift`` analysis rule).

``tracing`` and ``metrics`` import nothing from ``repro`` (everything
imports *them*); the profiler pulls in ``core``/``analysis`` machinery, so
it is loaded lazily on first :func:`profile` call.
"""

from __future__ import annotations

from repro.obs.metrics import (Counter, CounterGroup, Gauge, Histogram,
                               MetricsRegistry, registry)
from repro.obs.tracing import (Span, clear, disable, enable, enabled,
                               events, span, span_allocations, summary,
                               trace_to, traced)
from repro.obs import tracing as _tracing


def snapshot(prefix=None):
    """Flat ``{dotted_name: value}`` over every registered metric — the
    one call benchmarks embed so perf numbers carry their cache/retry
    discipline."""
    return registry.snapshot(prefix)


def reset_all() -> None:
    """Zero every metric and drop the trace buffer (counters only — plan
    compiled caches are storage, not telemetry, and are left alone)."""
    registry.reset_all()
    _tracing.clear()


def profile(target, **kwargs):
    """Predicted-vs-measured cost report for a plan (or anything coercible
    to one).  See :func:`repro.obs.profiler.profile`."""
    from repro.obs.profiler import profile as _profile
    return _profile(target, **kwargs)


__all__ = [
    "Counter", "CounterGroup", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "clear", "disable", "enable", "enabled", "events", "profile",
    "registry", "reset_all", "snapshot", "span", "span_allocations",
    "summary", "trace_to", "traced",
]
