"""Cost-model-attributed plan profiling: predicted vs measured, per node.

Every optimization decision in the repo — blockwise fusion, liveness
reordering, GEMM backend dispatch, serve bucket padding — is justified by
the ``costmodel`` byte/flop laws, but until now nothing ever checked the
laws against reality.  :func:`profile` closes the loop: it executes a
plan's optimized DAG node by node (the same child-first emission order the
fused body evaluates in, each ``lower`` fenced with ``block_until_ready``)
and pairs, per node,

* **measured wall time** of that node's dispatch;
* **measured bytes** of its actual output buffers (dense stacked tensor
  ``.nbytes``; stacked BCOO ``data.nbytes + indices.nbytes``; scalar
  avals by shape x itemsize);
* **predicted bytes** from the ``costmodel`` laws the liveness analysis
  uses (``analysis.liveness.node_output_bytes`` ->
  ``costmodel.node_live_bytes``).

The report also times the FUSED whole-plan execution (so per-node dispatch
cost vs one-launch cost is visible — the paper's fusion claim, measured)
and, where the backend supports it, attaches the compiled artifact's own
``memory_analysis()`` numbers for the whole program.

Nodes whose measured/predicted ratio falls outside
``costmodel.COSTMODEL_DRIFT_FACTOR`` are *drifting*; the ``costmodel-drift``
analysis rule turns them into findings, making the cost model a checked
contract instead of documentation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import costmodel, expr as _expr, plan as _plan
from repro.core.dsarray import DsArray
from repro.core.expr import ArrayLeaf, Expr, Leaf


def _as_plan(target) -> "_plan.Plan":
    if isinstance(target, _plan.Plan):
        return target
    items = target if isinstance(target, (list, tuple)) else [target]
    roots = []
    for t in items:
        if isinstance(t, (_expr.LazyDsArray, _expr.LazyScalar)):
            roots.append(t.expr)
        elif isinstance(t, Expr):
            roots.append(t)
        elif isinstance(t, DsArray):
            roots.append(_expr.Leaf(t))
        else:
            raise TypeError(f"cannot profile {type(t).__name__}: expected "
                            "a Plan, lazy expression, Expr or DsArray")
    return _plan.Plan(roots)


def _measured_bytes(val) -> int:
    """Actual bytes of one node's output buffers."""
    if isinstance(val, DsArray):
        val = val.blocks
    if hasattr(val, "data") and hasattr(val, "indices"):       # BCOO
        return int(val.data.nbytes) + int(val.indices.nbytes)
    if hasattr(val, "nbytes"):
        return int(val.nbytes)
    return int(np.asarray(val).nbytes)


def _block(val) -> None:
    jax.block_until_ready(val)


@dataclasses.dataclass
class NodeProfile:
    """One plan node's measured-vs-predicted record."""

    site: str                  # "Kind[key]#nID", the analysis site label
    kind: str                  # node class name
    time_s: float              # fenced wall time of this node's dispatch
    measured_bytes: int        # actual output buffer bytes
    predicted_bytes: int       # costmodel law prediction for the same node

    @property
    def ratio(self) -> float:
        if self.predicted_bytes <= 0:
            return float("inf") if self.measured_bytes else 1.0
        return self.measured_bytes / self.predicted_bytes

    def within(self, factor: Optional[float] = None) -> bool:
        return costmodel.costmodel_drift_ok(
            self.predicted_bytes, self.measured_bytes,
            factor if factor is not None
            else costmodel.COSTMODEL_DRIFT_FACTOR)


@dataclasses.dataclass
class ProfileReport:
    """Per-node records + whole-plan timings for one profiled execution."""

    nodes: List[NodeProfile]
    eager_total_s: float                 # sum of per-node dispatch times
    fused_time_s: Optional[float]        # one fenced compiled execution
    compiled: Dict[str, int]             # XLA memory_analysis(), if exposed

    def drifting(self, factor: Optional[float] = None) -> List[NodeProfile]:
        return [n for n in self.nodes if not n.within(factor)]

    def __str__(self) -> str:
        lines = [f"{'node':<44}{'time':>10}{'measured':>14}"
                 f"{'predicted':>14}{'ratio':>8}"]
        for n in self.nodes:
            lines.append(f"{n.site[:43]:<44}{n.time_s * 1e3:>8.2f}ms"
                         f"{n.measured_bytes:>14,}{n.predicted_bytes:>14,}"
                         f"{n.ratio:>8.2f}")
        lines.append(f"per-node total {self.eager_total_s * 1e3:.2f}ms"
                     + (f"; fused {self.fused_time_s * 1e3:.2f}ms"
                        if self.fused_time_s is not None else ""))
        if self.compiled:
            lines.append("compiled: " + ", ".join(
                f"{k}={v:,}" for k, v in self.compiled.items()))
        drift = self.drifting()
        lines.append(f"{len(drift)} node(s) beyond "
                     f"{costmodel.COSTMODEL_DRIFT_FACTOR}x drift tolerance"
                     if drift else "all nodes within drift tolerance")
        return "\n".join(lines)


def _compiled_memory(plan: "_plan.Plan") -> Dict[str, int]:
    """Whole-program memory analysis from the compiled artifact, where the
    backend exposes it (CPU PJRT often does not — then {})."""
    try:
        mem = plan.lowered().compile().memory_analysis()
        out = {}
        for field, key in (
                ("argument_size_in_bytes", "argument_bytes"),
                ("output_size_in_bytes", "output_bytes"),
                ("temp_size_in_bytes", "temp_bytes"),
                ("generated_code_size_in_bytes", "generated_code_bytes")):
            v = getattr(mem, field, None)
            if v is not None:
                out[key] = int(v)
        return out
    except Exception:                                    # noqa: BLE001
        return {}


def profile(target, *, fused: bool = True,
            compiled: bool = True) -> ProfileReport:
    """Predicted-vs-measured cost report for one plan execution.

    ``fused=False`` skips the whole-plan compiled timing, ``compiled=False``
    skips the XLA memory analysis (both cost a compile; the
    ``costmodel-drift`` rule only needs the per-node byte pairs, so it
    passes both off).
    """
    # imported here, not at module top: liveness imports core.plan, which
    # imports repro.obs — the package namespace must finish loading first
    from repro.analysis.liveness import node_output_bytes

    p = _as_plan(target)
    order = _plan.emission_order(p.roots)
    ids = {id(n): f"n{i}" for i, n in enumerate(order)}
    memo: Dict[int, object] = {}
    records: List[NodeProfile] = []
    with _expr.suspend_lazy():
        for node in order:
            if isinstance(node, Leaf):
                memo[id(node)] = node.value
                continue
            if isinstance(node, ArrayLeaf):
                memo[id(node)] = node.value
                continue
            args = [memo[id(c)] for c in node.children]
            t0 = time.perf_counter()
            out = node.lower(*args)
            _block(out)
            dt = time.perf_counter() - t0
            memo[id(node)] = out
            records.append(NodeProfile(
                site=f"{node.describe()}#{ids[id(node)]}",
                kind=type(node).__name__,
                time_s=dt,
                measured_bytes=_measured_bytes(out),
                predicted_bytes=int(node_output_bytes(node))))
    fused_s = None
    if fused:
        p.execute()                      # warm: compile outside the timing
        t0 = time.perf_counter()
        _block(p.execute())
        fused_s = time.perf_counter() - t0
    return ProfileReport(
        nodes=records,
        eager_total_s=sum(r.time_s for r in records),
        fused_time_s=fused_s,
        compiled=_compiled_memory(p) if compiled else {})
