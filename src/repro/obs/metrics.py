"""Typed metrics in one process-wide registry.

The repo grew three disconnected counter dicts — ``plan._STATS``,
``resilience._STATS`` and ``serve.stats._COUNTERS`` — with three reset
conventions and (for resilience) unlocked ``d[k] += 1`` read-modify-writes
reachable from ``PredictServer.start()`` worker threads.  This module is
the single substrate they all migrate onto: :class:`Counter`,
:class:`Gauge` and :class:`Histogram` objects registered by dotted name
(``"plan.hits"``, ``"serve.latency_s"``) in the process-wide
:data:`registry`, every mutation taken under one lock.

Two design constraints carried over from the dicts being replaced:

* the public snapshots (``plan.cache_stats()``, ``resilience.stats()``,
  ``serve.stats()``) must stay bitwise-compatible — :class:`CounterGroup`
  preserves insertion order and plain-``int`` values, and
  :meth:`Histogram.summary` reproduces serve's exact nearest-rank
  percentile math over a bounded ``deque(maxlen=...)`` reservoir;
* this module must import nothing from ``repro`` — ``core.plan``,
  ``resilience.execute``, ``serve.stats`` and ``core.io`` all import it,
  so it sits below everything.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Iterable, List, Optional


class Counter:
    """Monotonic (until reset) integer counter with a locked increment."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Point-in-time value (queue depths, watermarks)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._value = 0
        self._lock = lock

    def set(self, value) -> None:
        with self._lock:
            self._value = value

    def set_max(self, value) -> None:
        """High-watermark update (atomic compare-and-set)."""
        with self._lock:
            if value > self._value:
                self._value = value

    @property
    def value(self):
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Histogram:
    """Bounded-reservoir distribution (the serve latency deque, made a
    type).  ``summary()`` reports nearest-rank percentiles with the exact
    index math ``serve.latency_summary()`` always used, so migrating the
    latency reservoir here changes no reported number."""

    __slots__ = ("name", "_values", "_lock")

    def __init__(self, name: str, lock: threading.Lock, maxlen: int = 4096):
        self.name = name
        self._values: deque = deque(maxlen=maxlen)
        self._lock = lock

    def observe(self, value: float) -> None:
        with self._lock:
            self._values.append(value)

    def values(self) -> List[float]:
        with self._lock:
            return list(self._values)

    @staticmethod
    def _percentile(sorted_vals: List[float], q: float) -> float:
        if not sorted_vals:
            return 0.0
        i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
        return sorted_vals[i]

    def summary(self, scale: float = 1.0) -> Dict[str, float]:
        """``{count, p50, p99, mean, max}`` over the reservoir, each value
        multiplied by ``scale`` (serve passes 1e3 for milliseconds)."""
        with self._lock:
            vals = sorted(self._values)
        if not vals:
            return {"count": 0, "p50": 0.0, "p99": 0.0,
                    "mean": 0.0, "max": 0.0}
        return {
            "count": len(vals),
            "p50": self._percentile(vals, 0.50) * scale,
            "p99": self._percentile(vals, 0.99) * scale,
            "mean": sum(vals) / len(vals) * scale,
            "max": vals[-1] * scale,
        }

    def reset(self) -> None:
        with self._lock:
            self._values.clear()


class MetricsRegistry:
    """All metrics of the process, by dotted name.  ``counter``/``gauge``/
    ``histogram`` are get-or-create (idempotent across reloads and repeated
    ``CounterGroup`` construction); ``snapshot()`` flattens everything into
    one JSON-able dict."""

    def __init__(self):
        self._lock = threading.Lock()     # shared by every metric
        self._metrics: Dict[str, object] = {}

    def _get_or_create(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, self._lock, *args)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str, maxlen: int = 4096) -> Histogram:
        return self._get_or_create(name, Histogram, maxlen)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self, prefix: Optional[str] = None) -> Dict[str, object]:
        """Flat ``{name: value}`` over every registered metric (histograms
        contribute their ``summary()`` dict), optionally filtered to one
        dotted ``prefix`` (``"plan"``, ``"serve"``...)."""
        out: Dict[str, object] = {}
        for name in self.names():
            if prefix and not name.startswith(prefix + "."):
                continue
            m = self._metrics[name]
            out[name] = (m.summary() if isinstance(m, Histogram)
                         else m.value)
        return out

    def reset_all(self, prefix: Optional[str] = None) -> None:
        for name in self.names():
            if prefix and not name.startswith(prefix + "."):
                continue
            self._metrics[name].reset()


#: the process-wide registry every subsystem registers into
registry = MetricsRegistry()


class CounterGroup:
    """An ordered family of counters under one prefix — the migration shim
    for the former module-level ``_STATS`` dicts.  ``inc`` is the locked
    write path (the thread-safety fix for resilience's bare ``+=``);
    ``as_dict()`` reproduces the old ``dict(_STATS)`` snapshot bit for bit,
    insertion order included."""

    __slots__ = ("_names", "_counters")

    def __init__(self, prefix: str, names: Iterable[str],
                 reg: MetricsRegistry = None):
        reg = reg or registry
        self._names = tuple(names)
        self._counters = {n: reg.counter(f"{prefix}.{n}")
                          for n in self._names}

    def inc(self, name: str, n: int = 1) -> None:
        self._counters[name].inc(n)

    def __getitem__(self, name: str) -> int:
        return self._counters[name].value

    def as_dict(self) -> Dict[str, int]:
        return {n: self._counters[n].value for n in self._names}

    def reset(self) -> None:
        for c in self._counters.values():
            c.reset()
