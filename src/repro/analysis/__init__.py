"""Static analysis for ds-array plans and their compiled jaxprs.

Two inspection planes over one :func:`check` entry point:

* **plan plane** — lint rules over the recorded ``Expr`` DAG, before and
  after ``core.plan`` optimization (densify discipline, pad soundness,
  cache-key stability, peak-HBM liveness ordering);
* **jaxpr plane** — rules over the traced/compiled artifact (select-pass
  budgets, full-grid HBM intermediates), generalizing the hand-rolled
  jaxpr assertions the test suite grew in PRs 2-5.

>>> from repro import analysis
>>> analysis.check(plan_or_dsarray).raise_if_failed()

``python -m repro.analysis`` lints the plans behind the examples and
estimator fits (see ``__main__``).
"""

from repro.analysis.api import check, liveness_report
from repro.analysis.findings import (AnalysisError, Finding, Report,
                                     SEVERITIES, severity_rank)
from repro.analysis.graph import PlanView
from repro.analysis.jaxprs import (assert_fused_single_body,
                                   assert_no_densify,
                                   assert_no_global_intermediate,
                                   count_selects,
                                   dense_operand_intermediates,
                                   entry_full_grid_defs, jaxpr_primitives,
                                   rank2_global_intermediates, walk_eqns)
from repro.analysis.liveness import (LivenessReport, minimized_order,
                                     simulate_peak)
from repro.analysis.rules import Rule, all_rule_ids, get_rules, register

__all__ = [
    "check", "liveness_report",
    "AnalysisError", "Finding", "Report", "SEVERITIES", "severity_rank",
    "PlanView",
    "assert_fused_single_body", "assert_no_densify",
    "assert_no_global_intermediate", "count_selects",
    "dense_operand_intermediates", "entry_full_grid_defs",
    "jaxpr_primitives", "rank2_global_intermediates", "walk_eqns",
    "LivenessReport", "minimized_order", "simulate_peak",
    "Rule", "all_rule_ids", "get_rules", "register",
]
