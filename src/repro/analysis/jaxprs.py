"""Canonical jaxpr/HLO inspection helpers.

These were copy-pasted across seven test modules before PR 6; they now live
here so the tests and the analyzer rules share one traversal — a fix to the
walk applies to every consumer at once.  The ``assert_*`` wrappers are the
public test-facing form of the corresponding lint rules.
"""

from __future__ import annotations

from typing import List, Sequence, Set, Tuple

import numpy as np


def _open(jaxpr):
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def walk_eqns(jaxpr):
    """Yield every eqn of a (closed) jaxpr, descending into sub-jaxprs."""
    def visit(jx):
        for eqn in jx.eqns:
            yield eqn
            for v in eqn.params.values():
                for c in (v if isinstance(v, (list, tuple)) else [v]):
                    sub = getattr(c, "jaxpr", None)
                    if sub is not None:
                        yield from visit(sub)

    yield from visit(_open(jaxpr))


def jaxpr_primitives(jaxpr) -> Set[str]:
    """The set of primitive names anywhere in the jaxpr (incl. sub-jaxprs)."""
    return {e.primitive.name for e in walk_eqns(jaxpr)}


def count_selects(jaxpr) -> int:
    """Mask/remask passes in the trace: ``select``/``select_n`` eqns."""
    return sum(1 for e in walk_eqns(jaxpr)
               if e.primitive.name in ("select_n", "select"))


def dense_operand_intermediates(jaxpr, dense_shape) -> List[tuple]:
    """Eqn outputs at least as big as the densified sparse operand whose
    trailing dims are its block shape — the signature of a todense()."""
    gn, gm, bn, bm = dense_shape
    full = gn * gm * bn * bm
    bad = []
    for e in walk_eqns(jaxpr):
        for v in e.outvars:
            shp = tuple(getattr(v.aval, "shape", ()))
            if len(shp) >= 2 and shp[-2:] == (bn, bm) and \
                    int(np.prod(shp)) >= full:
                bad.append((e.primitive.name, shp))
    return bad


def rank2_global_intermediates(jaxpr, n, m, pn, pm) -> List[tuple]:
    """All rank-2 eqn outputs whose extent reaches the global array size.

    The seed paths materialized ``(pn, pm)``/``(n, m)`` tensors; block-native
    ops may only produce tensors that keep grid dims (rank 3/4) or small
    per-axis masks.
    """
    bad = []
    for e in walk_eqns(jaxpr):
        for v in e.outvars:
            shape = tuple(getattr(v.aval, "shape", ()))
            if len(shape) == 2 and shape[0] >= min(n, pn) and \
                    shape[1] >= min(m, pm):
                bad.append((e.primitive.name, shape))
    return bad


def _def_type(line: str) -> str:
    """The type portion of one HLO instruction line (between ``=`` and the
    opcode), handling tuple-typed defs like ``(f32[4,3,8,8]) opt-barrier``."""
    rhs = line.split("=", 1)[1].strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return rhs[:i + 1]
        return rhs
    return rhs.split("(", 1)[0]


def entry_full_grid_defs(compiled_text: str, shape4) -> List[str]:
    """Non-parameter, non-ROOT ENTRY instructions defining a full-grid value.

    The eager chain wrote every intermediate to HBM; a fused plan's ENTRY
    must contain the full-grid shape only as parameters and the ROOT
    fusion — anything else is an intermediate full-grid HBM write.
    """
    marker = "[" + ",".join(str(d) for d in shape4) + "]"
    entry = compiled_text[compiled_text.index("ENTRY"):]
    # ENTRY body ends at the first closing brace at column 0
    body = entry.split("\n}")[0]
    bad = []
    for line in body.splitlines():
        line = line.strip()
        if "=" not in line or marker not in _def_type(line):
            continue
        if "parameter(" in line or line.startswith("ROOT"):
            continue
        bad.append(line)
    return bad


# ---------------------------------------------------------------------------
# Assertion wrappers (the public test-facing form of the lint rules)
# ---------------------------------------------------------------------------


def assert_no_densify(jaxpr, dense_shape, msg: str = "") -> None:
    """Rule ``no-densify``, jaxpr plane: no eqn output shaped like the
    densified form of the ``dense_shape``-blocked sparse operand."""
    bad = dense_operand_intermediates(jaxpr, dense_shape)
    assert not bad, (f"sparse operand densified: {bad}"
                     + (f" ({msg})" if msg else ""))


def assert_no_global_intermediate(jaxpr, n, m, pn, pm) -> None:
    """Rule ``no-full-grid-intermediate``, rank-2 form: no global-extent
    rank-2 tensor anywhere in the trace (block-native ops keep grid dims)."""
    bad = rank2_global_intermediates(jaxpr, n, m, pn, pm)
    assert not bad, f"global-shape intermediates produced: {bad}"


def assert_fused_single_body(plan, shape4) -> None:
    """Rule ``no-full-grid-intermediate`` for a fully-fused plan: one jit
    body (no nested calls) whose compiled ENTRY defines the full-grid shape
    only as parameters and the ROOT fusion."""
    prims = jaxpr_primitives(plan.jaxpr())
    assert "pjit" not in prims and "custom_jvp_call" not in prims, prims
    txt = plan.lowered().compile().as_text()
    bad = entry_full_grid_defs(txt, tuple(shape4))
    assert not bad, f"intermediate full-grid HBM writes in ENTRY: {bad}"
