"""Plan inspection view: one object giving every rule both analysis planes.

``PlanView.of(target)`` coerces whatever the caller has — a ``Plan``, a
``LazyDsArray``/``LazyScalar``, a raw ``Expr``, a concrete ``DsArray`` or a
sequence of any of those — into a :class:`PlanView` holding:

* the **plan plane**: the raw (pre-optimization) roots and the optimized
  DAG, each enumerable in the naive emission order (``plan.emission_order``,
  the exact child-first DFS ``Plan._make_run`` evaluates in), with stable
  per-plan node ids ``n0, n1, ...`` assigned in that order;
* the **jaxpr plane**: the compiled body's jaxpr and (on demand, it costs a
  compile) the optimized-HLO text.

Both artifacts are computed lazily and memoized — rules that only look at
the DAG never pay for tracing or XLA.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core import expr as _expr
from repro.core import plan as _plan
from repro.core.dsarray import DsArray
from repro.core.expr import Expr


class PlanView:
    """Cached inspection facets of one plan (see module docstring)."""

    def __init__(self, plan: "_plan.Plan"):
        self.plan = plan
        self._order: Optional[List[Expr]] = None
        self._raw_order: Optional[List[Expr]] = None
        self._ids: Optional[Dict[int, str]] = None
        self._jaxpr = None
        self._hlo: Optional[str] = None
        self._profile = None

    # -- coercion ------------------------------------------------------------
    @classmethod
    def of(cls, target) -> "PlanView":
        if isinstance(target, cls):
            return target
        if isinstance(target, _plan.Plan):
            return cls(target)
        items = target if isinstance(target, (list, tuple)) else [target]
        roots = []
        for t in items:
            if isinstance(t, (_expr.LazyDsArray, _expr.LazyScalar)):
                roots.append(t.expr)
            elif isinstance(t, Expr):
                roots.append(t)
            elif isinstance(t, DsArray):
                roots.append(_expr.Leaf(t))
            else:
                raise TypeError(
                    f"cannot analyze {type(t).__name__}: expected a Plan, "
                    "lazy expression, Expr or DsArray")
        return cls(_plan.Plan(roots))

    # -- plan plane ----------------------------------------------------------
    @property
    def roots(self) -> List[Expr]:
        return self.plan.roots

    @property
    def raw_roots(self) -> List[Expr]:
        return self.plan.raw_roots

    @property
    def nodes(self) -> List[Expr]:
        """Post-optimization nodes in naive emission order."""
        if self._order is None:
            self._order = _plan.emission_order(self.plan.roots)
        return self._order

    @property
    def raw_nodes(self) -> List[Expr]:
        """Pre-optimization (as-recorded) nodes in emission order."""
        if self._raw_order is None:
            self._raw_order = _plan.emission_order(self.plan.raw_roots)
        return self._raw_order

    def node_id(self, node: Expr) -> str:
        """Stable per-plan id: position in the post-opt emission order."""
        if self._ids is None:
            self._ids = {id(n): f"n{i}" for i, n in enumerate(self.nodes)}
        return self._ids.get(id(node), "n?")

    def label(self, node: Expr) -> str:
        """Stable site label for findings: ``Kind[key]#id``."""
        return f"{node.describe()}#{self.node_id(node)}"

    def consumers(self) -> Dict[int, int]:
        """Consumer-edge counts per post-opt node id() (roots add one use)."""
        counts: Dict[int, int] = {}
        for n in self.nodes:
            for c in n.children:
                counts[id(c)] = counts.get(id(c), 0) + 1
        for r in self.roots:
            counts[id(r)] = counts.get(id(r), 0) + 1
        return counts

    # -- jaxpr / HLO plane ---------------------------------------------------
    def jaxpr(self):
        if self._jaxpr is None:
            self._jaxpr = self.plan.jaxpr()
        return self._jaxpr

    def hlo_text(self) -> str:
        """Optimized HLO of the compiled plan body (costs one XLA compile)."""
        if self._hlo is None:
            self._hlo = self.plan.lowered().compile().as_text()
        return self._hlo

    # -- profile plane -------------------------------------------------------
    def profile(self):
        """Per-node measured-vs-predicted cost records
        (:class:`repro.obs.profiler.ProfileReport`) — costs one per-node
        EXECUTION of the plan, so rules should declare ``"profile"`` in
        ``needs``.  The whole-plan fused timing and XLA memory analysis are
        skipped: the drift check only needs the byte pairs."""
        if self._profile is None:
            from repro.obs.profiler import profile as _profile
            self._profile = _profile(self.plan, fused=False, compiled=False)
        return self._profile
