"""Structured findings for the plan/jaxpr static analyzer.

A :class:`Finding` is one rule hit: the rule id, a severity, the site (a
plan-node label or an HLO/jaxpr description), a human message, and a stable
``token`` used for suppression.  Tokens are deterministic functions of the
rule id + site, so a waiver written against one run keeps matching as long
as the underlying plan structure is unchanged — the analyzer's analogue of
a ``# noqa: <code>`` comment for graphs that have no source lines.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

#: severity ladder; ``fail_on`` thresholds compare by this order.
SEVERITIES: Tuple[str, ...] = ("info", "warn", "error")


def severity_rank(severity: str) -> int:
    try:
        return SEVERITIES.index(severity)
    except ValueError:
        raise ValueError(f"unknown severity {severity!r}; "
                         f"expected one of {SEVERITIES}")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or report) at one site."""

    rule: str            # stable rule id, e.g. "no-densify"
    severity: str        # "info" | "warn" | "error"
    site: str            # node label / eqn primitive / HLO line
    message: str
    data: tuple = ()     # optional structured payload (hashable)

    @property
    def token(self) -> str:
        """Suppression token: pass it to ``check(..., suppress=[token])``
        (or a bare rule id to waive the whole rule)."""
        return f"{self.rule}@{self.site}"

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"[{self.severity}] {self.rule} @ {self.site}: {self.message}"


class Report:
    """The result of one ``analysis.check`` run.

    ``findings`` are the live (unsuppressed) findings; ``suppressed`` the
    waived ones.  ``ok`` is evaluated against the ``fail_on`` severity the
    check ran with: any live finding at or above it fails the report.
    """

    def __init__(self, findings: Sequence[Finding],
                 suppressed: Sequence[Finding] = (),
                 fail_on: str = "error"):
        self.findings: List[Finding] = list(findings)
        self.suppressed: List[Finding] = list(suppressed)
        self.fail_on = fail_on
        severity_rank(fail_on)   # validate eagerly

    def by_rule(self, rule_id: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule_id]

    @property
    def failing(self) -> List[Finding]:
        floor = severity_rank(self.fail_on)
        return [f for f in self.findings
                if severity_rank(f.severity) >= floor]

    @property
    def ok(self) -> bool:
        return not self.failing

    def raise_if_failed(self) -> "Report":
        if not self.ok:
            raise AnalysisError(self)
        return self

    def render(self) -> str:
        lines = []
        for f in self.findings:
            lines.append(str(f))
        for f in self.suppressed:
            lines.append(f"[suppressed] {f.rule} @ {f.site}: {f.message}")
        return "\n".join(lines) if lines else "(no findings)"

    def __iter__(self):
        return iter(self.findings)

    def __len__(self) -> int:
        return len(self.findings)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Report(findings={len(self.findings)}, "
                f"suppressed={len(self.suppressed)}, ok={self.ok})")


class AnalysisError(AssertionError):
    """Raised by ``Report.raise_if_failed`` — an AssertionError so test
    helpers built on the analyzer read as plain assertion failures."""

    def __init__(self, report: Report):
        self.report = report
        super().__init__("static analysis failed:\n" + report.render())


def split_suppressed(findings: Iterable[Finding],
                     suppress: Sequence[str]) -> Tuple[List[Finding],
                                                       List[Finding]]:
    """Partition findings into (live, suppressed).  A suppression entry
    matches a whole rule (``"no-densify"``) or one site token
    (``"no-densify@Blockwise[map]#3"``)."""
    sset = set(suppress)
    live, quiet = [], []
    for f in findings:
        (quiet if (f.rule in sset or f.token in sset) else live).append(f)
    return live, quiet
