"""``analysis.check`` — the one entry point the tests, the CLI and user
code all call."""

from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.findings import Report, split_suppressed
from repro.analysis.graph import PlanView
from repro.analysis.liveness import LivenessReport, analyze
from repro.analysis.rules import get_rules


def check(target, rules: Optional[Sequence[str]] = None,
          fail_on: str = "error",
          suppress: Sequence[str] = ()) -> Report:
    """Run the registered lint rules over a plan (or anything coercible to
    one: a ``Plan``, a lazy array/scalar, an ``Expr``, a ``DsArray``, or a
    sequence of those → one multi-root plan).

    ``rules`` selects rule ids (default: all).  ``fail_on`` sets the
    severity at which ``Report.ok`` flips false ("info" | "warn" |
    "error").  ``suppress`` entries waive a whole rule id or one finding
    token (``"rule@site"``).
    """
    view = PlanView.of(target)
    findings = []
    for rule in get_rules(rules):
        findings.extend(rule.run(view))
    live, quiet = split_suppressed(findings, suppress)
    return Report(live, quiet, fail_on=fail_on)


def liveness_report(target) -> LivenessReport:
    """Naive-vs-minimized peak HBM bytes for one plan (the data behind the
    ``peak-hbm-liveness`` rule, as a structured object)."""
    return analyze(PlanView.of(target).roots)
