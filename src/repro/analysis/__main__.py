"""``python -m repro.analysis`` — lint the plans behind the examples and
estimator fits.

Re-records the lazy plans the example scripts and estimator ``fit`` loops
actually build (fits are captured live via ``plan.capture_plans``), runs
every registered rule over each distinct plan, prints the findings plus the
``peak-hbm-liveness`` naive-vs-minimized numbers, and exits nonzero on any
unsuppressed finding at or above ``--fail-on`` (default: warn — the CI
analysis lane's contract of zero unexplained findings on main).

Waivers live in :data:`WAIVERS`: one suppression token (or rule id) per
entry with a one-line justification, the graph analogue of ``# noqa``.
"""

from __future__ import annotations

import argparse
import sys
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis
from repro.core import from_array, plan as _plan, random_array
from repro.core.io import from_array_auto

#: token (or rule id) -> one-line justification.  Every entry must explain
#: WHY the finding is acceptable; an empty dict means main is clean.
WAIVERS: Dict[str, str] = {
}


def _dedup(plans: List["_plan.Plan"]) -> List["_plan.Plan"]:
    """Distinct plans by structural key (hot loops re-plan one structure)."""
    seen, out = set(), []
    for p in plans:
        if p.key not in seen:
            seen.add(p.key)
            out.append(p)
    return out


def _captured(fit) -> List["_plan.Plan"]:
    with _plan.capture_plans() as caught:
        fit()
    return _dedup(caught)


# -- scenario builders -------------------------------------------------------


def _six_op_chain() -> List["_plan.Plan"]:
    """The PR-3 acceptance chain: 6 elementwise ops fusing to one body."""
    key = jax.random.PRNGKey(0)
    a = from_array(jax.random.normal(key, (64, 48)), (8, 8)).lazy()
    r = (((a + a) * 2.0 - a).abs() * 0.5 + 0.25)
    return [_plan.plan_for(r)]


def _quickstart() -> List["_plan.Plan"]:
    """The lazy mirrors of examples/quickstart.py: the paper's indexing
    expression, gram matmul, and the Fig. 5 column mean."""
    key = jax.random.PRNGKey(1)
    x = random_array(key, shape=(200, 80), block_shape=(50, 20)).lazy()
    w = x[100:180, :40]
    paper_expr = (w.transpose().norm(axis=1) ** 2).sqrt()
    gram = x.transpose() @ x
    col_mean = x.mean(axis=0)
    return [_plan.plan_for(paper_expr),
            _plan.plan_for(gram, col_mean)]


def _linreg_fit() -> List["_plan.Plan"]:
    from repro.estimators import LinearRegression
    rng = np.random.default_rng(2)
    x = from_array(rng.normal(size=(64, 6)).astype(np.float32), (16, 3))
    y = rng.normal(size=(64,)).astype(np.float32)
    return _captured(lambda: LinearRegression().fit(x, y))


def _csvm_fit() -> List["_plan.Plan"]:
    from repro.estimators import CascadeSVM
    rng = np.random.default_rng(3)
    xa = rng.normal(size=(64, 8)).astype(np.float32)
    y = (xa[:, 0] > 0).astype(np.float32)
    x = from_array(xa, (16, 8))
    return _captured(lambda: CascadeSVM(max_iter=1, solver_iters=20,
                                        sv_cap=16).fit(x, y))


def _csvm_sparse_fit() -> List["_plan.Plan"]:
    from repro.estimators import CascadeSVM
    rng = np.random.default_rng(4)
    xa = rng.normal(size=(64, 8)).astype(np.float32)
    xa[rng.random(xa.shape) > 0.2] = 0.0
    y = (xa.sum(axis=1) > 0).astype(np.float32)
    x = from_array_auto(xa, (16, 8), "bcoo")
    return _captured(lambda: CascadeSVM(max_iter=1, solver_iters=20,
                                        sv_cap=16).fit(x, y))


def _kmeans_fit() -> List["_plan.Plan"]:
    from repro.algorithms.kmeans import KMeans
    rng = np.random.default_rng(5)
    x = from_array(rng.normal(size=(64, 4)).astype(np.float32), (16, 4))
    return _captured(lambda: KMeans(n_clusters=3, max_iter=2,
                                    seed=0).fit(x))


def _pca_fit() -> List["_plan.Plan"]:
    from repro.algorithms.linalg import PCA
    rng = np.random.default_rng(6)
    x = from_array(rng.normal(size=(64, 8)).astype(np.float32), (16, 4))
    return _captured(lambda: PCA(n_components=2, n_iter=3, seed=0).fit(x))


def _serve_predict() -> List["_plan.Plan"]:
    """The predict plans the serving registry AOT-warms: a fitted Ridge
    served dense and bcoo across its declared geometry buckets."""
    from repro.estimators import Ridge
    from repro.serve import ModelRegistry
    rng = np.random.default_rng(7)
    xa = rng.normal(size=(64, 8)).astype(np.float32)
    y = (xa @ rng.normal(size=(8, 1))).astype(np.float32)
    est = Ridge(alpha=0.1).fit(from_array(xa, (16, 8)),
                               from_array(y, (16, 1)))
    reg = ModelRegistry()
    try:
        import scipy.sparse  # noqa: F401
        formats, nse = ("dense", "bcoo"), 64
    except ImportError:                                # pragma: no cover
        formats, nse = ("dense",), None
    reg.register("ridge", est, batch_sizes=(8, 32), formats=formats,
                 block_rows=4, nse=nse)
    return _dedup(reg.warmed_plans())


def _ingest_fit() -> List["_plan.Plan"]:
    """A fit on a STREAMED array: write an svmlight file, load it through
    the block-row-streaming loader (sparse x straight into a stacked BCOO,
    the way the paper's CSVM datasets arrive), and lint the plans behind a
    CascadeSVM fit on it — proving ingestion feeds the estimator layer
    without densifying or breaking plan discipline."""
    import os
    import tempfile
    from repro.core.io import load_svmlight_file
    from repro.estimators import CascadeSVM
    rng = np.random.default_rng(8)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "train.svm")
        with open(path, "w") as f:
            for i in range(64):
                feats = rng.choice(8, size=3, replace=False) + 1
                vals = rng.normal(size=3)
                f.write(f"{float(i % 2)} " + " ".join(
                    f"{c}:{v:.5f}" for c, v in sorted(zip(feats, vals)))
                    + "\n")
        x, y = load_svmlight_file(path, (16, 8), n_features=8,
                                  chunk_bytes=256)
    yv = np.asarray(y.collect()).ravel()
    return _captured(lambda: CascadeSVM(max_iter=1, solver_iters=20,
                                        sv_cap=16).fit(x, yv))


def _traced_fit() -> List["_plan.Plan"]:
    """A KMeans fit recorded UNDER TRACING: proves instrumentation changes
    no plan structure (the same rules stay clean on the captured plans —
    including ``costmodel-drift`` at its default tolerance) and that the
    trace itself round-trips as Chrome trace-event JSON with spans in it."""
    import json
    import os
    import tempfile
    from repro import obs
    from repro.algorithms.kmeans import KMeans
    rng = np.random.default_rng(9)
    x = from_array(rng.normal(size=(64, 4)).astype(np.float32), (16, 4))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "trace.json")
        with obs.trace_to(path):
            plans = _captured(lambda: KMeans(n_clusters=3, max_iter=2,
                                             seed=0).fit(x))
        with open(path) as f:
            trace = json.load(f)
    events = trace["traceEvents"]
    assert events, "tracing a KMeans fit produced no spans"
    assert all(e.get("ph") == "X" and "ts" in e and "dur" in e
               for e in events), "malformed trace events"
    return plans


SCENARIOS = [
    ("six-op-chain", _six_op_chain),
    ("quickstart", _quickstart),
    ("linreg-fit", _linreg_fit),
    ("csvm-fit", _csvm_fit),
    ("csvm-sparse-fit", _csvm_sparse_fit),
    ("kmeans-fit", _kmeans_fit),
    ("pca-fit", _pca_fit),
    ("serve-predict", _serve_predict),
    ("ingest-fit", _ingest_fit),
    ("traced-fit", _traced_fit),
]


def iter_plans(names) -> Iterator[Tuple[str, "_plan.Plan"]]:
    for name, build in SCENARIOS:
        if names and name not in names:
            continue
        for i, p in enumerate(build()):
            yield (f"{name}" if i == 0 else f"{name}#{i}"), p


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="lint the plans behind the examples and estimator fits")
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME", help="run one scenario (repeatable); "
                    "known: " + ", ".join(n for n, _ in SCENARIOS))
    ap.add_argument("--fail-on", default="warn",
                    choices=list(analysis.SEVERITIES),
                    help="exit nonzero on findings at/above this severity")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    args = ap.parse_args(argv)
    rules = args.rules.split(",") if args.rules else None

    failed = 0
    for name, p in iter_plans(args.scenario):
        rep = analysis.check(p, rules=rules, fail_on=args.fail_on,
                             suppress=list(WAIVERS))
        live = rep.by_rule("peak-hbm-liveness")
        print(f"== {name}: {len(p.roots)} root(s), "
              f"{p.stats.get('nodes_after', '?')} nodes ==")
        for f in live:
            naive, minimized = f.data[0], f.data[1]
            ratio = naive / minimized if minimized else 1.0
            print(f"   peak HBM: naive={naive:,} minimized={minimized:,} "
                  f"({ratio:.2f}x)")
        for f in rep.findings:
            if f.rule == "peak-hbm-liveness" and f.severity == "info":
                continue
            print(f"   {f}")
        for f in rep.suppressed:
            print(f"   [waived: {WAIVERS.get(f.token) or WAIVERS.get(f.rule)}]"
                  f" {f.rule} @ {f.site}")
        if not rep.ok:
            failed += len(rep.failing)
    if failed:
        print(f"\n{failed} unsuppressed finding(s) at/above "
              f"--fail-on={args.fail_on}", file=sys.stderr)
        return 1
    print("\nall plans clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
