"""Peak-HBM liveness of a plan under a static execution order.

The multi-host ROADMAP item needs exactly what dask's ``order.py`` computes
for its scheduler: a topological order over the task graph that keeps the
live set small, so executing a plan never spikes HBM by holding every
intermediate at once.  This module computes, from the ``costmodel`` byte
laws:

* the live-set peak under the **naive emission order** — the child-first
  DFS ``Plan._make_run`` actually evaluates (``plan.emission_order``);
* a **liveness-minimizing order** via generalized Sethi–Ullman numbering:
  every node is assigned the peak bytes its subtree needs, and the DFS
  visits children in descending need — the child that needs the most space
  runs while the fewest siblings are held.

Plan inputs (leaves) are caller-held for the whole execution, so they are a
constant baseline added to both peaks; the orders differ only in how long
intermediates stay alive.  ``costmodel.liveness_reorder_pays`` says when the
gap is worth acting on (the ``peak-hbm-liveness`` rule flags at >= 2x).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core import plan as _plan
from repro.core.expr import ArrayLeaf, Expr, Leaf, _is_ds, _is_sparse


def _is_input(node: Expr) -> bool:
    return isinstance(node, (Leaf, ArrayLeaf, _plan._Input))


def node_output_bytes(node: Expr) -> int:
    """Resident HBM bytes of one plan node's output, from its meta and the
    ``costmodel`` byte laws (dense stacked tensor / stacked BCOO)."""
    meta = node.meta
    if _is_ds(meta):
        gn, gm, bn, bm = meta.blocks.shape
        e = np.dtype(meta.blocks.dtype).itemsize
        nse = meta.blocks.nse if _is_sparse(meta) else None
        return int(costmodel.node_live_bytes((gn, gm, bn, bm), e, nse=nse))
    return int(np.prod(meta.shape, dtype=np.int64)
               * np.dtype(meta.dtype).itemsize) if meta.shape \
        else np.dtype(meta.dtype).itemsize


def _consumer_edges(nodes: Sequence[Expr],
                    roots: Sequence[Expr]) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for n in nodes:
        for c in n.children:
            counts[id(c)] = counts.get(id(c), 0) + 1
    for r in roots:
        counts[id(r)] = counts.get(id(r), 0) + 1   # outputs stay live
    return counts


def simulate_peak(order: Sequence[Expr],
                  roots: Sequence[Expr]) -> Tuple[int, int]:
    """(peak live bytes, input baseline bytes) of executing ``order``.

    A node's output becomes live when it executes and dies when its last
    consumer has executed; inputs and roots are live throughout.
    """
    remaining = _consumer_edges(order, roots)
    input_bytes = sum(node_output_bytes(n) for n in order if _is_input(n))
    live = input_bytes
    peak = live
    alive: Dict[int, int] = {}
    for n in order:
        if _is_input(n):
            continue
        b = node_output_bytes(n)
        alive[id(n)] = b
        live += b
        peak = max(peak, live)
        for c in n.children:
            remaining[id(c)] -= 1
            if remaining[id(c)] == 0 and id(c) in alive:
                live -= alive.pop(id(c))
    return peak, input_bytes


def minimized_order(roots: Sequence[Expr]) -> List[Expr]:
    """Liveness-minimizing topological order (dask-``order.py`` style).

    Generalized Sethi–Ullman: need(n) = the peak bytes evaluating n's
    subtree requires when its children are evaluated needy-first.  The DFS
    then emits children in descending need.  On DAGs with sharing the
    numbering is a (sound) over-estimate; the emitted order is always a
    valid topological order.
    """
    need: Dict[int, int] = {}

    def compute_need(n: Expr) -> int:
        if id(n) in need:
            return need[id(n)]
        if _is_input(n):
            need[id(n)] = 0            # inputs are part of the baseline
            return 0
        kids = sorted(n.children, key=compute_need, reverse=True)
        held = 0
        peak = 0
        for c in kids:
            peak = max(peak, held + compute_need(c))
            held += 0 if _is_input(c) else node_output_bytes(c)
        need[id(n)] = max(peak, held + node_output_bytes(n))
        return need[id(n)]

    for r in roots:
        compute_need(r)

    out: List[Expr] = []
    seen = set()

    def emit(n: Expr) -> None:
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in sorted(n.children, key=lambda c: need[id(c)], reverse=True):
            emit(c)
        out.append(n)

    for r in sorted(roots, key=lambda r: need[id(r)], reverse=True):
        emit(r)
    return out


@dataclasses.dataclass(frozen=True)
class LivenessReport:
    """Naive-vs-minimized peak live bytes for one plan."""

    naive_peak: int
    minimized_peak: int
    input_bytes: int
    n_nodes: int

    @property
    def ratio(self) -> float:
        return self.naive_peak / self.minimized_peak \
            if self.minimized_peak else 1.0

    @property
    def reorder_pays(self) -> bool:
        return costmodel.liveness_reorder_pays(self.naive_peak,
                                               self.minimized_peak)

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return (f"peak HBM live bytes: naive={self.naive_peak:,} "
                f"minimized={self.minimized_peak:,} "
                f"(ratio {self.ratio:.2f}x, inputs {self.input_bytes:,})")


def analyze(roots: Sequence[Expr]) -> LivenessReport:
    naive = _plan.emission_order(roots)
    naive_peak, input_bytes = simulate_peak(naive, roots)
    ordered = minimized_order(roots)
    min_peak, _ = simulate_peak(ordered, roots)
    # the numbering is a heuristic: never report a "minimized" order that is
    # actually worse than what the runtime already does
    min_peak = min(min_peak, naive_peak)
    return LivenessReport(naive_peak=naive_peak, minimized_peak=min_peak,
                          input_bytes=input_bytes, n_nodes=len(naive))
