"""The lint rules: small classes registered under stable ids.

Each rule inspects one or two planes of a :class:`~repro.analysis.graph.
PlanView` and returns :class:`Finding`s.  Planes are declared via
``needs`` so the driver can report what a rule costs: ``"plan"`` is free
(DAG walk), ``"jaxpr"`` pays one trace, ``"hlo"`` pays one XLA compile.
Rules that would need an expensive plane but can prove from the DAG alone
that nothing can fire skip it (e.g. ``no-densify`` never traces a plan
with no sparse nodes).

Rule ids, one line each:

``no-densify``            sparse values only densify through explicit nodes
``no-full-grid-intermediate``  fused bodies write no extra full-grid HBM defs
``pad-soundness``         claimed pad_state never stronger than derivable
``remask-budget``         select passes stay within the costmodel budget
``recompile-hazard``      recordings whose plan-cache key cannot be stable
``peak-hbm-liveness``     naive vs liveness-minimized peak HBM (info; warn
                          when reordering saves >= 2x)
``costmodel-drift``       measured per-node output bytes stay within the
                          costmodel byte laws' tolerance (pays one per-node
                          execution — the "profile" plane)
"""

from __future__ import annotations

from typing import Dict, List, Tuple, Type

import numpy as np

from repro.core import costmodel
from repro.core.expr import (AsType, Blockwise, ConcatRows, Densify, Expr,
                             GetItem, MatMul, PadGrid, Rechunk, Reduce,
                             Shuffle, ToSparse, Transpose, _is_ds, _is_sparse)
from repro.analysis import jaxprs, liveness
from repro.analysis.findings import Finding
from repro.analysis.graph import PlanView

_REGISTRY: Dict[str, Type["Rule"]] = {}


def register(cls: Type["Rule"]) -> Type["Rule"]:
    assert cls.id not in _REGISTRY, f"duplicate rule id {cls.id}"
    _REGISTRY[cls.id] = cls
    return cls


def all_rule_ids() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_rules(ids=None) -> List["Rule"]:
    if ids is None:
        return [cls() for cls in _REGISTRY.values()]
    unknown = [i for i in ids if i not in _REGISTRY]
    if unknown:
        raise ValueError(f"unknown rule ids {unknown}; "
                         f"known: {sorted(_REGISTRY)}")
    return [_REGISTRY[i]() for i in ids]


class Rule:
    """One lint rule: ``run(view)`` returns findings for one plan."""

    id: str = "?"
    severity: str = "error"
    needs: Tuple[str, ...] = ("plan",)

    def run(self, view: PlanView) -> List[Finding]:
        raise NotImplementedError

    def finding(self, site: str, message: str, severity: str = None,
                data: tuple = ()) -> Finding:
        return Finding(rule=self.id, severity=severity or self.severity,
                       site=site, message=message, data=data)


# ---------------------------------------------------------------------------


#: nodes a sparse value may legally flow into without a finding: Densify is
#: the explicit claim, MatMul/Reduce consume BCOO natively (spmm / entry
#: reduction), ToSparse/Canonicalize are format ops.
_SPARSE_SINKS = (Densify, MatMul, Reduce, ToSparse)
#: structural ops whose sparse handling is documented to go through dense.
_DOCUMENTED_DENSIFY = (GetItem, Rechunk, ConcatRows, Shuffle, Transpose,
                       PadGrid)


@register
class NoDensify(Rule):
    """A bcoo value never flows through a densifying op unless an explicit
    ``Densify`` node claims the conversion (the paper's sparse wins die the
    moment a chain silently materializes the dense form)."""

    id = "no-densify"
    severity = "error"
    needs = ("plan", "jaxpr")

    def run(self, view: PlanView) -> List[Finding]:
        out: List[Finding] = []
        flagged: set = set()
        sparse_nodes = [n for n in view.nodes if _is_sparse(n.meta)]
        for n in view.nodes:
            if not (_is_ds(n.meta) and not _is_sparse(n.meta)):
                continue
            if not any(_is_sparse(c.meta) for c in n.children):
                continue
            if isinstance(n, _SPARSE_SINKS):
                continue
            if isinstance(n, _DOCUMENTED_DENSIFY):
                out.append(self.finding(
                    view.label(n), "sparse operand goes through the "
                    "documented dense path of a structural op",
                    severity="info"))
                flagged.add(id(n))
                continue
            out.append(self.finding(
                view.label(n),
                f"{n.kind} consumes a bcoo operand but produces a dense "
                "result without an explicit Densify node claiming the "
                "conversion"))
            flagged.add(id(n))
        if not sparse_nodes:
            return out
        # jaxpr plane: eqn outputs shaped like the densified sparse operand
        # that no legitimate dense node accounts for
        claimed = {tuple(n.meta.blocks.shape) for n in view.nodes
                   if _is_ds(n.meta) and not _is_sparse(n.meta)
                   and id(n) not in flagged}
        seen: set = set()
        for sp in sparse_nodes:
            shape4 = tuple(sp.meta.blocks.shape)
            if shape4 in seen or shape4 in claimed:
                continue
            seen.add(shape4)
            hits = jaxprs.dense_operand_intermediates(view.jaxpr(), shape4)
            for prim, shp in hits:
                if tuple(shp) in claimed:
                    continue
                out.append(self.finding(
                    f"eqn:{prim}{list(shp)}",
                    f"trace materializes a dense {list(shp)} value from a "
                    f"bcoo operand blocked {list(shape4)} with no Densify "
                    "node in the plan"))
        return out


@register
class NoFullGridIntermediate(Rule):
    """No non-root full-grid HBM def in the compiled ENTRY beyond what the
    plan's surviving nodes account for — the general form of the PR-3
    hand-rolled single-fused-body HLO check."""

    id = "no-full-grid-intermediate"
    severity = "error"
    needs = ("plan", "hlo")

    def run(self, view: PlanView) -> List[Finding]:
        dense_bw = [n for n in view.nodes
                    if isinstance(n, Blockwise) and _is_ds(n.meta)
                    and not _is_sparse(n.meta)]
        if not dense_bw:
            return []        # nothing fusible: skip the XLA compile
        shapes = {tuple(n.meta.blocks.shape) for n in dense_bw}
        roots = {id(r) for r in view.roots}
        txt = view.hlo_text()
        out: List[Finding] = []
        for shape4 in sorted(shapes):
            # every surviving non-root node of this shape legitimately
            # materializes once; with several roots each root's def also
            # appears as a plain ENTRY instruction (ROOT is the tuple)
            budget = sum(
                1 for n in view.nodes
                if id(n) not in roots and n.children
                and _is_ds(n.meta) and not _is_sparse(n.meta)
                and tuple(n.meta.blocks.shape) == shape4)
            if len(view.roots) > 1:
                budget += sum(
                    1 for r in view.roots
                    if _is_ds(r.meta) and not _is_sparse(r.meta)
                    and tuple(r.meta.blocks.shape) == shape4)
            defs = jaxprs.entry_full_grid_defs(txt, shape4)
            if len(defs) > budget:
                out.append(self.finding(
                    f"entry:{list(shape4)}",
                    f"{len(defs)} full-grid {list(shape4)} HBM defs in the "
                    f"compiled ENTRY but the plan accounts for {budget} — "
                    "an intermediate is being materialized inside a fused "
                    f"chain (first: {defs[0][:96]})",
                    data=(len(defs), budget)))
        return out


@register
class PadSoundness(Rule):
    """Abstract-interpret pad state with the same probe the recorder uses
    and flag any node whose CLAIMED pad_state is stronger than the derived
    one — a wrong zero/fill claim makes every downstream mask elision
    unsound."""

    id = "pad-soundness"
    severity = "error"
    needs = ("plan",)

    def run(self, view: PlanView) -> List[Finding]:
        out: List[Finding] = []
        for n in view.nodes:
            if not isinstance(n, Blockwise) or not _is_ds(n.meta):
                continue
            if _is_sparse(n.meta):
                continue     # bcoo results are zero-padded by construction
            claim = n.pad
            derived = n._probe_pad()
            if claim == derived:
                continue
            if claim.kind == "dirty":
                continue     # weaker than derivable: sound, never flagged
            if derived.kind == "dirty":
                out.append(self.finding(
                    view.label(n),
                    f"claims pad_state {claim} but the probe cannot derive "
                    "it (derived DIRTY): the claim is stronger than the "
                    "transfer rules support",
                    data=(str(claim), str(derived))))
            else:
                out.append(self.finding(
                    view.label(n),
                    f"claims pad_state {claim} but the probe derives "
                    f"{derived}: mask elision downstream would read wrong "
                    "pad values",
                    data=(str(claim), str(derived))))
        return out


#: consumers that may pay one deferred remask per ds operand
#: (``costmodel.chain_remask_passes(1, pad_tracked=True,
#: zero_preserving=False) == 1``).
_REMASK_CONSUMERS = (MatMul, Reduce, GetItem, Rechunk, ConcatRows, Shuffle,
                     Densify, ToSparse)


@register
class RemaskBudget(Rule):
    """Count mask/select passes in the trace against the costmodel budget:
    one deferred pass per ds operand of each pad-sensitive consumer, plus
    one per root materialization — the pad-state tracking contract."""

    id = "remask-budget"
    severity = "warn"
    needs = ("plan", "jaxpr")

    def run(self, view: PlanView) -> List[Finding]:
        per_consumer = costmodel.chain_remask_passes(
            1, pad_tracked=True, zero_preserving=False)
        budget = len(view.roots) * per_consumer
        for n in view.nodes:
            if isinstance(n, _REMASK_CONSUMERS):
                budget += per_consumer * sum(
                    1 for c in n.children if _is_ds(c.meta))
        count = jaxprs.count_selects(view.jaxpr())
        if count <= budget:
            return []
        return [self.finding(
            "plan",
            f"{count} select/mask passes in the trace exceed the remask "
            f"budget of {budget} (one deferred pass per pad-sensitive "
            "consumer operand + one per root)",
            data=(count, budget))]


def _iter_key_atoms(key):
    if isinstance(key, tuple):
        for k in key:
            yield from _iter_key_atoms(k)
    else:
        yield key


def _scalar_atoms(key):
    """(value, dtype-str) pairs as baked by ``expr._scalar_key``."""
    if isinstance(key, tuple):
        if len(key) == 2 and isinstance(key[0], (bool, int, float)) \
                and isinstance(key[1], str):
            try:
                np.dtype(key[1])
            except TypeError:
                pass
            else:
                yield key
                return
        for k in key:
            yield from _scalar_atoms(k)


@register
class RecompileHazard(Rule):
    """Plan-cache key instability in the AS-RECORDED DAG: keys that cannot
    match across recordings (fresh lambdas), baked non-static data, and
    scalar operands whose weak-type drift splits the cache."""

    id = "recompile-hazard"
    severity = "warn"
    needs = ("plan",)

    def run(self, view: PlanView) -> List[Finding]:
        out: List[Finding] = []
        scalars: Dict[float, set] = {}
        scalar_site: Dict[float, str] = {}
        for n in view.raw_nodes:
            if not isinstance(n, Blockwise):
                continue
            site = f"{n.describe()}#raw"
            for atom in _iter_key_atoms(n.key):
                if callable(atom) and \
                        getattr(atom, "__name__", "") == "<lambda>":
                    out.append(self.finding(
                        site, "a lambda is baked into the plan key: every "
                        "re-recording creates a fresh function object, so "
                        "the compiled-plan cache can never hit (name the "
                        "fn, or pass a stable _key)"))
            for cell in getattr(n.fn, "__closure__", None) or ():
                v = cell.cell_contents
                if getattr(v, "ndim", 0) and not callable(v):
                    out.append(self.finding(
                        site, f"recorded fn closes over a {v.ndim}-D array "
                        f"{tuple(v.shape)}: the data is baked into the "
                        "compiled plan instead of being a runtime input "
                        "(thread it through map_blocks operands)"))
            for val, dt in _scalar_atoms(n.key):
                try:
                    fval = float(val)
                except (TypeError, OverflowError):
                    continue
                scalars.setdefault(fval, set()).add(dt)
                scalar_site.setdefault(fval, site)
        for fval, dts in sorted(scalars.items()):
            if len(dts) > 1:
                out.append(self.finding(
                    scalar_site[fval],
                    f"scalar {fval} is baked with {len(dts)} distinct "
                    f"dtypes {sorted(dts)} in one plan: weak-type drift "
                    "(e.g. `2` vs `2.0`) keys separate cache entries for "
                    "the same computation",
                    data=(fval, tuple(sorted(dts)))))
        return out


@register
class PeakHbmLiveness(Rule):
    """Per-node live-set bytes under the naive emission order vs a
    liveness-minimizing topological order (dask ``order.py`` style) from
    the costmodel byte laws.  Always reports both peaks (info); flags the
    plan (warn) when reordering saves ``PEAK_REORDER_FACTOR``x or more."""

    id = "peak-hbm-liveness"
    severity = "warn"
    needs = ("plan",)

    def run(self, view: PlanView) -> List[Finding]:
        rep = liveness.analyze(view.roots)
        data = (rep.naive_peak, rep.minimized_peak, rep.input_bytes,
                rep.n_nodes)
        if rep.reorder_pays:
            return [self.finding(
                "plan",
                f"naive emission order peaks at {rep.naive_peak:,} live "
                f"bytes; a liveness-minimizing order needs only "
                f"{rep.minimized_peak:,} ({rep.ratio:.2f}x) — reordering "
                "pays (costmodel.PEAK_REORDER_FACTOR)",
                data=data)]
        return [self.finding(
            "plan", str(rep), severity="info", data=data)]


@register
class CostmodelDrift(Rule):
    """Execute the plan node by node (``obs.profile``) and flag any node
    whose MEASURED output bytes land outside the costmodel byte laws'
    tolerance (``costmodel.COSTMODEL_DRIFT_FACTOR``).  The laws are exact
    for both block representations, so drift means a representation or a
    law changed without the other — every liveness/fusion/bucket decision
    derived from the stale side is then wrong.  This is the expensive rule
    (one per-node execution), declared as its own ``"profile"`` plane."""

    id = "costmodel-drift"
    severity = "warn"
    needs = ("plan", "profile")

    def run(self, view: PlanView) -> List[Finding]:
        out: List[Finding] = []
        for rec in view.profile().drifting():
            out.append(self.finding(
                rec.site,
                f"measured output {rec.measured_bytes:,} bytes vs "
                f"costmodel-predicted {rec.predicted_bytes:,} "
                f"({rec.ratio:.2f}x) — beyond the "
                f"{costmodel.COSTMODEL_DRIFT_FACTOR}x drift tolerance; "
                "the byte law and the block representation disagree",
                data=(rec.measured_bytes, rec.predicted_bytes)))
        return out
