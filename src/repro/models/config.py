"""Unified architecture config covering the 10 assigned model families."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None   # default d_model // n_heads

    # -- attention features ---------------------------------------------------
    attn_window: int = 0             # sliding-window size (0 = full attention)
    local_global_period: int = 0     # gemma2: every p-th layer is global
    attn_softcap: float = 0.0        # gemma2/grok logit soft-capping
    final_softcap: float = 0.0       # gemma2 final-logit soft-capping
    qkv_bias: bool = False           # qwen1.5
    rope_theta: float = 10000.0

    # -- mlp --------------------------------------------------------------------
    mlp_type: str = "swiglu"         # swiglu | relu2 | gelu
    tie_embeddings: bool = False

    # -- MoE ---------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # -- SSM (mamba2) -----------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    ssm_conv: int = 4

    # -- hybrid (zamba2) ---------------------------------------------------------
    share_period: int = 0            # shared attn block applied every k SSM layers

    # -- enc-dec (seamless) --------------------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0

    # -- modality frontend stub ------------------------------------------------------
    frontend: str = "none"           # none | vision | audio
    frontend_dim: int = 0            # raw patch/frame embedding width
    frontend_tokens: int = 0         # patch/frame count prepended to the sequence

    # -- numerics / training ----------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True               # activation checkpointing per layer

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def ssm_dinner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_dinner // self.ssm_headdim

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def layer_is_global(self, layer_idx: int) -> bool:
        """gemma2-style local/global alternation (odd layers global, p=2)."""
        if self.local_global_period <= 0:
            return self.attn_window == 0
        return (layer_idx % self.local_global_period) == self.local_global_period - 1

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.hd
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        dense_mlp = (3 if self.mlp_type in ("swiglu", "geglu") else 2) * d * f
        norms = 2 * d
        if self.family == "ssm":
            dinner, s, g = self.ssm_dinner, self.ssm_state, self.ssm_ngroups
            h = self.ssm_heads
            in_proj = d * (2 * dinner + 2 * g * s + h)
            conv = self.ssm_conv * (dinner + 2 * g * s)
            per_layer = in_proj + conv + h + h + dinner + dinner * d + d  # A, D, norm, out
            body = self.n_layers * per_layer
        elif self.family == "hybrid":
            dinner, s, g = self.ssm_dinner, self.ssm_state, self.ssm_ngroups
            h = self.ssm_heads
            in_proj = d * (2 * dinner + 2 * g * s + h)
            conv = self.ssm_conv * (dinner + 2 * g * s)
            ssm_layer = in_proj + conv + h + h + dinner + dinner * d + d
            body = self.n_layers * ssm_layer + (attn + dense_mlp + norms)  # one shared block
        elif self.family == "moe":
            moe_mlp = self.n_experts * dense_mlp + d * self.n_experts
            body = self.n_layers * (attn + moe_mlp + norms)
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + dense_mlp + norms)
            dec = self.dec_layers * (2 * attn + dense_mlp + 3 * d)
            body = enc + dec
        else:
            body = self.n_layers * (attn + dense_mlp + norms)
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.frontend == "vision":
            emb += self.frontend_dim * d + d * d  # 2-layer mm projector
        if self.frontend == "audio":
            emb += self.frontend_dim * d
        return int(body + emb + d)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_mlp = (3 if self.mlp_type in ("swiglu", "geglu") else 2) * d * f
        inactive = self.n_layers * (self.n_experts - self.top_k) * dense_mlp
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment matrix."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def get_shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)
