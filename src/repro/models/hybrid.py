"""Zamba2-style hybrid: Mamba-2 backbone + a SHARED attention block.

Structure (arXiv:2411.15242, simplified — see DESIGN.md): ``n_layers`` Mamba-2
blocks; after every ``share_period`` of them, ONE shared transformer block
(attention + MLP, the same parameters every application) runs.  Weight
sharing means the shared block's params live outside the layer scan; its KV
caches are per-application (stacked on the scan axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import ssm
from repro.models import transformer as tf
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def n_apps(cfg: ModelConfig) -> int:
    assert cfg.share_period > 0 and cfg.n_layers % cfg.share_period == 0
    return cfg.n_layers // cfg.share_period


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.activation_dtype
    k_emb, k_layers, k_shared, k_head = jax.random.split(key, 4)
    keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                   dtype) * 0.02,
        "layers": cm.stack_layer_params(
            list(keys), lambda k: ssm.mamba_init(k, cfg, dtype)),
        "shared": tf._layer_init(k_shared, cfg, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": cm.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype),
    }
    return params


def _reshape_groups(tree: Params, n_groups: int, per: int) -> Params:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n_groups, per) + x.shape[1:]), tree)


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   patches=None, env: cm.ShardEnv = cm.NO_SHARD,
                   banded: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    del patches
    x = env.act_btd(jnp.take(params["embed"], tokens, axis=0))
    t = x.shape[1]
    positions = jnp.arange(t)
    ng = n_apps(cfg)
    grouped = _reshape_groups(params["layers"], ng, cfg.share_period)
    shared = params["shared"]

    def group_body(x, group_params):
        def inner(x, lp):
            y, _ = ssm.mamba_apply(lp, x, cfg, env)
            return y, None
        x, _ = jax.lax.scan(inner, x, group_params)
        # shared attention block (same weights every application)
        x, _ = tf._block_apply(shared, x, positions, cfg, cfg.attn_window,
                               env, banded)
        return x, None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, grouped)
    return cm.rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.float32(0.0)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            patches=None, env: cm.ShardEnv = cm.NO_SHARD,
            banded: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x, aux = forward_hidden(params, cfg, tokens, patches, env, banded)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return env.act_btv(logits.astype(jnp.float32)), aux


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, patches=None,
            env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True) -> jnp.ndarray:
    hidden, _ = forward_hidden(params, cfg, tokens, env=env, banded=banded)
    return cm.chunked_lm_loss(hidden, params["lm_head"], labels, env=env,
                               vocab_parallel=env.vocab_parallel)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = cfg.activation_dtype
    dinner, s, g = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_ngroups
    conv_dim = dinner + 2 * g * s
    L, na = cfg.n_layers, n_apps(cfg)
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((L, batch, cfg.ssm_heads, s, cfg.ssm_headdim),
                       jnp.float32),
        "attn_k": jnp.zeros((na, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        "attn_v": jnp.zeros((na, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, env: cm.ShardEnv = cm.NO_SHARD
                ) -> Tuple[jnp.ndarray, Params]:
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["pos"]
    ng, per = n_apps(cfg), cfg.share_period
    grouped = _reshape_groups(params["layers"], ng, per)
    conv_g = jax.tree_util.tree_map(
        lambda c: c.reshape((ng, per) + c.shape[1:]), cache["conv"])
    h_g = cache["h"].reshape((ng, per) + cache["h"].shape[1:])
    shared = params["shared"]

    def group_body(x, xs):
        lp, conv, h, kc, vc = xs

        def inner(x, inner_xs):
            p, cv, hh = inner_xs
            y, st = ssm.mamba_apply(p, x, cfg, env,
                                    state={"conv": cv, "h": hh},
                                    single_step=True)
            return y, (st["conv"], st["h"])

        x, (conv_new, h_new) = jax.lax.scan(inner, x, (lp, conv, h))
        x, kc, vc = tf.decode_block(shared, x, kc, vc, pos, cfg,
                                    cfg.attn_window, env)
        return x, (conv_new, h_new, kc, vc)

    x, (convs, hs, kcs, vcs) = jax.lax.scan(
        group_body, x, (grouped, conv_g, h_g, cache["attn_k"],
                        cache["attn_v"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_cache = {
        "conv": convs.reshape(cache["conv"].shape),
        "h": hs.reshape(cache["h"].shape),
        "attn_k": kcs, "attn_v": vcs,
        "pos": pos + 1,
    }
    return logits.astype(jnp.float32), new_cache
