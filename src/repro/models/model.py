"""Model registry: one uniform functional interface per architecture family.

    model = build_model(cfg)
    params = model.init(key)
    logits, aux = model.forward(params, tokens, patches, env)
    loss = model.loss(params, tokens, labels, patches, env)
    cache = model.init_cache(batch, max_len)
    logits, cache = model.decode_step(params, cache, tokens, env)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.models import common as cm
from repro.models import encdec, hybrid, ssm, transformer
from repro.models.config import ModelConfig

_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": ssm,
    "hybrid": hybrid,
    "encdec": encdec,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    module: Any

    def init(self, key):
        return self.module.init_params(key, self.cfg)

    def forward(self, params, tokens, patches=None, env: cm.ShardEnv = cm.NO_SHARD,
                banded: bool = True):
        return self.module.forward(params, self.cfg, tokens, patches, env,
                                   banded)

    def loss(self, params, tokens, labels, patches=None,
             env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True):
        return self.module.loss_fn(params, self.cfg, tokens, labels, patches,
                                   env, banded)

    def init_cache(self, batch: int, max_len: int, **kw):
        return self.module.init_cache(self.cfg, batch, max_len, **kw)

    def decode_step(self, params, cache, tokens, env: cm.ShardEnv = cm.NO_SHARD):
        return self.module.decode_step(params, self.cfg, cache, tokens, env)

    @property
    def needs_patches(self) -> bool:
        return self.cfg.frontend != "none"


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise KeyError(f"unknown family {cfg.family!r}")
    return Model(cfg=cfg, module=_FAMILY_MODULES[cfg.family])
