"""Mamba-2 (SSD) language model — the attention-free arch (mamba2-370m).

The chunked SSD computation here is the pure-jnp/XLA path used for training,
the dry-run and the roofline; it is mathematically identical to the Pallas
kernel in ``repro.kernels.ssd`` (which is the TPU-runtime fast path, validated
against the same oracle).  Chunking the (sequence × state) plane is the
paper's 2-D blocking idea applied inside the layer: chunk grid = block grid.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Chunked SSD in pure jnp (batched over B and heads)
# ---------------------------------------------------------------------------


def ssd_chunked(x: jnp.ndarray,      # (B, T, H, P)
                dt: jnp.ndarray,     # (B, T, H)  positive
                a: jnp.ndarray,      # (H,)       negative
                bmat: jnp.ndarray,   # (B, T, G, S)
                cmat: jnp.ndarray,   # (B, T, G, S)
                h0: Optional[jnp.ndarray] = None,   # (B, H, S, P)
                chunk: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,T,H,P), h_final (B,H,S,P))."""
    b, t, h, p = x.shape
    g, s = bmat.shape[2], bmat.shape[3]
    hpg = h // g                     # heads per group
    pad = (-t) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tp = t + pad
    nc = tp // chunk
    l = chunk

    xc = x.reshape(b, nc, l, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, l, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, l, g, s).astype(jnp.float32)
    cc = cmat.reshape(b, nc, l, g, s).astype(jnp.float32)

    lda = dtc * a.astype(jnp.float32)                     # (B,NC,L,H) <= 0
    ell = jnp.cumsum(lda, axis=2)                          # inclusive
    # pairwise decay within chunk, per head: exp(ell_t - ell_s), s<=t.
    # The masked (s>t) region has POSITIVE diff -> exp overflows -> inf, and
    # `where(mask, inf, 0)` poisons the backward pass (0·inf = NaN); clamp
    # the masked region to 0 BEFORE the exp.
    diff = ell[:, :, :, None, :] - ell[:, :, None, :, :]   # (B,NC,L_t,L_s,H)
    tri = (jnp.arange(l)[:, None] >= jnp.arange(l)[None, :])
    tri_b = tri[None, None, :, :, None]
    gate = jnp.where(tri_b, jnp.exp(jnp.where(tri_b, diff, 0.0)), 0.0)
    # scores per group: C_t · B_s
    scores = jnp.einsum("bnlgs,bnmgs->bnlmg", cc, bc)      # (B,NC,L,L,G)
    scores = jnp.repeat(scores, hpg, axis=-1)              # (B,NC,L,L,H)
    w = scores * gate
    xdt = xc * dtc[..., None]                              # (B,NC,L,H,P)
    y_intra = jnp.einsum("bnlmh,bnmhp->bnlhp", w, xdt)

    # per-chunk boundary state: sum_s exp(ell_last - ell_s) dt_s B_s x_sᵀ
    w_end = jnp.exp(ell[:, :, -1:, :] - ell)               # (B,NC,L,H)
    bg = jnp.repeat(bc, hpg, axis=3) if g != h else bc     # (B,NC,L,H,S)
    states = jnp.einsum("bnlhs,bnlh,bnlhp->bnhsp", bg, w_end * dtc, xc)
    decays = jnp.exp(ell[:, :, -1, :])                     # (B,NC,H)

    if h0 is not None:
        states = states.at[:, 0].add(decays[:, 0, :, None, None]
                                     * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, d2[..., None, None] * s1 + s2

    d_acc, h_after = jax.lax.associative_scan(combine, (decays, states), axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(h_after[:, :1]),
                              h_after[:, :-1]], axis=1)
    if h0 is not None:
        h_prev = h_prev.at[:, 0].set(h0.astype(jnp.float32))

    cg = jnp.repeat(cc, hpg, axis=3) if g != h else cc     # (B,NC,L,H,S)
    y_inter = jnp.einsum("bnlhs,bnlh,bnhsp->bnlhp", cg, jnp.exp(ell), h_prev)
    y = (y_intra + y_inter).reshape(b, tp, h, p)[:, :t]
    return y.astype(x.dtype), h_after[:, -1]


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def mamba_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    dinner, s, g, h = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    conv_dim = dinner + 2 * g * s
    ks = jax.random.split(key, 5)
    return {
        "norm": jnp.zeros((d,), dtype),
        "in_proj": cm.dense_init(ks[0], (d, 2 * dinner + 2 * g * s + h), dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), dtype)
                  / math.sqrt(cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.zeros((dinner,), dtype),
        "out_proj": cm.dense_init(ks[2], (dinner, d), dtype, fan_in=dinner),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv along T. x (B,T,C), w (K,C). Returns (y, new
    state (B,K-1,C)) where state carries the last K-1 inputs."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)              # (B, T+K-1, C)
    out = sum(xx[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xx[:, -(k - 1):, :] if k > 1 else state
    return out + b, new_state


def mamba_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                env: cm.ShardEnv = cm.NO_SHARD,
                state: Optional[Params] = None, single_step: bool = False
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x (B,T,D) -> (y (B,T,D), new_state).  ``state`` carries
    {"conv": (B,K-1,C), "h": (B,H,S,P)} for decode."""
    b, t, d = x.shape
    dinner, s, g, h = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    pdim = cfg.ssm_headdim
    res = x
    x = cm.rms_norm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("btd,dk->btk", x, env.weight(p["in_proj"], 1),
                      preferred_element_type=jnp.float32).astype(x.dtype)
    z, xbc, dt = jnp.split(proj, [dinner, 2 * dinner + 2 * g * s], axis=-1)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [dinner, dinner + g * s], axis=-1)
    xs = env.act_btf(xs) if dinner == cfg.d_ff else xs
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = -jnp.exp(p["a_log"])                                # (H,)

    xh = xs.reshape(b, t, h, pdim)
    bm = bmat.reshape(b, t, g, s)
    cmt = cmat.reshape(b, t, g, s)

    if single_step:
        hpg = h // g
        h_prev = state["h"]                                 # (B,H,S,P)
        dt1 = dt[:, 0]                                      # (B,H)
        decay = jnp.exp(a * dt1)[..., None, None]
        bg = jnp.repeat(bm[:, 0], hpg, axis=1)              # (B,H,S)
        cg = jnp.repeat(cmt[:, 0], hpg, axis=1)
        x1 = xh[:, 0].astype(jnp.float32)                   # (B,H,P)
        h_new = decay * h_prev + (dt1[..., None, None]
                                  * bg[..., None] * x1[:, :, None, :])
        y = jnp.einsum("bhs,bhsp->bhp", cg, h_new)[:, None]  # (B,1,H,P)
        y = y.astype(x.dtype)
        h_fin = h_new
    else:
        h0 = state["h"] if state is not None else None
        y, h_fin = ssd_chunked(xh, dt, a, bm, cmt, h0, cfg.ssm_chunk)

    y = y + p["d_skip"][None, None, :, None].astype(jnp.float32) \
        * xh.astype(jnp.float32)
    y = y.reshape(b, t, dinner).astype(x.dtype)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                    p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("btk,kd->btd", y, env.weight(p["out_proj"], 0),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_state = {"conv": new_conv, "h": h_fin} if (state is not None
                                                   or single_step) else None
    return env.act_btd(res + out), new_state


# ---------------------------------------------------------------------------
# Mamba-2 LM
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.activation_dtype
    k_emb, k_layers = jax.random.split(key)
    keys = jax.random.split(k_layers, cfg.n_layers)
    return {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                   dtype) * 0.02,
        "layers": cm.stack_layer_params(list(keys),
                                        lambda k: mamba_init(k, cfg, dtype)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   patches=None, env: cm.ShardEnv = cm.NO_SHARD,
                   banded: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    del patches, banded
    x = env.act_btd(jnp.take(params["embed"], tokens, axis=0))

    def body(x, layer_params):
        y, _ = mamba_apply(layer_params, x, cfg, env)
        return y, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return cm.rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.float32(0.0)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            patches=None, env: cm.ShardEnv = cm.NO_SHARD,
            banded: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x, aux = forward_hidden(params, cfg, tokens, patches, env, banded)
    logits = jnp.einsum("btd,dv->btv", x, params["embed"].T,
                        preferred_element_type=jnp.float32)
    return env.act_btv(logits.astype(jnp.float32)), aux


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, patches=None,
            env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True) -> jnp.ndarray:
    hidden, _ = forward_hidden(params, cfg, tokens, env=env)
    return cm.chunked_lm_loss(hidden, params["embed"].T, labels, env=env,
                               vocab_parallel=env.vocab_parallel)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    del max_len  # SSM state is O(1) in sequence length
    dtype = cfg.activation_dtype
    dinner, s, g, h = cfg.ssm_dinner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_heads
    conv_dim = dinner + 2 * g * s
    L = cfg.n_layers
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((L, batch, h, s, cfg.ssm_headdim), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, env: cm.ShardEnv = cm.NO_SHARD
                ) -> Tuple[jnp.ndarray, Params]:
    x = jnp.take(params["embed"], tokens, axis=0)     # (B, 1, D)

    def body(x, xs):
        layer_params, conv, h = xs
        y, st = mamba_apply(layer_params, x, cfg, env,
                            state={"conv": conv, "h": h}, single_step=True)
        return y, (st["conv"], st["h"])

    x, (convs, hs) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["h"]))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["embed"].T,
                        preferred_element_type=jnp.float32)
    new_cache = {"conv": convs, "h": hs, "pos": cache["pos"] + 1}
    return logits.astype(jnp.float32), new_cache
