"""Shared model building blocks: norms, RoPE, attention (XLA paths), MLPs,
init helpers, and the sharding environment.

Everything is pure-functional over param pytrees (plain nested dicts); no
framework.  All matmuls run in bf16 with fp32 accumulation
(``preferred_element_type``); softmax/norm statistics are fp32.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Sharding environment: names the mesh axes so model code can place
# activation constraints without knowing the physical mesh.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardEnv:
    mesh: Optional[Mesh] = None
    dp: Tuple[str, ...] = ("data",)      # batch-parallel axes (pod+data)
    tp: Optional[str] = "model"          # tensor-parallel axis
    # §Perf toggles (False/off = paper-faithful baseline):
    vocab_parallel: bool = True          # vocab-sharded chunked loss
    bf16_tp_reduce: bool = False         # bf16 partials for TP all-reduces
    gather_weights: bool = False         # explicit FSDP weight all-gather
    mode: str = "tp_sp"                  # "tp_sp" | "fsdp" (§Perf iter 4)

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Axes the batch dim shards over.  In "fsdp" mode the batch covers
        the WHOLE mesh (both named axes — the paper's block-both-axes idea
        applied to parallelism): no TP/SP, weights are gathered per layer,
        and the only collectives left are the FSDP param gathers + grad
        reduce-scatter."""
        if self.mode == "fsdp" and self.tp is not None:
            return tuple(self.dp) + (self.tp,)
        return tuple(self.dp)

    def out_proj_dtype(self):
        """Accumulation dtype for output projections (wo / w_down): bf16
        halves the TP all-reduce bytes at a small precision cost."""
        return jnp.bfloat16 if self.bf16_tp_reduce else jnp.float32

    def weight(self, w: jnp.ndarray, tp_dim: int) -> jnp.ndarray:
        """§Perf iteration 3: explicitly all-gather the FSDP ('data') shards
        of a weight before use, keeping only its TP dim sharded.  Without
        this GSPMD sometimes contracts over the FSDP-sharded dim and
        ALL-REDUCES THE ACTIVATIONS — (B,S,F)-sized collectives instead of
        weight-sized ones (measured 300x larger on yi-9b train_4k).
        ``tp_dim``: which dim keeps the `model`-axis sharding (-1 = none)."""
        if self.mesh is None:
            return w
        if self.mode == "fsdp":
            return self.constrain(w, P(*([None] * w.ndim)))  # full gather
        if not self.gather_weights:
            return w
        spec = [None] * w.ndim
        if tp_dim >= 0:
            spec[tp_dim] = self.tp
        return self.constrain(w, P(*spec))

    def _axis_size(self, names) -> int:
        if names is None:
            return 1
        if isinstance(names, str):
            names = (names,)
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size

    def sanitize(self, spec: P, shape) -> P:
        """Drop spec entries whose mesh extent does not divide the dim (the
        non-divisible cases replicate rather than shard unevenly)."""
        out = []
        for i, names in enumerate(spec):
            if names is not None and shape[i] % self._axis_size(names) != 0:
                out.append(None)
            else:
                out.append(names)
        return P(*out)

    def constrain(self, x: jnp.ndarray, spec: P) -> jnp.ndarray:
        if self.mesh is None:
            return x
        spec = self.sanitize(spec, x.shape)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))

    # common activation layouts
    def act_btd(self, x):    # (batch, seq, d_model) — sequence-parallel:
        # the residual stream is sharded over the model axis between blocks
        # (Megatron-SP), which is what keeps per-layer saved activations
        # inside HBM at 1M-token global batches; GSPMD inserts the
        # all-gather/reduce-scatter pair around each block's TP region.
        if self.mode == "fsdp":
            return self.constrain(x, P(self.batch_axes, None, None))
        return self.constrain(x, P(self.dp, self.tp, None))

    def act_bhtd(self, x):   # (batch, heads, seq, head_dim) -> TP over heads,
        # falling back to TP over the sequence when the head count does not
        # divide the model axis (gemma2's 8 q-heads on a 16-wide axis).
        if self.mode == "fsdp":
            return self.constrain(x, P(self.batch_axes, None, None, None))
        if self.mesh is not None and x.shape[1] % self._axis_size(self.tp):
            return self.constrain(x, P(self.dp, None, self.tp, None))
        return self.constrain(x, P(self.dp, self.tp, None, None))

    def act_btf(self, x):    # (batch, seq, d_ff) -> TP over hidden
        if self.mode == "fsdp":
            return self.constrain(x, P(self.batch_axes, None, None))
        return self.constrain(x, P(self.dp, None, self.tp))

    def act_btv(self, x):    # (batch, seq, vocab) -> TP over vocab
        if self.mode == "fsdp":
            return self.constrain(x, P(self.batch_axes, None, None))
        return self.constrain(x, P(self.dp, None, self.tp))


NO_SHARD = ShardEnv(mesh=None)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             plus_one: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (y * w).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, H, T, D); positions: (B, T) or (T,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — XLA paths (the Pallas kernel is the TPU-runtime fast path; the
# dry-run/roofline lowers these).
# ---------------------------------------------------------------------------


def _mask_scores(s: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                 causal: bool, window: int) -> jnp.ndarray:
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(mask, s, -1e30)


def _sdpa_block(q, k, v, q_pos, k_pos, *, causal, window, softcap, scale):
    return _sdpa_block_dyn(q, k, v, q_pos, k_pos, causal=causal,
                           window=window, softcap=softcap, scale=scale)


def attention_xla(
    q: jnp.ndarray,       # (B, Hq, Tq, D)
    k: jnp.ndarray,       # (B, Hkv, Tk, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    q_chunk: int = 1024,
    banded: bool = True,
) -> jnp.ndarray:
    """Memory-bounded attention: scans over q chunks so the live score
    buffer is (B,H,q_chunk,Tk); the scan body is remat'd so backward
    recomputes scores chunk-by-chunk.  All chunking is static (reshape +
    scan-over-xs + static gather indices), never traced dynamic-slice — this
    is what lets GSPMD keep clean shardings through the loop.

    With ``banded`` and a sliding window, each q chunk reads only its
    (window + q_chunk) KV band via a precomputed gather — the sub-quadratic
    local-attention path (beyond-paper §Perf optimization; ``banded=False``
    is the dense paper-faithful baseline).
    """
    import numpy as np

    b, hq, tq, d = q.shape
    tk = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    if tq <= q_chunk:
        q_pos = jnp.arange(tq) + q_offset
        return _sdpa_block(q, k, v, q_pos, jnp.arange(tk), causal=causal,
                           window=window, softcap=softcap, scale=scale)

    assert tq % q_chunk == 0, (tq, q_chunk)
    nc = tq // q_chunk
    use_band = banded and window > 0 and causal and tk > window + q_chunk

    q5 = jnp.moveaxis(q.reshape(b, hq, nc, q_chunk, d), 2, 0)  # (nc,B,H,qc,D)
    q_pos = (np.arange(nc)[:, None] * q_chunk + np.arange(q_chunk)[None, :]
             + q_offset)                                        # (nc, qc) static

    if use_band:
        band = min(tk, ((window + q_chunk + 127) // 128) * 128)
        starts = np.clip(q_pos[:, -1] + 1 - band, 0, tk - band)  # (nc,)
        idx = starts[:, None] + np.arange(band)[None, :]         # (nc, band)
        k_b = jnp.take(k, jnp.asarray(idx.reshape(-1)), axis=2)
        k_b = jnp.moveaxis(k_b.reshape(k.shape[0], k.shape[1], nc, band, d),
                           2, 0)                                 # (nc,B,Hkv,band,D)
        v_b = jnp.take(v, jnp.asarray(idx.reshape(-1)), axis=2)
        v_b = jnp.moveaxis(v_b.reshape(*k.shape[:2], nc, band, d), 2, 0)

        def body(_, xs):
            q_c, k_c, v_c, qp, kp = xs
            return None, _sdpa_block_dyn(q_c, k_c, v_c, qp, kp, causal=causal,
                                         window=window, softcap=softcap,
                                         scale=scale)

        xs = (q5, k_b, v_b, jnp.asarray(q_pos), jnp.asarray(idx))
    else:
        k_pos = jnp.arange(tk)

        def body(_, xs):
            q_c, qp = xs
            return None, _sdpa_block_dyn(q_c, k, v, qp, k_pos, causal=causal,
                                         window=window, softcap=softcap,
                                         scale=scale)

        xs = (q5, jnp.asarray(q_pos))

    body = jax.checkpoint(body, prevent_cse=False)
    _, outs = jax.lax.scan(body, None, xs)
    return jnp.moveaxis(outs, 0, 2).reshape(b, hq, tq, d)


def _sdpa_block_dyn(q, k, v, q_pos, k_pos, *, causal, window, softcap, scale):
    """Dense score block: q (B,H,qc,D) x k/v (B,Hkv,Tk,D).

    KV heads are REPEATED to the full q-head count before the score einsum
    (cheap: KV tensors are small) so that the (B,H,qc,Tk) score buffer keeps
    a shardable head dim — a (Hkv, group) reshape would leave both factors
    non-divisible by the 16-wide model axis on every GQA arch in the pool.
    """
    b, hq, qc, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, Hq, 1, D)
    k_cache: jnp.ndarray,  # (B, Hkv, Tmax, D)
    v_cache: jnp.ndarray,
    length: jnp.ndarray,   # scalar int32: number of valid cache slots
    *,
    softcap: float = 0.0,
    rolling: bool = False,
) -> jnp.ndarray:
    """Single-token attention against a (possibly rolling) KV cache.

    With ``rolling`` the cache is a circular buffer (sliding-window archs at
    long context); validity is simply min(length, Tmax) slots, and RoPE has
    already been applied at insert time so order does not matter.
    """
    b, hq, _, d = q.shape
    hkv, tmax = k_cache.shape[1], k_cache.shape[2]
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, group, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    n_valid = jnp.minimum(length, tmax) if rolling else length
    valid = jnp.arange(tmax)[None, None, None, :] < n_valid
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_apply(params: Params, x: jnp.ndarray, mlp_type: str,
              env: ShardEnv = NO_SHARD) -> jnp.ndarray:
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        h = jnp.einsum("btd,df->btf", x, env.weight(params["w_gate"], 1),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("btd,df->btf", x, env.weight(params["w_up"], 1),
                       preferred_element_type=jnp.float32)
        h = env.act_btf((act(h) * u).astype(x.dtype))
    elif mlp_type == "relu2":  # nemotron squared-ReLU
        h = jnp.einsum("btd,df->btf", x, env.weight(params["w_up"], 1),
                       preferred_element_type=jnp.float32)
        h = env.act_btf((jax.nn.relu(h) ** 2).astype(x.dtype))
    elif mlp_type == "gelu":
        h = jnp.einsum("btd,df->btf", x, env.weight(params["w_up"], 1),
                       preferred_element_type=jnp.float32)
        h = env.act_btf(jax.nn.gelu(h).astype(x.dtype))
    else:
        raise ValueError(mlp_type)
    out = jnp.einsum("btf,fd->btd", h, env.weight(params["w_down"], 0),
                     preferred_element_type=env.out_proj_dtype())
    return out.astype(x.dtype)


def mlp_init(key, d: int, f: int, mlp_type: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    p = {"w_up": jax.random.normal(k2, (d, f), dtype) * scale_in,
         "w_down": jax.random.normal(k3, (f, d), dtype) * scale_out}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k1, (d, f), dtype) * scale_in
    return p


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def _largest_divisor_leq(n: int, target: int) -> int:
    target = max(1, min(n, target))
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return 1


def chunked_lm_loss(hidden: jnp.ndarray, head: jnp.ndarray,
                    labels: jnp.ndarray, *, softcap: float = 0.0,
                    z_loss: float = 1e-4, token_chunk: int = 8192,
                    env: "ShardEnv" = None,
                    vocab_parallel: bool = True) -> jnp.ndarray:
    """Cross-entropy from final hidden states WITHOUT materializing the full
    (B, T, V) logits: scans over sequence chunks, computing each chunk's
    logits inside a remat'd body (so backward recomputes them too).  This is
    what keeps the 256k-vocab archs inside HBM at 1M-token global batches.

    ``vocab_parallel`` (§Perf iteration 1): re-layout the head ONCE to
    (d replicated × vocab TP-sharded) outside the scan, so each chunk's
    logits come out vocab-sharded with NO per-chunk collective; the gold
    logit is picked Megatron-style (one-hot mask + sum) so no cross-shard
    gather appears; only the tiny (b, sc) LSE reductions cross shards.  The
    paper-faithful baseline (False) leaves the head 2-D blocked and pays a
    per-chunk logits all-reduce (measured: ~40% of ALL collective bytes on
    qwen train_4k).
    """
    b, t, d = hidden.shape
    v = head.shape[-1]
    if env is not None and env.mesh is not None and env.mode == "fsdp":
        # §Perf iteration 5: the head GRADIENT is all-reduced once per loss
        # chunk (the batch-sharded bsv,bsd->dv contraction in backward), so
        # fewer/bigger chunks cut that traffic linearly; with batch fully
        # sharded the per-device logits chunk stays small.
        token_chunk = max(token_chunk, 65536)
    sc = _largest_divisor_leq(t, max(1, token_chunk // max(b, 1)))
    nc = t // sc
    if vocab_parallel and env is not None and env.mesh is not None:
        head = env.constrain(head, P(None, env.tp if env.mode == "tp_sp"
                                     else None))
    h = hidden.reshape(b, nc, sc, d).swapaxes(0, 1)      # (nc, b, sc, d)
    lab = labels.reshape(b, nc, sc).swapaxes(0, 1)

    def body(total, xs):
        h_c, l_c = xs
        logits = jnp.einsum("bsd,dv->bsv", h_c, head,
                            preferred_element_type=jnp.float32)
        if softcap > 0.0:
            logits = softcap * jnp.tanh(logits / softcap)
        if env is not None:
            logits = env.act_btv(logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        if vocab_parallel:
            onehot = jax.nn.one_hot(l_c, v, dtype=logits.dtype)
            gold = jnp.sum(logits * onehot, axis=-1)
        else:
            gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if z_loss > 0.0:
            nll = nll + z_loss * lse ** 2
        return total + nll.sum(), None

    body = jax.checkpoint(body, prevent_cse=False)
    total, _ = jax.lax.scan(body, jnp.float32(0.0), (h, lab))
    return total / (b * t)


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 z_loss: float = 1e-4,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean cross-entropy (+ z-loss) in fp32. logits (..., V)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0.0:
        nll = nll + z_loss * lse ** 2
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape: Sequence[int], dtype, fan_in: Optional[int] = None):
    fan_in = fan_in or shape[0]
    return jax.random.normal(key, tuple(shape), dtype) / math.sqrt(fan_in)


def stack_layer_params(keys, init_fn: Callable[[Any], Params]) -> Params:
    """Initialize L layers and stack each leaf along a new leading axis
    (the scan-over-layers layout)."""
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, 0), *layers)
