"""Mixture-of-Experts layer (mixtral / grok-1): top-k routing with capacity
buffers and expert-parallel GEMMs.

Dispatch strategy (TPU/SPMD-native, static shapes):

1. router logits → ``lax.top_k`` (k experts per token, softmax over the k),
2. position-in-expert via a cumsum over the flattened (N·k) slot axis,
3. scatter tokens into per-expert capacity buffers (E, C, D) — slots beyond
   capacity are DROPPED (GShard-style; capacity_factor controls the drop
   rate),
4. batched expert GEMMs ``(E,C,D)x(E,D,F)`` — these shard over the `model`
   axis (expert parallelism) so each device holds E/|model| experts,
5. gather + combine with routing weights.

FLOPs are k·cf·N·D·F·(2 or 3 matmuls) — the ACTIVE-expert count, so the
roofline's MODEL_FLOPS ratio stays honest (a dense-dispatch einsum would
inflate compiled FLOPs by E/k).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import NO_SHARD, Params, ShardEnv
from repro.models.config import ModelConfig


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) / math.sqrt(d),
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) / math.sqrt(d),
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) / math.sqrt(f),
    }
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(ks[1], (e, d, f), dtype) / math.sqrt(d)
    return p


def _dp_size(env: ShardEnv) -> int:
    if env.mesh is None:
        return 1
    size = 1
    for name in env.batch_axes:
        size *= env.mesh.shape[name]
    return size


def moe_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig,
              env: ShardEnv = NO_SHARD) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) -> (y (B,T,D), aux_loss scalar).

    Dispatch is LOCAL per data-parallel shard: tokens are reshaped to an
    explicit (dp, N/dp) shard dimension and the cumsum / scatter / gather all
    carry it as a batch dim, so GSPMD keeps every dispatch op shard-local
    (no replicated capacity buffers).  Per-shard capacity means a slow shard
    drops locally — standard local-dispatch MoE semantics.
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * t
    ds = _dp_size(env)
    if n % ds != 0:
        ds = 1
    nl = n // ds                                           # tokens per shard
    xs = x.reshape(ds, nl, d)
    if env.mesh is not None:
        xs = env.constrain(xs, jax.sharding.PartitionSpec(env.batch_axes, None, None))

    logits = jnp.einsum("snd,de->sne", xs, params["router"],
                        preferred_element_type=jnp.float32)  # (ds, nl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(logits, k)             # (ds, nl, k)
    weights = jax.nn.softmax(top_vals, axis=-1).astype(x.dtype)

    # load-balancing aux (Switch): E * sum_e mean_frac_e * mean_prob_e
    frac = jnp.mean(jax.nn.one_hot(top_idx[..., 0], e, dtype=jnp.float32),
                    axis=(0, 1))
    aux = e * jnp.sum(frac * probs.mean(axis=(0, 1)))

    if t == 1:
        # decode: dropless dispatch (buffers are tiny; drop noise would make
        # serving diverge from teacher-forced logits)
        cap = nl * k
    else:
        cap = max(8, int(math.ceil(cfg.capacity_factor * nl * k / e / 8.0)) * 8)
        cap = min(cap, nl)

    assign = top_idx.reshape(ds, nl * k)                     # (ds, nl*k)
    onehot = jax.nn.one_hot(assign, e, dtype=jnp.int32)      # (ds, nl*k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.sum(pos * onehot, axis=-1)                     # (ds, nl*k)
    keep = pos < cap
    pos_c = jnp.minimum(pos, cap - 1)

    x_rep = jnp.repeat(xs, k, axis=1)                        # (ds, nl*k, D)
    x_rep = x_rep * keep[..., None].astype(x.dtype)

    def scatter_one(xr, a, p):
        return jnp.zeros((e, cap, d), x.dtype).at[a, p].add(xr)

    buf = jax.vmap(scatter_one)(x_rep, assign, pos_c)        # (ds, E, cap, D)
    if env.mesh is not None:
        buf = env.constrain(
            buf, jax.sharding.PartitionSpec(env.batch_axes, None, None, None))

    # expert GEMMs: FSDP-gathered weights, hidden dim TP over `model`
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        h = jnp.einsum("secd,edf->secf", buf, env.weight(params["w_gate"], 2),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("secd,edf->secf", buf, env.weight(params["w_up"], 2),
                       preferred_element_type=jnp.float32)
        h = (act(h) * u).astype(x.dtype)
    else:
        h = jnp.einsum("secd,edf->secf", buf, env.weight(params["w_up"], 2),
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(h).astype(x.dtype)
    if env.mesh is not None:
        h = env.constrain(h, jax.sharding.PartitionSpec(
            env.dp, None, None, env.tp))
    out_buf = jnp.einsum("secf,efd->secd", h, env.weight(params["w_down"], 1),
                         preferred_element_type=jnp.float32).astype(x.dtype)

    def gather_one(ob, a, p):
        return ob[a, p]

    y_rep = jax.vmap(gather_one)(out_buf, assign, pos_c)     # (ds, nl*k, D)
    y_rep = y_rep * keep[..., None].astype(x.dtype)
    y = jnp.sum(y_rep.reshape(ds, nl, k, d)
                * weights[..., None], axis=2)                # (ds, nl, D)
    return y.reshape(b, t, d), aux
