"""Encoder–decoder transformer (seamless-m4t-medium backbone).

The modality frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed speech-frame embeddings (B, T_enc, frontend_dim) which a linear
adapter projects to d_model.  Encoder layers are bidirectional; decoder
layers are causal self-attention + cross-attention over the encoder output.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _attn_init(key, cfg: ModelConfig, dtype) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": cm.dense_init(ks[0], (d, h * hd), dtype),
        "wk": cm.dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": cm.dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": cm.dense_init(ks[3], (h * hd, d), dtype, fan_in=h * hd),
    }


def _enc_layer_init(key, cfg: ModelConfig, dtype) -> Params:
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": _attn_init(ka, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": cm.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig, dtype) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "self_attn": _attn_init(ka, cfg, dtype),
        "ln_cross": jnp.zeros((cfg.d_model,), dtype),
        "cross_attn": _attn_init(kc, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": cm.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.activation_dtype
    ke, kd, kemb, kfr, kh = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.dec_layers)
    return {
        "frontend_proj": cm.dense_init(kfr, (cfg.frontend_dim, cfg.d_model),
                                       dtype),
        "embed": jax.random.normal(kemb, (cfg.vocab_size, cfg.d_model),
                                   dtype) * 0.02,
        "enc_layers": cm.stack_layer_params(
            list(enc_keys), lambda k: _enc_layer_init(k, cfg, dtype)),
        "dec_layers": cm.stack_layer_params(
            list(dec_keys), lambda k: _dec_layer_init(k, cfg, dtype)),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "dec_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": cm.dense_init(kh, (cfg.d_model, cfg.vocab_size), dtype),
    }


def _mha(p: Params, xq: jnp.ndarray, xkv: jnp.ndarray, cfg: ModelConfig,
         env: cm.ShardEnv, causal: bool, rope: bool,
         q_positions=None, kv_positions=None) -> jnp.ndarray:
    b, tq, d = xq.shape
    tk = xkv.shape[1]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("btd,dk->btk", xq, env.weight(p["wq"], 1),
                   preferred_element_type=jnp.float32).astype(xq.dtype)
    k = jnp.einsum("btd,dk->btk", xkv, env.weight(p["wk"], 1),
                   preferred_element_type=jnp.float32).astype(xq.dtype)
    v = jnp.einsum("btd,dk->btk", xkv, env.weight(p["wv"], 1),
                   preferred_element_type=jnp.float32).astype(xq.dtype)
    q = env.act_bhtd(q.reshape(b, tq, h, hd).transpose(0, 2, 1, 3))
    k = env.act_bhtd(k.reshape(b, tk, hkv, hd).transpose(0, 2, 1, 3))
    v = env.act_bhtd(v.reshape(b, tk, hkv, hd).transpose(0, 2, 1, 3))
    if rope:
        qp = q_positions if q_positions is not None else jnp.arange(tq)
        kp = kv_positions if kv_positions is not None else jnp.arange(tk)
        q = cm.apply_rope(q, qp, cfg.rope_theta)
        k = cm.apply_rope(k, kp, cfg.rope_theta)
    o = cm.attention_xla(q, k, v, causal=causal, window=0, softcap=0.0)
    o = o.transpose(0, 2, 1, 3).reshape(b, tq, h * hd)
    return jnp.einsum("btk,kd->btd", o, env.weight(p["wo"], 0),
                      preferred_element_type=jnp.float32).astype(xq.dtype)


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray,
           env: cm.ShardEnv = cm.NO_SHARD) -> jnp.ndarray:
    """frames (B, T_enc, frontend_dim) -> encoder states (B, T_enc, D)."""
    x = jnp.einsum("btf,fd->btd", frames.astype(cfg.activation_dtype),
                   params["frontend_proj"],
                   preferred_element_type=jnp.float32)
    x = env.act_btd(x.astype(cfg.activation_dtype))

    def body(x, p):
        h = cm.rms_norm(x, p["ln1"], cfg.norm_eps)
        x = env.act_btd(x + _mha(p["attn"], h, h, cfg, env, causal=False,
                                 rope=True))
        h = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = env.act_btd(x + cm.mlp_apply(p["mlp"], h, cfg.mlp_type, env))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_hidden(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                  enc_out: jnp.ndarray, env: cm.ShardEnv = cm.NO_SHARD
                  ) -> jnp.ndarray:
    x = env.act_btd(jnp.take(params["embed"], tokens, axis=0))

    def body(x, p):
        h = cm.rms_norm(x, p["ln1"], cfg.norm_eps)
        x = env.act_btd(x + _mha(p["self_attn"], h, h, cfg, env, causal=True,
                                 rope=True))
        h = cm.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = env.act_btd(x + _mha(p["cross_attn"], h, enc_out, cfg, env,
                                 causal=False, rope=False))
        h = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = env.act_btd(x + cm.mlp_apply(p["mlp"], h, cfg.mlp_type, env))
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return cm.rms_norm(x, params["dec_norm"], cfg.norm_eps)


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   patches: Optional[jnp.ndarray] = None,
                   env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    del banded
    assert patches is not None, "encdec needs encoder frames"
    enc_out = encode(params, cfg, patches, env)
    return decode_hidden(params, cfg, tokens, enc_out, env), jnp.float32(0.0)


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            patches: Optional[jnp.ndarray] = None,
            env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """patches = encoder frames (B, T_enc, frontend_dim)."""
    x, aux = forward_hidden(params, cfg, tokens, patches, env, banded)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    return env.act_btv(logits.astype(jnp.float32)), aux


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, patches: Optional[jnp.ndarray] = None,
            env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True) -> jnp.ndarray:
    hidden, _ = forward_hidden(params, cfg, tokens, patches, env)
    return cm.chunked_lm_loss(hidden, params["lm_head"], labels, env=env,
                               vocab_parallel=env.vocab_parallel)


# ---------------------------------------------------------------------------
# Serving: encoder runs once (its output lives in the cache); decoder steps.
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: Optional[int] = None) -> Params:
    dtype = cfg.activation_dtype
    enc_len = enc_len or max_len
    L = cfg.dec_layers
    return {
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), dtype),
        "k": jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        "v": jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, env: cm.ShardEnv = cm.NO_SHARD
                ) -> Tuple[jnp.ndarray, Params]:
    b = tokens.shape[0]
    pos = cache["pos"]
    h_, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    x = jnp.take(params["embed"], tokens, axis=0)
    enc_out = cache["enc_out"]

    def body(x, xs):
        p, kc, vc = xs
        hh = cm.rms_norm(x, p["ln1"], cfg.norm_eps)
        q = jnp.einsum("btd,dk->btk", hh, p["self_attn"]["wq"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
        kk = jnp.einsum("btd,dk->btk", hh, p["self_attn"]["wk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        vv = jnp.einsum("btd,dk->btk", hh, p["self_attn"]["wv"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        q = q.reshape(b, 1, h_, hd).transpose(0, 2, 1, 3)
        kk = kk.reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
        vv = vv.reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
        posv = jnp.full((b, 1), pos, jnp.int32)
        q = cm.apply_rope(q, posv, cfg.rope_theta)
        kk = cm.apply_rope(kk, posv, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kk, pos, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vv, pos, axis=2)
        o = cm.decode_attention(q, kc, vc, pos + 1)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, h_ * hd)
        x = x + jnp.einsum("btk,kd->btd", o, p["self_attn"]["wo"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
        hh = cm.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        x = x + _mha(p["cross_attn"], hh, enc_out, cfg, cm.NO_SHARD,
                     causal=False, rope=False)
        hh = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + cm.mlp_apply(p["mlp"], hh, cfg.mlp_type, env)
        return x, (kc, vc)

    x, (kcs, vcs) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                           cache["v"]))
    x = cm.rms_norm(x, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,dv->btv", x, params["lm_head"],
                        preferred_element_type=jnp.float32)
    new_cache = dict(cache, k=kcs, v=vcs, pos=pos + 1)
    return logits.astype(jnp.float32), new_cache
