"""Unified decoder-only transformer covering the dense / MoE / VLM archs.

Features selected per ``ModelConfig``: GQA, RoPE, sliding-window (mistral/
mixtral), local-global alternation + sandwich norms + logit soft-caps
(gemma2), QKV bias (qwen), squared-ReLU (nemotron), MoE (mixtral/grok),
vision-patch prefix (llava).

Layer stacks run as ``lax.scan`` over stacked per-layer params (layer-group
granularity so heterogeneous alternations stay scannable) with optional
per-group remat — this keeps the HLO size O(1) in depth, which is what makes
the 64-layer/314B dry-runs tractable.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig
from repro.models.moe import moe_apply, moe_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Layer groups: the repeating unit of the scan.  gemma2 alternates
# local/global, so its group is [local, global]; everything else has a
# single-layer group.
# ---------------------------------------------------------------------------


def group_size(cfg: ModelConfig) -> int:
    return cfg.local_global_period if cfg.local_global_period > 0 else 1


def n_groups(cfg: ModelConfig) -> int:
    g = group_size(cfg)
    assert cfg.n_layers % g == 0, (cfg.n_layers, g)
    return cfg.n_layers // g


def sublayer_window(cfg: ModelConfig, sub_idx: int) -> int:
    """Sliding window for sub-layer ``sub_idx`` of a group (0 = full attn)."""
    if cfg.local_global_period > 0:
        is_global = sub_idx == cfg.local_global_period - 1
        return 0 if is_global else cfg.attn_window
    return cfg.attn_window


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg: ModelConfig, dtype) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": cm.dense_init(ks[0], (d, h * hd), dtype),
        "wk": cm.dense_init(ks[1], (d, hkv * hd), dtype),
        "wv": cm.dense_init(ks[2], (d, hkv * hd), dtype),
        "wo": cm.dense_init(ks[3], (h * hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _layer_init(key, cfg: ModelConfig, dtype) -> Params:
    ka, km, kr = jax.random.split(key, 3)
    p: Params = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": _attn_init(ka, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.n_experts > 0:
        p["moe"] = moe_init(km, cfg, dtype)
    else:
        p["mlp"] = cm.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    if cfg.local_global_period > 0:  # gemma2 sandwich norms
        p["ln1_post"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln2_post"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = cfg.activation_dtype
    k_emb, k_layers, k_head, k_mm = jax.random.split(key, 4)
    g, ng = group_size(cfg), n_groups(cfg)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    groups = []
    for s in range(g):
        groups.append(cm.stack_layer_params(
            [layer_keys[i * g + s] for i in range(ng)],
            lambda kk: _layer_init(kk, cfg, dtype)))
    params: Params = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                   dtype) * 0.02,
        "groups": groups,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                          dtype)
    if cfg.frontend == "vision":
        k1, k2 = jax.random.split(k_mm)
        params["mm_proj"] = {
            "w1": cm.dense_init(k1, (cfg.frontend_dim, cfg.d_model), dtype),
            "w2": cm.dense_init(k2, (cfg.d_model, cfg.d_model), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _attn_apply(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, window: int, env: cm.ShardEnv,
                banded: bool) -> jnp.ndarray:
    b, t, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("btd,dk->btk", x, env.weight(p["wq"], 1),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("btd,dk->btk", x, env.weight(p["wk"], 1),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btd,dk->btk", x, env.weight(p["wv"], 1),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = env.act_bhtd(q.reshape(b, t, h, hd).transpose(0, 2, 1, 3))
    k = env.act_bhtd(k.reshape(b, t, hkv, hd).transpose(0, 2, 1, 3))
    v = env.act_bhtd(v.reshape(b, t, hkv, hd).transpose(0, 2, 1, 3))
    q = cm.apply_rope(q, positions, cfg.rope_theta)
    k = cm.apply_rope(k, positions, cfg.rope_theta)
    o = cm.attention_xla(q, k, v, causal=True, window=window,
                         softcap=cfg.attn_softcap, banded=banded)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, h * hd)
    out = jnp.einsum("btk,kd->btd", o, env.weight(p["wo"], 0),
                     preferred_element_type=env.out_proj_dtype())
    return out.astype(x.dtype)


def _block_apply(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, window: int, env: cm.ShardEnv,
                 banded: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One transformer block; returns (x, aux_loss)."""
    sandwich = cfg.local_global_period > 0
    h = cm.rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=True)
    h = _attn_apply(p["attn"], h, positions, cfg, window, env, banded)
    if sandwich:
        h = cm.rms_norm(h, p["ln1_post"], cfg.norm_eps, plus_one=True)
    x = env.act_btd(x + h)
    h = cm.rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=True)
    if cfg.n_experts > 0:
        h, aux = moe_apply(p["moe"], h, cfg, env)
    else:
        h = cm.mlp_apply(p["mlp"], h, cfg.mlp_type, env)
        aux = jnp.float32(0.0)
    if sandwich:
        h = cm.rms_norm(h, p["ln2_post"], cfg.norm_eps, plus_one=True)
    return env.act_btd(x + h), aux


def embed_inputs(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                 patches: Optional[jnp.ndarray], env: cm.ShardEnv
                 ) -> jnp.ndarray:
    """Token embeddings, with the VLM patch prefix projected + prepended."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.local_global_period > 0:  # gemma-style embedding scaling
        x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
    if patches is not None:
        pe = jnp.einsum("bpf,fd->bpd", patches.astype(x.dtype),
                        params["mm_proj"]["w1"],
                        preferred_element_type=jnp.float32)
        pe = jax.nn.gelu(pe)
        pe = jnp.einsum("bpd,de->bpe", pe.astype(x.dtype),
                        params["mm_proj"]["w2"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    return env.act_btd(x)


def forward_hidden(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   patches: Optional[jnp.ndarray] = None,
                   env: cm.ShardEnv = cm.NO_SHARD,
                   banded: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) [+ patches (B, P, F)] -> (final hidden (B,T,D), aux)."""
    x = embed_inputs(params, cfg, tokens, patches, env)
    t = x.shape[1]
    positions = jnp.arange(t)
    g = group_size(cfg)

    def group_body(carry, group_params):
        x, aux = carry
        for s in range(g):
            win = sublayer_window(cfg, s)
            x, a = _block_apply(group_params[s], x, positions, cfg, win, env,
                                banded)
            aux = aux + a
        return (x, aux), None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.float32(0.0)),
        tuple(params["groups"]))
    return cm.rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True), aux


def lm_head(params: Params, cfg: ModelConfig) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            patches: Optional[jnp.ndarray] = None,
            env: cm.ShardEnv = cm.NO_SHARD,
            banded: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) [+ patches (B, P, F)] -> (logits (B, T, V), aux)."""
    x, aux = forward_hidden(params, cfg, tokens, patches, env, banded)
    logits = jnp.einsum("btd,dv->btv", x, lm_head(params, cfg),
                        preferred_element_type=jnp.float32)
    if cfg.final_softcap > 0.0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return env.act_btv(logits.astype(jnp.float32)), aux


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, patches: Optional[jnp.ndarray] = None,
            env: cm.ShardEnv = cm.NO_SHARD, banded: bool = True) -> jnp.ndarray:
    hidden, aux = forward_hidden(params, cfg, tokens, patches, env, banded)
    if patches is not None:  # loss only over the text suffix
        hidden = hidden[:, patches.shape[1]:]
    loss = cm.chunked_lm_loss(hidden, lm_head(params, cfg), labels,
                              softcap=cfg.final_softcap, env=env,
                              vocab_parallel=env.vocab_parallel)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serving): KV caches with rolling buffers for windowed layers
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Cache pytree: per group-sublayer stacked (ng, B, Hkv, Tc, hd)."""
    dtype = cfg.activation_dtype
    ng = n_groups(cfg)
    caches = []
    for s in range(group_size(cfg)):
        win = sublayer_window(cfg, s)
        tc = min(win, max_len) if win > 0 else max_len
        caches.append({
            "k": jnp.zeros((ng, batch, cfg.n_kv_heads, tc, cfg.hd), dtype),
            "v": jnp.zeros((ng, batch, cfg.n_kv_heads, tc, cfg.hd), dtype),
        })
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}


def decode_block(p: Params, x: jnp.ndarray, kc: jnp.ndarray, vc: jnp.ndarray,
                 pos: jnp.ndarray, cfg: ModelConfig, win: int,
                 env: cm.ShardEnv) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One transformer block for a single decode token.  Returns
    (x, new_k_cache, new_v_cache).  ``win > 0`` caches are rolling buffers."""
    b = x.shape[0]
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    rolling = win > 0
    tc = kc.shape[2]
    sandwich = cfg.local_global_period > 0
    hh = cm.rms_norm(x, p["ln1"], cfg.norm_eps, plus_one=True)
    q = jnp.einsum("btd,dk->btk", hh, p["attn"]["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    kk = jnp.einsum("btd,dk->btk", hh, p["attn"]["wk"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    vv = jnp.einsum("btd,dk->btk", hh, p["attn"]["wv"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qkv_bias:
        q, kk, vv = (q + p["attn"]["bq"], kk + p["attn"]["bk"],
                     vv + p["attn"]["bv"])
    q = q.reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
    kk = kk.reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    vv = vv.reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = cm.apply_rope(q, posv, cfg.rope_theta)
    kk = cm.apply_rope(kk, posv, cfg.rope_theta)
    slot = (pos % tc) if rolling else jnp.minimum(pos, tc - 1)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kk, slot, axis=2)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, vv, slot, axis=2)
    o = cm.decode_attention(q, kc, vc, pos + 1, softcap=cfg.attn_softcap,
                            rolling=rolling)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    attn_out = jnp.einsum("btk,kd->btd", o, p["attn"]["wo"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
    if sandwich:
        attn_out = cm.rms_norm(attn_out, p["ln1_post"], cfg.norm_eps,
                               plus_one=True)
    x = x + attn_out
    hh = cm.rms_norm(x, p["ln2"], cfg.norm_eps, plus_one=True)
    if cfg.n_experts > 0:
        mlp_out, _ = moe_apply(p["moe"], hh, cfg, env)
    else:
        mlp_out = cm.mlp_apply(p["mlp"], hh, cfg.mlp_type, env)
    if sandwich:
        mlp_out = cm.rms_norm(mlp_out, p["ln2_post"], cfg.norm_eps,
                              plus_one=True)
    return x + mlp_out, kc, vc


def decode_step(params: Params, cfg: ModelConfig, cache: Params,
                tokens: jnp.ndarray, env: cm.ShardEnv = cm.NO_SHARD
                ) -> Tuple[jnp.ndarray, Params]:
    """One token for every sequence: tokens (B, 1) -> (logits (B, 1, V), cache)."""
    b = tokens.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.local_global_period > 0:
        x = (x.astype(jnp.float32) * math.sqrt(cfg.d_model)).astype(x.dtype)
    g = group_size(cfg)
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def group_body(carry, xs):
        x = carry
        group_params, group_caches = xs
        new_caches = []
        for s in range(g):
            win = sublayer_window(cfg, s)
            x, kc, vc = decode_block(group_params[s], x,
                                     group_caches[s]["k"],
                                     group_caches[s]["v"], pos, cfg, win, env)
            new_caches.append({"k": kc, "v": vc})
        return x, tuple(new_caches)

    (x), new_layer_caches = jax.lax.scan(
        group_body, x, (tuple(params["groups"]), tuple(cache["layers"])))
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps, plus_one=True)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("btd,dv->btv", x, head,
                        preferred_element_type=jnp.float32)
    if cfg.final_softcap > 0.0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    new_cache = {"layers": list(new_layer_caches), "pos": pos + 1}
    return logits.astype(jnp.float32), new_cache
