"""Public wrapper for the fused K-means assignment kernel.

Pads N to block multiples and K/D to lane multiples.  Padded center rows are
placed at +1e15 so no real sample ever selects them; padded sample rows are
masked inside the kernel via ``n``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.blocking import round_up
from repro.kernels.kmeans.kernel import kmeans_assign_padded

_FAR = 1e15


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(
    x: jnp.ndarray,        # (n, d)
    centers: jnp.ndarray,  # (k, d)
    *,
    block_n: int = 512,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n, d = x.shape
    k = centers.shape[0]
    bn = min(block_n, round_up(n, 128))
    n_pad = round_up(n, bn)
    d_pad = round_up(d, 128)
    k_pad = round_up(k, 8)
    x_p = jnp.pad(x, ((0, n_pad - n), (0, d_pad - d)))
    c_p = jnp.pad(centers.astype(x.dtype), ((0, k_pad - k), (0, d_pad - d)),
                  constant_values=0)
    if k_pad != k:
        far = jnp.zeros((k_pad, 1), x.dtype).at[k:].set(_FAR)
        c_p = c_p + far  # padded centers sit at (1e15, 0, ...): never nearest
    labels, sums, counts = kmeans_assign_padded(
        x_p, c_p, n=n, block_n=bn, interpret=interpret)
    return (labels[:n, 0], sums[:k, :d], counts[:k, 0])
