"""Fused K-means assignment kernel (paper §5.5's per-Subset task, fused).

One grid step processes a (block_n × D) tile of samples against the full
(K × D) center table resident in VMEM:

    distances (MXU: x·cᵀ) → argmin → one-hot → partial sums (MXU: onehotᵀ·x)

all without re-touching HBM — this is the entire per-iteration inner loop of
K-means as a single kernel.  The per-cluster sums/counts OUTPUT BLOCKS are
revisited by every grid step (index_map → block 0) with the K reduction
running over the sequential grid dimension, which is the TPU analogue of the
paper's partial-sum tasks + reduction tree (Fig. 3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _kmeans_kernel(x_ref, c_ref, labels_ref, sums_ref, counts_ref, *,
                   n: int, block_n: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]                      # (block_n, D)
    c = c_ref[...]                      # (K, D)
    dots = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    x_sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    c_sq = jnp.sum(c.astype(jnp.float32) ** 2, axis=1)
    dist = x_sq - 2.0 * dots + c_sq[None, :]          # (block_n, K)

    rows = i * block_n + jax.lax.broadcasted_iota(jnp.int32, dist.shape, 0)
    valid = rows < n
    labels = jnp.argmin(dist, axis=1).astype(jnp.int32)  # (block_n,)
    labels_ref[...] = jnp.where(valid[:, :1][:, 0], labels, -1)[:, None]

    k_iota = jax.lax.broadcasted_iota(jnp.int32, dist.shape, 1)
    onehot = ((labels[:, None] == k_iota) & valid).astype(jnp.float32)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x.astype(jnp.float32), (((0,), (0,)), ((), ())))
    counts_ref[...] += jnp.sum(onehot, axis=0)[:, None] * jnp.ones(
        (1, counts_ref.shape[1]), jnp.float32)


def kmeans_assign_padded(
    x: jnp.ndarray,        # (N_pad, D) pad rows beyond n
    centers: jnp.ndarray,  # (K_pad, D) pad centers pushed far away by ops
    *,
    n: int,
    block_n: int = 512,
    interpret: bool = False,
):
    n_pad, d = x.shape
    k_pad = centers.shape[0]
    assert n_pad % block_n == 0
    grid = (n_pad // block_n,)
    kernel = functools.partial(_kmeans_kernel, n=n, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((k_pad, d), lambda i: (0, 0)),
            pl.BlockSpec((k_pad, 128), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((k_pad, d), jnp.float32),
            jax.ShapeDtypeStruct((k_pad, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x, centers)
