"""Pure-jnp oracle for the fused K-means assignment kernel."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jnp.ndarray, centers: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """labels (n,), per-cluster sums (k, d), counts (k,)."""
    d2 = (jnp.sum(x ** 2, 1, keepdims=True)
          - 2.0 * x @ centers.T
          + jnp.sum(centers ** 2, 1)[None, :])
    labels = jnp.argmin(d2, axis=1)
    onehot = jax.nn.one_hot(labels, centers.shape[0], dtype=jnp.float32)
    sums = onehot.T @ x.astype(jnp.float32)
    counts = onehot.sum(0)
    return labels.astype(jnp.int32), sums, counts
