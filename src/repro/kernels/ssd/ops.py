"""Public SSD op: chunk kernel + inter-chunk associative scan + combine.

    y = y_intra + (C ⊙ decay) @ h_prev_chunk

The inter-chunk recurrence over (decay, state) pairs is associative:
    (d1, s1) ∘ (d2, s2) = (d1·d2, d2·s1 + s2)
so it runs as ``lax.associative_scan`` over the (tiny) per-chunk states —
O(log NC) depth, bytes ≈ NC·S·P — the same trick the paper uses for
reduction trees (Fig. 3), applied along the sequence axis.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.blocking import round_up
from repro.kernels.ssd.kernel import ssd_chunk_padded


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,    # (BH, T, P)
    dt: jnp.ndarray,   # (BH, T)
    a: jnp.ndarray,    # (BH,)
    b: jnp.ndarray,    # (BH, T, S)
    c: jnp.ndarray,    # (BH, T, S)
    h0: Optional[jnp.ndarray] = None,   # (BH, S, P)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (BH,T,P), h_final (BH,S,P))."""
    bh, t, p = x.shape
    s = b.shape[-1]
    t_pad = round_up(t, chunk)
    if t_pad != t:
        # pad with dt=0 steps: decay=exp(0)=1, input contribution 0 -> no-ops
        x = jnp.pad(x, ((0, 0), (0, t_pad - t), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, t_pad - t)))
        b = jnp.pad(b, ((0, 0), (0, t_pad - t), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, t_pad - t), (0, 0)))
    nc = t_pad // chunk

    y_intra, states, c_dec, chunk_dec = ssd_chunk_padded(
        x, dt[..., None], a[:, None], b, c, chunk=chunk, interpret=interpret)
    decays = chunk_dec[:, :, 0, 0]                       # (BH, NC)

    # inclusive associative scan over chunks: h_after[c]
    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, d2[..., None, None] * s1 + s2

    if h0 is not None:
        states = states.at[:, 0].add(decays[:, 0, None, None] * h0)
    d_acc, h_after = jax.lax.associative_scan(combine, (decays, states), axis=1)
    # h entering chunk c  =  h_after[c-1]  (h0-adjusted above)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_after[:, :1]), h_after[:, :-1]], axis=1)
    if h0 is not None:
        h_prev = h_prev.at[:, 0].set(h0)

    # y_inter[t] = (C_t exp(ell_t)) @ h_prev_chunk(t)
    c_dec_c = c_dec.reshape(bh, nc, chunk, s)
    y_inter = jnp.einsum("bnls,bnsp->bnlp", c_dec_c.astype(jnp.float32),
                         h_prev).reshape(bh, t_pad, p)
    y = (y_intra.astype(jnp.float32) + y_inter).astype(x.dtype)
    return y[:, :t], h_after[:, -1]


def ssd_decode_step(
    x: jnp.ndarray,    # (BH, P) one token
    dt: jnp.ndarray,   # (BH,)
    a: jnp.ndarray,    # (BH,)
    b: jnp.ndarray,    # (BH, S)
    c: jnp.ndarray,    # (BH, S)
    h: jnp.ndarray,    # (BH, S, P) carried state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrence for serving (no kernel needed: O(S·P) FMA)."""
    decay = jnp.exp(a * dt)[:, None, None]
    h = decay * h + dt[:, None, None] * (b[..., None] * x[:, None, :])
    y = jnp.einsum("bs,bsp->bp", c, h)
    return y.astype(x.dtype), h
