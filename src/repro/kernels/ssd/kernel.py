"""Mamba-2 SSD (state-space duality) chunk kernel.

The SSD insight (Dao & Gu, arXiv:2405.21060) is the paper's 2-D blocking idea
applied to the (sequence × state) plane: cut the sequence into chunks so the
recurrence

    h_t = exp(A·dt_t) h_{t-1} + dt_t · B_t x_tᵀ ,   y_t = C_t h_t

becomes, per chunk, three MXU matmuls (the "dual" quadratic form) plus a tiny
inter-chunk scan:

    y_intra = (C Bᵀ ⊙ decay-mask) @ (dt ⊙ x)          (L×L)·(L×P)
    state   = (B ⊙ dt ⊙ decay-to-end)ᵀ @ x            (S×L)·(L×P)
    y_inter = (C ⊙ decay-from-start) @ h_prev          (L×S)·(S×P)

This kernel computes the chunk-local quantities (everything except the
h_prev recurrence, which ops.py runs as an associative scan over chunk
states).  Grid = (batch·heads, n_chunks); per step the (L×P) x-tile, (L×S)
B/C tiles and the (L×L) decay tile live in VMEM.

All decays are exp of non-positive numbers (A<0, dt>0) — no overflow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, cdec_ref, chunk_dec_ref, *,
                      chunk: int):
    x = x_ref[0]            # (L, P)
    dt = dt_ref[0]          # (L, 1)
    a = a_ref[0, 0]         # scalar, negative, for this head
    b = b_ref[0]            # (L, S)
    c = c_ref[0]            # (L, S)

    lda = a * dt                                        # (L, 1) log-decays
    ell = jnp.cumsum(lda, axis=0)                       # (L, 1) inclusive
    # pairwise decay  exp(ell_t - ell_s)  masked to s <= t
    diff = ell - ell[:, 0][None, :]                     # (L, L): [t, s]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    mask = t_idx >= s_idx
    # clamp masked (s>t) region before exp: overflow there would be inf
    gate = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * gate                                   # (L, L)
    xdt = x * dt                                        # (L, P)
    y_ref[0] = jax.lax.dot_general(
        w.astype(x.dtype), xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # chunk state: sum_s exp(ell_L - ell_s) dt_s B_s x_sᵀ  -> (S, P)
    w_end = jnp.exp(ell[chunk - 1, 0] - ell)            # (L, 1)
    b_scaled = b * (w_end * dt)                         # (L, S)
    state_ref[0, 0] = jax.lax.dot_general(
        b_scaled, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(state_ref.dtype)

    # decayed C for the inter-chunk pass: C_t ⊙ exp(ell_t)
    cdec_ref[0] = (c * jnp.exp(ell)).astype(cdec_ref.dtype)
    # total chunk decay exp(ell_L) (lane-replicated scalar)
    chunk_dec_ref[0, 0] = (jnp.exp(ell[chunk - 1, 0])
                           * jnp.ones_like(chunk_dec_ref[0, 0]))


def ssd_chunk_padded(
    x: jnp.ndarray,    # (BH, T, P)
    dt: jnp.ndarray,   # (BH, T, 1)
    a: jnp.ndarray,    # (BH, 1)     negative per-head decay rates
    b: jnp.ndarray,    # (BH, T, S)
    c: jnp.ndarray,    # (BH, T, S)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    """Chunk-local SSD quantities; T must divide by ``chunk`` (ops pads).

    Returns (y_intra (BH,T,P), states (BH,NC,S,P), c_decayed (BH,T,S),
    chunk_decay (BH,NC,1,128))."""
    bh, t, p = x.shape
    s = b.shape[-1]
    assert t % chunk == 0
    nc = t // chunk
    grid = (bh, nc)
    kernel = functools.partial(_ssd_chunk_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, s, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, chunk, s), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, 1, 128), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, p), x.dtype),
            jax.ShapeDtypeStruct((bh, nc, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, t, s), x.dtype),
            jax.ShapeDtypeStruct((bh, nc, 1, 128), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, dt, a, b, c)
