"""Naive sequential-recurrence oracle for the SSD kernel.

    h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_tᵀ ;  y_t = C_t h_t

Runs as an O(T) ``lax.scan`` per (batch·head); exact (up to fp) and
independent of the chunked/dual formulation.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,    # (BH, T, P)
    dt: jnp.ndarray,   # (BH, T)
    a: jnp.ndarray,    # (BH,)
    b: jnp.ndarray,    # (BH, T, S)
    c: jnp.ndarray,    # (BH, T, S)
    h0: Optional[jnp.ndarray] = None,  # (BH, S, P)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (BH, T, P), h_final (BH, S, P))."""
    bh, t, p = x.shape
    s = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bh, s, p), jnp.float32)

    def per_head(xh, dth, ah, bh_, ch, h0h):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(ah * dtt) * h + dtt * (bt[:, None] * xt[None, :])
            return h, ct @ h

        h_fin, y = jax.lax.scan(step, h0h, (xh.astype(jnp.float32),
                                            dth.astype(jnp.float32),
                                            bh_.astype(jnp.float32),
                                            ch.astype(jnp.float32)))
        return y, h_fin

    y, h_fin = jax.vmap(per_head)(x, dt, a, b, c, h0)
    return y.astype(x.dtype), h_fin
