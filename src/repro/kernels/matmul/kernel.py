"""Tiled MXU matmul kernel — the local GEMM under every ds-array ``@``.

Tiling: C is computed one (block_m × block_n) VMEM tile at a time; the K
reduction runs as the innermost (sequential) grid dimension with an fp32
accumulator tile resident in VMEM, so each C tile is written to HBM exactly
once.  Block sizes default to 512×512×512 fp32-equivalents; all dims must be
multiples of 128 to keep the MXU systolic array full (the ops.py wrapper pads
arbitrary shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_padded(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 512,
    block_n: int = 512,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """C = A @ B for shapes already padded to block multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or a.dtype
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
