"""Tiled MXU matmul kernels — the local GEMM under every ds-array ``@``.

Two entry points:

* ``matmul_padded`` — dense 2-D ``(m, k) @ (k, n)`` on pre-padded shapes.
* ``stacked_matmul`` — the ds-array-native form: consumes the stacked block
  tensors ``(gi, gk, bn, bk) x (gk, gj, bk, bm)`` directly, grid dims as
  Pallas grid dims, so the distributed ``@`` lowers into ONE kernel launch
  with no relayout.

Both compute C one VMEM tile at a time; the whole K reduction (grid-k and
block-k) runs as the innermost (sequential) grid dimension with an fp32
accumulator tile resident in VMEM, so each C tile is written to HBM exactly
once.  Tile sizes default to 512³ fp32-equivalents; dims should be multiples
of 128 to keep the MXU systolic array full (the ops.py wrappers pad 2-D
shapes / fall back to einsum for non-MXU block shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _stacked_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                           transpose_a: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_tile = a_ref[0, 0].T if transpose_a else a_ref[0, 0]
    acc_ref[...] += jnp.dot(
        a_tile, b_ref[0, 0], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def _pick_tile(dim: int, target: int) -> int:
    """Sub-tile a block dim only when it divides evenly; else take it whole."""
    return target if (dim > target and dim % target == 0) else dim


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret",
                     "transpose_a"),
)
def stacked_matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 512,
    block_n: int = 512,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
    transpose_a: bool = False,
) -> jnp.ndarray:
    """Fused GEMM directly on stacked ds-array block tensors.

    ``(gi, gk, bn, bk) x (gk, gj, bk, bm) -> (gi, gj, bn, bm)``: the ds-array
    grid dims become Pallas grid dims and the whole k reduction — grid-k
    times block-k — runs as the innermost (sequential) grid dimension with
    one fp32 accumulator tile resident in VMEM, so each C tile is written to
    HBM exactly once.  This replaces the per-grid-k Python loop of vmapped
    2-D kernels (O(gk) pallas_call launches, each re-reading and re-writing
    the full C partial) with a single launch and no HBM round-trips for
    partial sums.

    ``transpose_a=True`` computes ``Aᵀ @ B`` with ``a`` still in its
    UNtransposed stacked layout ``(gk, gi, bk, bn)``: the transpose is folded
    into the A-operand block-index map (grid dims swapped) plus an in-VMEM
    tile transpose fed to the MXU — the relayout of the full stacked tensor
    that an eager ``A.T`` would materialize in HBM never happens.

    Block dims larger than ``block_*`` are sub-tiled when they divide evenly;
    otherwise the whole block is one tile (ds-array blocks are VMEM-sized by
    construction).  ``interpret=True`` runs the same kernel off-TPU.
    """
    if transpose_a:
        gk, gi, bk, bn = a.shape
        gk2, gj, bk2, bm = b.shape
    else:
        gi, gk, bn, bk = a.shape
        gk2, gj, bk2, bm = b.shape
    if gk != gk2 or bk != bk2:
        raise ValueError(f"stacked matmul inner mismatch {a.shape} x {b.shape}")
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    tm, tn, tk = (_pick_tile(bn, block_m), _pick_tile(bm, block_n),
                  _pick_tile(bk, block_k))
    fm, fn, fk = bn // tm, bm // tn, bk // tk
    grid = (gi * fm, gj * fn, gk * fk)
    if transpose_a:
        # A block (i, k) of Aᵀ lives at a[k, i] with dims (bk, bn): swap the
        # grid/sub-tile coordinates in the index map and transpose in VMEM
        a_spec = pl.BlockSpec((1, 1, tk, tm),
                              lambda i, j, k: (k // fk, i // fm, k % fk, i % fm))
    else:
        a_spec = pl.BlockSpec((1, 1, tm, tk),
                              lambda i, j, k: (i // fm, k // fk, i % fm, k % fk))
    return pl.pallas_call(
        functools.partial(_stacked_matmul_kernel, n_k=grid[2],
                          transpose_a=transpose_a),
        grid=grid,
        in_specs=[
            a_spec,
            pl.BlockSpec((1, 1, tk, tn),
                         lambda i, j, k: (k // fk, j // fn, k % fk, j % fn)),
        ],
        out_specs=pl.BlockSpec((1, 1, tm, tn),
                               lambda i, j, k: (i // fm, j // fn, i % fm, j % fn)),
        out_shape=jax.ShapeDtypeStruct((gi, gj, bn, bm), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)


def matmul_padded(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 512,
    block_n: int = 512,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    """C = A @ B for shapes already padded to block multiples."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    out_dtype = out_dtype or a.dtype
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, b)
