"""Public wrappers for the matmul kernels: padding, dtype and backend policy.

``matmul(a, b)`` accepts arbitrary (m, k) x (k, n) shapes; inputs are padded
to MXU-aligned block multiples (pad contributes zeros to the K reduction, so
results are exact) and the output is sliced back.

``local_matmul(a, b)`` is the local GEMM under every distributed ds-array
``@`` and every shmap schedule: it takes the stacked block tensors directly
and dispatches to the fused Pallas ``stacked_matmul`` kernel on TPU (or in
interpret mode), falling back to a stacked-block ``jnp.einsum`` off-TPU or
for shapes/dtypes the MXU path does not cover.  The backend can be forced
with the ``REPRO_GEMM`` env var (``pallas`` / ``interpret`` / ``einsum``) or
the ``backend=`` argument — tests use ``interpret`` to assert the Pallas
lowering without TPU hardware.

A BCOO-blocked A (``core.sparse``) takes the sparse dispatch table instead:
one ``bcoo_dot_general`` over (grid-k, block-k), never densifying A.
"""

from __future__ import annotations

import functools
import os
import sys
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import sparse as jsparse
from jax.experimental.sparse import BCOO

from repro.core.blocking import round_up
from repro.kernels.matmul.kernel import matmul_padded, stacked_matmul
from repro.obs import metrics as _metrics

# backend-dispatch decisions ("gemm.dispatch_*" in obs.snapshot()).  These
# count DECISIONS, not launches: inside a jitted plan body the dispatch
# (like its `_fire` hook) runs once at trace time — a span here would time
# tracing, not device work, so GEMM telemetry is counters only and per-op
# device time is the profiler's job (obs.profile).
_DISPATCHES = _metrics.CounterGroup(
    "gemm", ("dispatch_pallas", "dispatch_einsum", "dispatch_interpret",
             "dispatch_sparse"))


def _fire(site: str, **info) -> None:
    """Fault-injection hook: active only when ``repro.resilience.inject``
    is already imported (a chaos test armed it); clean runs pay one
    sys.modules lookup.  Fires at trace time, so an armed dispatch fault
    aborts the launch before any device work."""
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


_PALLAS_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)

# ---------------------------------------------------------------------------
# Sparse local GEMM: einsum-style dispatch table for BCOO-blocked operands.
#
# The sparse analogue of the backend policy below: a BCOO lhs contracts over
# BOTH the grid-k batch dim and the block-k sparse dim in ONE
# bcoo_dot_general (spec strings shown for reference — they are the einsum
# the dense fallback would run), so the stored entries are streamed exactly
# once and the sparse operand is never densified (no (bn, bk) dense
# intermediate appears in the jaxpr; asserted by tests/test_sparse.py).
# dot_general emits contracted-lhs-free dims first, hence the out_perm back
# to the stacked (gi, gj, bn, bm) layout.
# ---------------------------------------------------------------------------

_SPARSE_GEMM_SPECS = {
    # transpose_a: (einsum spec, ((contract), (batch)), out permutation)
    False: ("ikab,kjbc->ijac", (((1, 3), (0, 2)), ((), ())), (0, 2, 1, 3)),
    True:  ("kiba,kjbc->ijac", (((0, 2), (0, 2)), ((), ())), (0, 2, 1, 3)),
}


def _sparse_local_matmul(a: BCOO, b: jnp.ndarray, *, out_dtype,
                         transpose_a: bool) -> jnp.ndarray:
    """Blocked local GEMM with a BCOO-blocked A (see ``core.sparse``)."""
    if isinstance(b, BCOO):
        b = b.todense()         # sp @ sp densifies the right operand
    _, dimension_numbers, out_perm = _SPARSE_GEMM_SPECS[bool(transpose_a)]
    out = jsparse.bcoo_dot_general(a, b, dimension_numbers=dimension_numbers)
    return out.transpose(out_perm).astype(out_dtype)


def _mxu_aligned(bn: int, bk: int, bm: int) -> bool:
    """True when the block dims keep the MXU/VPU tiling constraints without
    implicit padding: sublane multiples of 8, lane multiples of 128."""
    return bn % 8 == 0 and bk % 128 == 0 and bm % 128 == 0


def gemm_backend(bn: int, bk: int, bm: int, dtype,
                 backend: Optional[str] = None) -> str:
    """Resolve the local-GEMM backend: "pallas" | "interpret" | "einsum".

    Priority: explicit ``backend`` arg > ``REPRO_GEMM`` env var > auto.  Auto
    picks the compiled Pallas kernel exactly when it can win: TPU backend,
    float dtype the fp32-accumulator path covers, MXU-aligned block dims.
    Everything else (CPU/GPU, ints, ragged blocks) takes the einsum path,
    which XLA fuses fine at small scale.
    """
    forced = (backend or os.environ.get("REPRO_GEMM", "auto")).lower()
    if forced in ("pallas", "interpret", "einsum"):
        return forced
    if forced != "auto":
        raise ValueError(
            f"unknown GEMM backend {forced!r}: want pallas|interpret|einsum|auto")
    if jax.default_backend() != "tpu":
        return "einsum"
    if dtype not in [jnp.dtype(d) for d in _PALLAS_DTYPES]:
        return "einsum"
    if not _mxu_aligned(bn, bk, bm):
        return "einsum"
    return "pallas"


def local_matmul(a: jnp.ndarray, b: jnp.ndarray, *, out_dtype=None,
                 backend: Optional[str] = None,
                 transpose_a: bool = False) -> jnp.ndarray:
    """Blocked local GEMM on stacked tiles: (gi,gk,bn,bk) x (gk,gj,bk,bm).

    The single entry point for every local contraction in the repo —
    ``DsArray.__matmul__``, SUMMA and Cannon bodies, the lazy plan's folded
    ``Aᵀ @ B`` — so the backend policy lives in one place.

    ``transpose_a=True`` computes ``Aᵀ @ B`` with ``a`` still in its
    untransposed stacked layout ``(gk, gi, bk, bn)``: both backends fold the
    transpose into the contraction (block-index maps for Pallas, a relabeled
    einsum otherwise) instead of materializing the transposed tensor.
    """
    if transpose_a:
        gk, gi, bk, bn = a.shape
        gk2, gj, bk2, bm = b.shape
    else:
        gi, gk, bn, bk = a.shape
        gk2, gj, bk2, bm = b.shape
    if gk != gk2 or bk != bk2:
        raise ValueError(f"local_matmul inner mismatch {a.shape} x {b.shape}")
    out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
    if isinstance(a, BCOO):
        _fire("gemm_dispatch", mode="sparse")
        _DISPATCHES.inc("dispatch_sparse")
        return _sparse_local_matmul(a, b, out_dtype=out_dtype,
                                    transpose_a=transpose_a)
    if isinstance(b, BCOO):
        b = b.todense()         # dense @ sp: right operand densifies
    mode = gemm_backend(bn, bk, bm, jnp.dtype(a.dtype), backend)
    _fire("gemm_dispatch", mode=mode)
    _DISPATCHES.inc(f"dispatch_{mode}")
    if mode == "einsum":
        preferred = None
        if jnp.issubdtype(a.dtype, jnp.floating):
            preferred = jnp.promote_types(a.dtype, jnp.float32)
        spec = "kiba,kjbc->ijac" if transpose_a else "ikab,kjbc->ijac"
        out = jnp.einsum(spec, a, b, preferred_element_type=preferred)
        return out.astype(out_dtype)
    return stacked_matmul(a, b, out_dtype=jnp.dtype(out_dtype),
                          interpret=(mode == "interpret"),
                          transpose_a=transpose_a)


def _pick_block(dim: int, target: int) -> int:
    """Largest multiple-of-128 block <= target that keeps padding small."""
    if dim <= 128:
        return 128
    return min(target, round_up(dim, 128))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 512,
    block_n: int = 512,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch {a.shape} @ {b.shape}")
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = matmul_padded(
        a_p, b_p, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n]
