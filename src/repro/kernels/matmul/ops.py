"""Public wrapper for the tiled matmul kernel: padding + dtype policy.

``matmul(a, b)`` accepts arbitrary (m, k) x (k, n) shapes; inputs are padded
to MXU-aligned block multiples (pad contributes zeros to the K reduction, so
results are exact) and the output is sliced back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.blocking import round_up
from repro.kernels.matmul.kernel import matmul_padded


def _pick_block(dim: int, target: int) -> int:
    """Largest multiple-of-128 block <= target that keeps padding small."""
    if dim <= 128:
        return 128
    return min(target, round_up(dim, 128))


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def matmul(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 512,
    block_n: int = 512,
    block_k: int = 512,
    out_dtype=None,
    interpret: bool = False,
) -> jnp.ndarray:
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch {a.shape} @ {b.shape}")
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = matmul_padded(
        a_p, b_p, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n]
