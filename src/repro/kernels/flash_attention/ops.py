"""Public wrapper for fused attention: padding, scale defaults, interpret plumbing.

Pads head_dim to 128 lanes and sequence lengths to block multiples.  Padded
KV positions are masked out via the window/causal machinery: we append pad
keys AFTER the logical keys and rely on causal masking for decode; for the
bidirectional/encoder case we pass an explicit kv length mask by baking the
pad region into ``window``-independent masking (pad keys get NEG_INF scores
because the kernel masks k_pos >= kv_len via the causal/window terms computed
here).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blocking import round_up
from repro.kernels.flash_attention.kernel import flash_attention_padded


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "sm_scale", "q_offset",
                     "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,   # (B, Hq, Tq, D)
    k: jnp.ndarray,   # (B, Hkv, Tk, D)
    v: jnp.ndarray,   # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    bq = min(block_q, round_up(tq, 128))
    bk = min(block_k, round_up(tk, 128))
    tq_p, tk_p, d_p = round_up(tq, bq), round_up(tk, bk), round_up(d, 128)

    # pad: Q rows beyond tq produce garbage rows we slice off; padded K
    # columns are hidden inside the kernel via the kv_len mask.
    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, tq_p - tq), (0, d_p - d)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, tk_p - tk), (0, d_p - d)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, tk_p - tk), (0, d_p - d)))

    out = flash_attention_padded(
        q_p, k_p, v_p, causal=causal, window=window, softcap=softcap,
        sm_scale=sm_scale, q_offset=q_offset, kv_len=tk, block_q=bq,
        block_k=bk, interpret=interpret)
    return out[:, :, :tq, :d]
