"""Fused attention kernel (FlashAttention-style online softmax) for TPU.

Covers the attention variants of the assigned architecture pool in ONE body:

* causal / bidirectional          (decoder LMs vs the seamless encoder)
* GQA                             (every LM arch: kv_heads <= q_heads)
* sliding window                  (mistral/llava, mixtral, gemma2 local layers)
* logit soft-capping              (gemma2, grok-1)

TPU adaptation (vs the CUDA flash-attention): the online-softmax state
(m, l, acc) lives in VMEM scratch across the sequential KV grid dimension;
each (q-block × kv-block) score tile is one MXU matmul.  Block shapes are
(block_q × head_dim) and (block_k × head_dim) with head_dim padded to 128
lanes by ops.py.  Grid = (batch, q_heads, q_blocks, kv_blocks) with the KV
dimension innermost/sequential ("arbitrary") so the scratch carry is legal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import tpu_compiler_params

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 n_kv: int, block_q: int, block_k: int, causal: bool,
                 window: int, softcap: float, sm_scale: float,
                 q_offset: int, kv_len: int):
    """One (q-block, kv-block) step of online-softmax attention.

    q_ref: (block_q, d); k_ref/v_ref: (block_k, d); o_ref: (block_q, d)
    scratch: m/l (block_q, 128) fp32 (lane-replicated), acc (block_q, d) fp32.
    ``q_offset`` shifts absolute q positions (decode: q_len << kv_len).
    """
    kv_idx = pl.program_id(3)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]
    k = k_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale   # (block_q, block_k)

    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    # absolute positions of this tile
    q_pos = (pl.program_id(2) * block_q + q_offset
             + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
    k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len            # hide KV padding
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                  # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)              # (block_q, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF): keep exp at 0
    p = jnp.exp(s - m_new)                                  # (block_q, block_k)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                          # (block_q, 1)
    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0, 0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kv_idx == n_kv - 1)
    def _flush():
        l = l_ref[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention_padded(
    q: jnp.ndarray,   # (B, Hq, Tq, D)
    k: jnp.ndarray,   # (B, Hkv, Tk, D)
    v: jnp.ndarray,   # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    sm_scale: float = 1.0,
    q_offset: int = 0,
    kv_len: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Attention over block-padded inputs. All of Tq % block_q, Tk % block_k,
    Hq % Hkv must be 0 (ops.py guarantees this). ``kv_len`` is the logical
    (unpadded) key count; 0 means Tk."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert tq % block_q == 0 and tk % block_k == 0 and hq % hkv == 0
    group = hq // hkv
    grid = (b, hq, tq // block_q, tk // block_k)

    kernel = functools.partial(
        _attn_kernel, n_kv=grid[3], block_q=block_q, block_k=block_k,
        causal=causal, window=window, softcap=softcap, sm_scale=sm_scale,
        q_offset=q_offset, kv_len=kv_len or tk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, iq, jk: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, iq, jk, g=group: (bb, h // g, jk, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, iq, jk, g=group: (bb, h // g, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, iq, jk: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
