"""Pure-jnp oracle for the fused attention kernel (all variants)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,   # (B, Hq, Tq, D)
    k: jnp.ndarray,   # (B, Hkv, Tk, D)
    v: jnp.ndarray,   # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    sm_scale=None,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(tq)[:, None] + q_offset
    k_pos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows -> zeros
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
