"""TPU Pallas kernels for the perf-critical compute layers.

Each kernel lives in its own subpackage with the contract:

* ``kernel.py`` -- the ``pl.pallas_call`` body + BlockSpec VMEM tiling,
* ``ops.py``    -- the jit'd public wrapper (padding, dtype policy,
  ``interpret=`` plumbing so CPU CI validates the kernel body),
* ``ref.py``    -- a pure-jnp oracle used by the allclose test sweeps.

Kernels: ``matmul`` (ds-array block GEMM), ``flash_attention`` (causal/GQA/
sliding-window/softcap), ``kmeans`` (fused assign+partial-sum, paper 5.5),
``ssd`` (Mamba-2 state-space-duality chunk scan).
"""
