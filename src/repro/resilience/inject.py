"""Deterministic fault injection for the plan executor and estimator fits.

Chaos engineering for the reproduction: every recovery path in
``repro.resilience`` (retry, degradation ladder, numerical guards,
checkpoint-resume) must be *provable* in CI, which means faults must be
raisable on demand, at an exact execution point, reproducibly.  This module
is that harness::

    with inject(FaultSpec(kind="transient", site="plan_execute", at=1)):
        out = run_resilient(lazy_expr)      # first launch fails, retry wins

A :class:`FaultSpec` names WHAT fails (``kind``), WHERE (``site`` — a
string the instrumented code passes to :func:`maybe_fire`), and WHEN
(``at``/``times`` count matching arrivals 1-based, or ``p``/``seed`` for a
seeded Bernoulli draw per arrival — both fully deterministic given the
spec, so a failing chaos test replays exactly).  Specs are armed by the
``inject`` context manager onto a module-level stack; instrumented sites
cost one truthy check on that stack when no injection is active, so the
clean path stays zero-overhead.

Sites instrumented across the repo:

====================  =====================================================
site                  where / info keys
====================  =====================================================
``plan_execute``      ``core.plan.Plan.execute`` (``mode="fused"``) and
                      ``Plan.execute_eager`` (``mode="eager"|"einsum"`` —
                      the degradation-ladder rungs)
``gemm_dispatch``     ``kernels.matmul.ops.local_matmul`` per local GEMM
                      (``mode=<resolved backend>`` or ``"sparse"``)
``fit_iteration``     each outer iteration of the checkpointable estimator
                      fits (``estimator=<class name>``, ``iteration=<n>``)
``io_load``           ``core.io`` loaders and ``checkpoint.restore``
                      (``source=<loader name>``); the streaming loaders
                      (``load_txt_file``/``load_svmlight_file``) also fire
                      once per chunk with ``block_row=<i>``, so mid-stream
                      failures are injectable — an abort leaves no partial
                      state (assembly is all-local)
``serve_dispatch``    ``serve.server.PredictServer`` per dispatch attempt
                      (``mode="batched"`` for a micro-batched plan launch,
                      ``mode="single"`` for the shed-batching unbatched
                      fallback; ``model=<name>``, ``requests=<n>``) — every
                      serving recovery path (dispatch retry, batch shed,
                      per-request isolation) is provable through it
====================  =====================================================

Fault kinds and the errors they raise:

* ``"transient"`` — :class:`TransientError` (simulated ``UNAVAILABLE`` /
  device-loss, the class of failure a retry absorbs);
* ``"oom"``       — :class:`OOMError` (simulated ``RESOURCE_EXHAUSTED``;
  for the degradation ladder, ``modes`` restricts firing to the execution
  modes that should keep failing, e.g. ``modes=("fused", "eager")`` forces
  the executor all the way down to the einsum rung);
* ``"crash"``     — :class:`CrashError` (a hard, non-retriable kill — used
  to prove checkpoint-resume of estimator fits);
* ``"io"``        — :class:`IOLoadError` (an ``OSError``: failed load);
* ``"poison"``    — raises nothing: :func:`poison_matches` returns the
  armed specs and the executor writes ``value`` (default NaN) into block
  ``block`` of root ``root`` *after* the op, so the numerical guards can
  prove they localize it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, List, Optional, Tuple


class FaultError(RuntimeError):
    """Base class for injected faults (marker: this failure is simulated)."""


class TransientError(FaultError):
    """Simulated transient executor failure (device loss / UNAVAILABLE)."""


class OOMError(FaultError):
    """Simulated RESOURCE_EXHAUSTED: allocation failure at dispatch."""


class CrashError(FaultError):
    """Simulated hard crash: non-retriable, kills the current driver loop."""


class IOLoadError(FaultError, OSError):
    """Simulated failed I/O load (checkpoint or data file)."""


_MESSAGES = {
    "transient": ("UNAVAILABLE: injected transient executor error "
                  "(simulated device loss)"),
    "oom": ("RESOURCE_EXHAUSTED: injected out of memory while allocating "
            "(simulated HBM OOM)"),
    "crash": "injected hard crash (simulated driver kill)",
    "io": "injected I/O failure (simulated unreadable load)",
}

_ERRORS = {"transient": TransientError, "oom": OOMError,
           "crash": CrashError, "io": IOLoadError}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative description of one injectable fault.

    ``at``/``times`` select arrivals by count (1-based over arrivals that
    match ``site``/``modes``/``where``): fire on arrivals
    ``at .. at+times-1``; ``times=None`` keeps firing from ``at`` onward.
    ``p`` (with ``seed``) instead draws a seeded Bernoulli per matching
    arrival — a deterministic pseudo-random fault schedule.
    """

    kind: str                               # transient|oom|crash|io|poison
    site: Optional[str] = None              # None: any instrumented site
    at: int = 1
    times: Optional[int] = 1
    p: Optional[float] = None
    seed: int = 0
    modes: Tuple[str, ...] = ()             # restrict to execution modes
    where: Optional[Dict[str, object]] = None   # extra info filters
    block: Optional[Tuple[int, int]] = None     # poison: block coordinate
    root: int = 0                               # poison: which plan root
    value: float = math.nan                     # poison: injected value

    def __post_init__(self):
        if self.kind not in ("transient", "oom", "crash", "io", "poison"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "poison" and self.block is None:
            raise ValueError("poison faults need a block=(gi, gj) coordinate")


class _Armed:
    """Runtime state of one armed spec: the deterministic arrival counter
    (and, for ``p`` specs, the seeded draw sequence)."""

    __slots__ = ("spec", "hits", "fired", "_rng")

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self.hits = 0
        self.fired = 0
        self._rng = None
        if spec.p is not None:
            import numpy as np
            self._rng = np.random.default_rng(spec.seed)

    def matches(self, site: str, info: Dict[str, object]) -> bool:
        s = self.spec
        if s.site is not None and s.site != site:
            return False
        if s.modes and info.get("mode") not in s.modes:
            return False
        if s.where:
            for k, v in s.where.items():
                if info.get(k) != v:
                    return False
        return True

    def arrive(self) -> bool:
        """Count one matching arrival; True when the fault fires."""
        self.hits += 1
        if self._rng is not None:
            fire = bool(self._rng.random() < self.spec.p)
        else:
            fire = self.hits >= self.spec.at and (
                self.spec.times is None
                or self.hits < self.spec.at + self.spec.times)
        if fire:
            self.fired += 1
        return fire


# The armed-spec stack.  Instrumented sites check truthiness before doing
# any work, so un-injected runs pay one list lookup per site.
_STACK: List[_Armed] = []


def active() -> bool:
    return bool(_STACK)


@contextlib.contextmanager
def inject(*specs: FaultSpec):
    """Arm the given specs for the dynamic extent of the block.  Yields the
    armed states (``.hits`` / ``.fired`` are readable for assertions).
    Nested ``inject`` blocks stack; counters reset on every entry."""
    armed = [_Armed(s) for s in specs]
    _STACK.extend(armed)
    try:
        yield armed
    finally:
        for a in armed:
            _STACK.remove(a)


def maybe_fire(site: str, **info) -> None:
    """Instrumentation hook: raise the armed fault matching this arrival.

    Poison specs never raise here — they are applied to results via
    :func:`poison_matches`.  Arrival counting happens for every matching
    armed spec (so two specs at the same site count independently).
    """
    if not _STACK:
        return
    for armed in list(_STACK):
        if armed.spec.kind == "poison" or not armed.matches(site, info):
            continue
        if armed.arrive():
            raise _ERRORS[armed.spec.kind](
                f"{_MESSAGES[armed.spec.kind]} [site={site}"
                + (f", mode={info['mode']}" if "mode" in info else "")
                + f", arrival={armed.hits}]")


def poison_matches(site: str, **info) -> List[FaultSpec]:
    """The poison specs firing at this arrival (counted like any other)."""
    if not _STACK:
        return []
    out = []
    for armed in list(_STACK):
        if armed.spec.kind != "poison" or not armed.matches(site, info):
            continue
        if armed.arrive():
            out.append(armed.spec)
    return out
