"""Resilience subsystem: fault injection, guarded execution, numerical guards.

The paper's ds-array inherits failure handling from PyCOMPSs; this package is
the reproduction's equivalent substrate, in three layers:

* :mod:`repro.resilience.inject` — deterministic, seeded, context-scoped
  fault injection (``with inject(FaultSpec(...)):``) so every recovery path
  is provable in CI;
* :mod:`repro.resilience.execute` — :func:`run_resilient` with error
  classification, bounded retry + exponential backoff for transients, and
  the OOM degradation ladder fused → eager → einsum;
* :mod:`repro.resilience.guards` — block-granular numerical guards
  (``DsArray.finite_report()``, ``guard_finite``,
  :class:`NumericalDivergence`).

Import order matters for the rest of the repo: ``inject`` is
dependency-free, so ``core.plan``, ``kernels.matmul.ops``, ``checkpoint``
and the estimators import it directly without cycles.  ``execute`` and
``guards`` sit above core and are imported lazily where needed.
"""

from repro.resilience.execute import (
    DETERMINISTIC,
    OOM,
    TRANSIENT,
    RetryPolicy,
    classify_error,
    reset_stats,
    run_resilient,
    stats,
)
from repro.resilience.guards import (
    BadBlock,
    FiniteReport,
    NumericalDivergence,
    all_finite,
    finite_report,
    guard_finite,
    poison_block,
    require_finite_host,
)
from repro.resilience.inject import (
    CrashError,
    FaultError,
    FaultSpec,
    IOLoadError,
    OOMError,
    TransientError,
    inject,
    maybe_fire,
    poison_matches,
)

__all__ = [
    "BadBlock",
    "CrashError",
    "DETERMINISTIC",
    "FaultError",
    "FaultSpec",
    "FiniteReport",
    "IOLoadError",
    "NumericalDivergence",
    "OOM",
    "OOMError",
    "RetryPolicy",
    "TRANSIENT",
    "TransientError",
    "all_finite",
    "classify_error",
    "finite_report",
    "guard_finite",
    "inject",
    "maybe_fire",
    "poison_block",
    "poison_matches",
    "require_finite_host",
    "reset_stats",
    "run_resilient",
    "stats",
]
