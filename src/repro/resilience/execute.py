"""Guarded plan execution: error classification, retry, degradation ladder.

The paper's runtime (PyCOMPSs) absorbs task failures for free — a died task
is re-submitted, the data structure survives.  The jit-compiled executor has
no runtime underneath it, so the resilience has to live in the driver:
:func:`run_resilient` wraps a plan execution with

1. **classification** (:func:`classify_error`) — *transient* failures
   (device loss, UNAVAILABLE, interconnect hiccups) are worth retrying;
   *oom* (RESOURCE_EXHAUSTED) is deterministic for the same program but
   recoverable by running a cheaper program; everything else is
   *deterministic* — retrying recomputes the same failure, so it raises
   immediately (unlike the seed's ``run_with_restarts``, which burned
   ``max_failures`` restarts on any exception whatsoever);

2. **retry with exponential backoff** for transients, bounded by
   ``RetryPolicy.max_retries``;

3. **a degradation ladder** for OOM: the fused jitted plan (one XLA
   program, peak-HBM heavy — every intermediate of the fused body is live
   inside one launch) degrades to per-node eager execution (each DAG node
   its own dispatch: smaller peak, more launches), then to the einsum GEMM
   backend (``REPRO_GEMM=einsum`` — no Pallas VMEM accumulator, XLA picks
   its own tiling).  Results are bit-compatible modulo float reassociation,
   so a degraded execution still satisfies the differential oracle;

4. an optional **numerical post-condition** (``guard="finite"``) — one
   fused reduction per root on the clean path, block-coordinate
   :class:`~repro.resilience.guards.NumericalDivergence` on failure.

Counters (``stats()``) record retries / degradations / recoveries so tests
and benchmarks can assert the clean path is clean (all zeros) and each
recovery path actually ran.
"""

from __future__ import annotations

import dataclasses
import re
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core import expr as _expr
from repro.core import plan as _plan
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing
from repro.resilience import inject as _inject
from repro.resilience.guards import NumericalDivergence, guard_finite, \
    poison_block

# ---------------------------------------------------------------------------
# Error classification
# ---------------------------------------------------------------------------

TRANSIENT = "transient"
OOM = "oom"
DETERMINISTIC = "deterministic"

# message patterns for errors that arrive as opaque runtime exceptions
# (jaxlib raises XlaRuntimeError with the gRPC status baked into the text)
_OOM_PAT = re.compile(
    r"RESOURCE_EXHAUSTED|out of memory|\bOOM\b|allocat\w* .*exceed", re.I)
_TRANSIENT_PAT = re.compile(
    r"UNAVAILABLE|DEADLINE_EXCEEDED|ABORTED|device.{0,20}(lost|halt|reset)"
    r"|data transfer|socket closed|connection reset", re.I)

# programming / numerical errors: retrying re-raises the same thing
_DETERMINISTIC_TYPES = (
    NumericalDivergence, ArithmeticError, ValueError, TypeError,
    AssertionError, KeyError, IndexError, AttributeError, NameError,
    NotImplementedError,
)


def classify_error(exc: BaseException, default: str = DETERMINISTIC) -> str:
    """``"transient"`` | ``"oom"`` | ``"deterministic"`` for an executor
    exception.

    Injected faults classify by type; real runtime errors by status-message
    pattern; known programming/numerical error types are deterministic.
    ``default`` decides the unknown remainder: plan execution uses
    ``"deterministic"`` (an unexplained failure of a pure function will
    recur), while ``run_with_restarts`` passes ``"transient"`` (a training
    step touches hosts, disks and interconnects — the seed's
    retry-everything behaviour stays its backstop).
    """
    if isinstance(exc, _inject.OOMError):
        return OOM
    if isinstance(exc, _inject.TransientError):
        return TRANSIENT
    if isinstance(exc, (_inject.CrashError, _inject.IOLoadError)):
        return DETERMINISTIC
    if isinstance(exc, MemoryError):
        return OOM
    if isinstance(exc, _DETERMINISTIC_TYPES):
        return DETERMINISTIC
    msg = str(exc)
    if _OOM_PAT.search(msg):
        return OOM
    if _TRANSIENT_PAT.search(msg):
        return TRANSIENT
    return default


# ---------------------------------------------------------------------------
# Policy + stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How hard to try: retries/backoff for transients, ladder for OOM.

    ``retriable`` overrides :func:`classify_error` (same contract: exception
    -> class string).  ``backoff`` is the first sleep; each further retry
    multiplies by ``backoff_factor`` up to ``max_backoff`` (exponential
    backoff — hammering a recovering device makes device loss worse).
    """

    max_retries: int = 3
    backoff: float = 0.0
    backoff_factor: float = 2.0
    max_backoff: float = 2.0
    retriable: Optional[Callable[[BaseException], str]] = None
    ladder: Tuple[str, ...] = ("fused", "eager", "einsum")

    def classify(self, exc: BaseException) -> str:
        if self.retriable is not None:
            return self.retriable(exc)
        return classify_error(exc)

    def delay(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (1-based)."""
        if self.backoff <= 0.0:
            return 0.0
        return min(self.backoff * self.backoff_factor ** (attempt - 1),
                   self.max_backoff)


# registered as "resilience.*" in the obs registry; CounterGroup.inc is a
# LOCKED increment — these counters are hit from PredictServer worker
# threads, where the old dict's bare `+=` read-modify-write lost updates
_STATS = _metrics.CounterGroup(
    "resilience", ("executions", "retries", "degradations", "recoveries",
                   "guard_failures"))


def stats() -> Dict[str, int]:
    """Counters since the last :func:`reset_stats` — the resilience
    analogue of ``plan.cache_stats()``; tests assert the clean path shows
    zero retries/degradations and each chaos test shows its recovery."""
    return _STATS.as_dict()


def reset_stats() -> None:
    _STATS.reset()


# ---------------------------------------------------------------------------
# Guarded execution
# ---------------------------------------------------------------------------


def _as_plan(exprs: Sequence) -> _plan.Plan:
    if len(exprs) == 1 and isinstance(exprs[0], _plan.Plan):
        return exprs[0]
    roots = [e.expr if isinstance(e, (_expr.LazyDsArray, _expr.LazyScalar))
             else e for e in exprs]
    return _plan.Plan(roots)


def _execute_rung(p: _plan.Plan, rung: str) -> tuple:
    if rung == "fused":
        return p.execute()
    if rung == "eager":
        return p.execute_eager()
    if rung == "einsum":
        return p.execute_eager(backend="einsum")
    raise ValueError(f"unknown ladder rung {rung!r}")


def run_resilient(*exprs, policy: Optional[RetryPolicy] = None,
                  guard: Optional[str] = None):
    """Execute recorded expression(s) (or a prepared :class:`~repro.core.plan.Plan`)
    with retry + degradation + optional numerical guard.

    Single expression returns its value; several return a tuple (the
    ``compute`` / ``compute_multi`` shapes).  The clean path is one extra
    function call and a counter bump around ``Plan.execute`` — plan
    optimizer and compile caches behave exactly as under ``compute()``
    (``opt_runs == 1`` hot loops keep holding).

    ``guard="finite"`` arms the whole-plan finiteness post-condition.
    """
    if guard not in (None, "finite"):
        raise ValueError(f"unknown guard {guard!r} (want None or 'finite')")
    pol = policy or RetryPolicy()
    p = _as_plan(exprs)
    _STATS.inc("executions")
    rung_i = 0
    attempts = 0
    recovered = False
    while True:
        rung = pol.ladder[rung_i]
        try:
            # one span per ATTEMPT (failed ones carry an "error" attr), so
            # a trace shows every rung the ladder walked, not just the win
            with _tracing.span("resilience.rung", rung=rung,
                               attempt=attempts):
                out = _execute_rung(p, rung)
            break
        except Exception as exc:                         # noqa: BLE001
            kind = pol.classify(exc)
            if kind == TRANSIENT and attempts < pol.max_retries:
                attempts += 1
                _STATS.inc("retries")
                recovered = True
                d = pol.delay(attempts)
                if d > 0.0:
                    time.sleep(d)
                continue
            if kind == OOM and rung_i + 1 < len(pol.ladder):
                rung_i += 1
                attempts = 0
                _STATS.inc("degradations")
                recovered = True
                continue
            raise
    if recovered:
        _STATS.inc("recoveries")
    # post-op poison (chaos for the guards): armed specs write NaN/Inf into
    # a named block coordinate of a named root
    for spec in _inject.poison_matches("plan_result"):
        from repro.core.dsarray import DsArray
        if spec.root < len(out) and isinstance(out[spec.root], DsArray):
            out = tuple(
                poison_block(v, spec.block, spec.value) if i == spec.root
                else v for i, v in enumerate(out))
    if guard == "finite":
        try:
            guard_finite(*out)
        except NumericalDivergence:
            _STATS.inc("guard_failures")
            raise
    return out[0] if len(out) == 1 else out
