"""Block-granular numerical guards for ds-arrays and plan outputs.

Long-running iterative fits diverge numerically long before they crash: one
NaN in one block propagates through every GEMM it touches and the fit
silently converges to garbage.  The runtime the paper rides (PyCOMPSs)
surfaces *task* failures; numerical failures need their own guard layer, and
it has to be block-granular — on a distributed array, "there is a NaN
somewhere in 2 GB" is not an actionable report, "block (3, 1) at offset
(2, 7)" is (the same philosophy as ``DsArray.check_invariants()``).

Three levels, cheapest first:

* :func:`all_finite` — ONE fused reduction over an array (pad-state aware:
  a DIRTY or non-finite FILL pad is masked out first, so pads never
  false-positive); this is the per-execution post-condition
  ``run_resilient(..., guard="finite")`` runs on the clean path.
* :func:`finite_report` — the block-granular diagnosis, host-side: per-block
  NaN/Inf counts with the first offending offset, dense and BCOO
  (``DsArray.finite_report()`` delegates here).  Only built when the cheap
  check already failed.
* :func:`require_finite_host` — guard for small host-side arrays (solver
  outputs); the single API behind the previously ad-hoc ``np.isfinite``
  checks in ``estimators.linear``.

All failures raise :class:`NumericalDivergence`, which carries the
structured report — ``run_with_restarts`` and ``run_resilient`` classify it
as *deterministic* (retrying a NaN recomputes the NaN).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dsarray import DsArray


class NumericalDivergence(ArithmeticError):
    """A guarded value contains NaN/Inf.  ``report`` holds the
    :class:`FiniteReport` (None for host-scalar guards)."""

    def __init__(self, message: str, report: Optional["FiniteReport"] = None):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass(frozen=True)
class BadBlock:
    """One offending block: coordinate, counts, and the first bad site
    (dense: in-block offset; bcoo: entry slot)."""

    gi: int
    gj: int
    n_nan: int
    n_inf: int
    first: Tuple[int, ...]      # (bi, bj) dense offset | (slot,) bcoo
    sparse: bool = False

    def describe(self) -> str:
        what = []
        if self.n_nan:
            what.append(f"{self.n_nan} nan")
        if self.n_inf:
            what.append(f"{self.n_inf} inf")
        site = (f"slot {self.first[0]}" if self.sparse
                else f"offset {self.first}")
        return f"block ({self.gi}, {self.gj}): {' + '.join(what)}, " \
               f"first at {site}"


@dataclasses.dataclass(frozen=True)
class FiniteReport:
    """Block-granular finiteness report for one ds-array."""

    shape: Tuple[int, int]
    block_format: str
    bad_blocks: Tuple[BadBlock, ...]

    @property
    def ok(self) -> bool:
        return not self.bad_blocks

    def describe(self) -> str:
        if self.ok:
            return f"all finite ({self.block_format} {self.shape})"
        lines = "; ".join(b.describe() for b in self.bad_blocks[:8])
        more = len(self.bad_blocks) - 8
        if more > 0:
            lines += f"; ... {more} more block(s)"
        return (f"non-finite values in {len(self.bad_blocks)} block(s) of "
                f"{self.block_format} ds-array {self.shape}: {lines}")


def _dense_valid_mask(a: DsArray) -> np.ndarray:
    """(sgn, sgm, bn, bm) bool: True on positions inside the logical shape."""
    sgn, sgm = a.stacked_grid
    bn, bm = a.block_shape
    n, m = a.shape
    rows = (np.arange(sgn)[:, None] * bn + np.arange(bn)[None, :]) < n
    cols = (np.arange(sgm)[:, None] * bm + np.arange(bm)[None, :]) < m
    return rows[:, None, :, None] & cols[None, :, None, :]


def finite_report(a: DsArray) -> FiniteReport:
    """Per-block NaN/Inf diagnosis (host-side; pad-state aware).

    Dense: only positions inside the logical shape count — a DIRTY pad
    holding NaN is the pad's business, not a divergence.  BCOO: every stored
    entry counts (a non-finite stored value poisons any data map that
    touches it, pad slot or not); reported as ``block (gi, gj) slot k`` in
    the ``check_invariants`` style.
    """
    if a.is_sparse:
        data = np.asarray(a.blocks.data)                   # (gn, gm, nse)
        bad_nan = np.isnan(data)
        bad_inf = np.isinf(data)
        bad = bad_nan | bad_inf
        blocks = []
        for gi, gj in zip(*np.nonzero(bad.any(axis=-1))):
            slot = int(np.flatnonzero(bad[gi, gj])[0])
            blocks.append(BadBlock(
                int(gi), int(gj), int(bad_nan[gi, gj].sum()),
                int(bad_inf[gi, gj].sum()), (slot,), sparse=True))
        return FiniteReport(a.shape, "bcoo", tuple(blocks))
    g = np.asarray(a.blocks)
    valid = _dense_valid_mask(a)
    bad_nan = np.isnan(g) & valid
    bad_inf = np.isinf(g) & valid
    bad = bad_nan | bad_inf
    blocks = []
    for gi, gj in zip(*np.nonzero(bad.any(axis=(2, 3)))):
        bi, bj = (int(v) for v in np.argwhere(bad[gi, gj])[0])
        blocks.append(BadBlock(
            int(gi), int(gj), int(bad_nan[gi, gj].sum()),
            int(bad_inf[gi, gj].sum()), (bi, bj)))
    return FiniteReport(a.shape, "dense", tuple(blocks))


def _pad_is_finite(a: DsArray) -> bool:
    """True when the pad region is known finite (so raw blocks can be
    checked without a mask pass)."""
    ps = a.pad_state
    if ps.kind == "zero":
        return True
    if ps.kind == "fill":
        return bool(math.isfinite(float(ps.fill)))
    return False


def all_finite(value) -> bool:
    """ONE fused finiteness reduction over a ds-array / array / scalar.

    The cheap whole-plan post-condition: for a ds-array whose pad is known
    finite this is ``isfinite(blocks).all()`` on the raw stacked tensor (no
    mask pass); a DIRTY pad masks first so an intentionally-unknown pad
    region never false-positives.
    """
    if isinstance(value, DsArray):
        if value.is_sparse:
            return bool(jnp.isfinite(value.blocks.data).all())
        blocks = value.blocks if _pad_is_finite(value) else value._remask()
        return bool(jnp.isfinite(blocks).all())
    if not jnp.issubdtype(jnp.asarray(value).dtype, jnp.floating):
        return True
    return bool(jnp.isfinite(jnp.asarray(value)).all())


def guard_finite(*values, what: str = "plan output"):
    """Post-condition: every value is finite, else :class:`NumericalDivergence`.

    Clean path cost: one fused reduction per value.  On failure the
    block-granular :func:`finite_report` is built (only then) and its
    coordinates go into the error message.  Integer-dtype values pass for
    free.  Returns the values (single value un-tupled) for chaining.
    """
    for i, v in enumerate(values):
        if isinstance(v, DsArray):
            if jnp.issubdtype(v.dtype, jnp.floating) and not all_finite(v):
                rep = finite_report(v)
                raise NumericalDivergence(
                    f"{what}[{i}]: {rep.describe()}", rep)
        elif not all_finite(v):
            raise NumericalDivergence(
                f"{what}[{i}]: non-finite scalar/array value "
                f"{np.asarray(v)!r}")
    return values[0] if len(values) == 1 else values


def require_finite_host(arr: np.ndarray, what: str) -> np.ndarray:
    """Small host-side arrays (solver outputs): raise on NaN/Inf.

    The single API behind the former ad-hoc ``np.isfinite(...).all()``
    checks in ``estimators.linear`` — callers that treat divergence as a
    fallback trigger catch :class:`NumericalDivergence` alongside
    ``LinAlgError``.
    """
    a = np.asarray(arr)
    if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
        n_nan = int(np.isnan(a).sum())
        n_inf = int(np.isinf(a).sum())
        raise NumericalDivergence(
            f"{what}: {n_nan} nan + {n_inf} inf in shape {a.shape}")
    return arr


def poison_block(a: DsArray, block: Tuple[int, int],
                 value: float = math.nan) -> DsArray:
    """``a`` with ``value`` written into one position of block ``block`` —
    the fault-injection side of the guards (dense: offset (0, 0) of the
    block; bcoo: entry slot 0 of the block).  Used by ``run_resilient`` to
    apply armed poison specs, and directly by tests."""
    gi, gj = block
    sgn, sgm = a.stacked_grid
    if not (0 <= gi < sgn and 0 <= gj < sgm):
        raise ValueError(f"block {block} outside stacked grid {(sgn, sgm)}")
    if a.is_sparse:
        data = a.blocks.data.at[gi, gj, 0].set(value)
        from repro.core.sparse import _rebuild
        return DsArray(_rebuild(a.blocks, data, a.blocks.indices),
                       a.grid, a.pad_state)
    blocks = a.blocks.at[gi, gj, 0, 0].set(
        jnp.asarray(value, a.blocks.dtype))
    return DsArray(blocks, a.grid, a.pad_state)
