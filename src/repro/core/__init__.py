"""repro.core — the ds-array distributed data structure (the paper's contribution).

Public API mirrors the paper's NumPy-like interface (§4.2.3): creation
routines, indexing, elementwise algebra, reductions, transpose, matmul,
shuffles, plus explicit-collective variants for performance work.
"""

from repro.core.blocking import BlockGrid, ceil_div, round_up
from repro.core.dsarray import (
    DsArray,
    PAD_DIRTY,
    PAD_ZERO,
    PadState,
    apply_along_axis,
    concat_rows,
    eye,
    from_array,
    full,
    identity_like,
    matmul_ta,
    pad_state_of,
    random_array,
    zeros,
)
from repro.core.shuffle import exact_shuffle, pseudo_shuffle
from repro.core import compat, costmodel, structural
from repro.core import sparse
from repro.core.sparse import from_scipy, random_sparse
from repro.core import expr, plan
from repro.core.expr import LazyDsArray, lazy
from repro.core.plan import compute, compute_multi
from repro.core.structural import gram, take_cols, take_rows
from repro.core.dataset_baseline import Dataset, Subset, TaskCounter

__all__ = [
    "BlockGrid", "DsArray", "Dataset", "Subset", "TaskCounter",
    "PadState", "PAD_ZERO", "PAD_DIRTY", "pad_state_of",
    "from_array", "zeros", "full", "eye", "identity_like", "random_array",
    "concat_rows", "pseudo_shuffle", "exact_shuffle", "costmodel",
    "compat", "structural", "gram", "take_rows", "take_cols",
    "apply_along_axis", "matmul_ta",
    "sparse", "from_scipy", "random_sparse",
    "expr", "plan", "LazyDsArray", "lazy", "compute", "compute_multi",
    "ceil_div", "round_up",
]
