"""DsArray: the paper's distributed array, adapted to JAX/TPU.

A ds-array is a 2-D array divided into blocks of arbitrary size that live on
different workers and are operated on by per-block parallel tasks behind a
NumPy-like API (paper §4.2).  The TPU-native representation used here is a
single **stacked block tensor** of shape ``(gn, gm, bn, bm)`` — grid dims
first, block dims last — which is the direct SPMD analogue of the paper's
list-of-lists-of-blocks:

* grid cell (i, j)               <->  paper block (i, j)
* sharding grid dims over a mesh <->  PyCOMPSs placing blocks on workers
* vectorized op over grid dims   <->  one PyCOMPSs task per block
* XLA collective                 <->  inter-worker future transfer

Everything is a pure function of the stacked tensor, so a DsArray traces
through ``jax.jit`` and shards with ``NamedSharding(P(axis0, axis1))`` on the
grid dims.  Edge blocks are zero-padded; the **pad-is-zero invariant** is
maintained by every public op (re-masking is a fused, nearly-free op under
jit) so reductions and matmuls never see garbage.

Structural-op complexity (paper §5 claims, as implemented by
``core.structural``; N = n*m elements, "seed" = the old
materialize-then-reblock path this replaced):

======================  ========================  ==========================
op                      seed path                 block-native path
======================  ========================  ==========================
aligned ``A[r0:r1,...]``  O(N) gather + repack      O(selected blocks) view
unaligned slice/stride  O(N) + gather             O(out) single block gather
row filter ``A[idx]``   O(N) + gather             O(out) single block gather
``rechunk`` (dividing)  O(N) two global layouts   O(N) one regroup reshape
``rechunk`` (general)   O(N) two global layouts   O(N) two block gathers
``concat_rows`` aligned O(sum N_i) x2             O(1) block-grid stack
======================  ========================  ==========================

None of the block-native paths form a rank-2 global ``(n, m)`` tensor, so
they compose with ``jit``/sharding without pulling the array onto one host,
and on ``NamedSharding`` inputs the result is re-placed on the same mesh.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.blocking import BlockGrid, ceil_div, round_up

Number = Union[int, float]


def _axis_mask(size: int, g: int, b: int) -> jnp.ndarray:
    """(g, b) bool mask: True where global index g*b_idx + offset < size."""
    gi = jax.lax.broadcasted_iota(jnp.int32, (g, b), 0)
    bi = jax.lax.broadcasted_iota(jnp.int32, (g, b), 1)
    return (gi * b + bi) < size


def _valid_mask(grid: BlockGrid, stacked_grid: Tuple[int, int]) -> jnp.ndarray:
    """Boolean mask over the stacked tensor marking logically-valid elements.

    ``stacked_grid`` may exceed ``grid.grid`` when the grid was padded to a
    mesh multiple; the extra all-pad blocks mask out naturally because their
    global indices exceed the logical shape.  Built from two small per-axis
    masks broadcast together (never four full-size iotas — the broadcast
    keeps the eager cost at ~one pass over the tensor).
    """
    n, m = grid.shape
    bn, bm = grid.block_shape
    gn, gm = stacked_grid
    rows = _axis_mask(n, gn, bn)                 # (gn, bn)
    cols = _axis_mask(m, gm, bm)                 # (gm, bm)
    return rows[:, None, :, None] & cols[None, :, None, :]


@jax.tree_util.register_pytree_node_class
class DsArray:
    """2-D blocked distributed array with a NumPy-like API (paper §4.2.3).

    Do not call the constructor with unpadded data; use :func:`from_array`,
    :func:`zeros`, :func:`random_array` etc.
    """

    __slots__ = ("blocks", "grid")

    def __init__(self, blocks: jnp.ndarray, grid: BlockGrid):
        if blocks.ndim != 4:
            raise ValueError(f"stacked block tensor must be rank 4, got {blocks.shape}")
        bn, bm = grid.block_shape
        if blocks.shape[2:] != (bn, bm):
            raise ValueError(
                f"block dims {blocks.shape[2:]} != block_shape {grid.block_shape}"
            )
        gn, gm = grid.grid
        if blocks.shape[0] < gn or blocks.shape[1] < gm:
            raise ValueError(
                f"stacked grid {blocks.shape[:2]} smaller than logical grid {grid.grid}"
            )
        self.blocks = blocks
        self.grid = grid

    # -- pytree protocol ------------------------------------------------------
    def tree_flatten(self):
        return (self.blocks,), self.grid

    @classmethod
    def tree_unflatten(cls, grid, children):
        (blocks,) = children
        return cls(blocks, grid)

    # -- basic properties -----------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return self.grid.shape

    @property
    def block_shape(self) -> Tuple[int, int]:
        return self.grid.block_shape

    @property
    def stacked_grid(self) -> Tuple[int, int]:
        return self.blocks.shape[:2]

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def ndim(self) -> int:
        return 2

    @property
    def T(self) -> "DsArray":
        return self.transpose()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"DsArray(shape={self.shape}, block_shape={self.block_shape}, "
            f"grid={self.stacked_grid}, dtype={self.dtype})"
        )

    # -- masking --------------------------------------------------------------
    def _mask(self) -> jnp.ndarray:
        return _valid_mask(self.grid, self.stacked_grid)

    def _remask(self, fill: Number = 0) -> jnp.ndarray:
        """Blocks with the pad region forced to ``fill``."""
        fill_v = jnp.asarray(fill, dtype=self.blocks.dtype)
        return jnp.where(self._mask(), self.blocks, fill_v)

    def _with_blocks(self, blocks: jnp.ndarray, grid: Optional[BlockGrid] = None) -> "DsArray":
        return DsArray(blocks, grid if grid is not None else self.grid)

    # -- materialization ------------------------------------------------------
    def collect(self) -> jnp.ndarray:
        """Paper §4.2.3 ``collect``: merge the blocks into one local array."""
        gn, gm, bn, bm = self.blocks.shape
        n, m = self.shape
        global_form = self.blocks.transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)
        return global_form[:n, :m]

    def _global_padded(self) -> jnp.ndarray:
        """Global layout including pad (pad guaranteed zero)."""
        gn, gm, bn, bm = self.blocks.shape
        return self.blocks.transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)

    # -- elementwise ----------------------------------------------------------
    def _binary(self, other, op: Callable, reverse: bool = False) -> "DsArray":
        me = self
        if isinstance(other, DsArray):
            if other.shape != self.shape or other.block_shape != self.block_shape:
                if other.shape != self.shape:
                    raise ValueError(
                        f"shape mismatch {self.shape} vs {other.shape}")
                other = other.rechunk(self.block_shape)
            if other.stacked_grid != self.stacked_grid:
                # pad whichever operand has the smaller stacked grid (either
                # may have been grown, e.g. by distribute()'s mesh padding)
                common = (max(me.stacked_grid[0], other.stacked_grid[0]),
                          max(me.stacked_grid[1], other.stacked_grid[1]))
                me = me._pad_grid_to(common)
                other = other._pad_grid_to(common)
            rhs = other.blocks
        elif isinstance(other, (int, float, jnp.ndarray, np.ndarray)) and jnp.ndim(other) == 0:
            rhs = other
        else:
            return NotImplemented
        out = op(rhs, me.blocks) if reverse else op(me.blocks, rhs)
        res = DsArray(out, BlockGrid(me.shape, me.block_shape))
        return res._with_blocks(res._remask())

    def __add__(self, o):
        return self._binary(o, jnp.add)

    __radd__ = __add__

    def __sub__(self, o):
        return self._binary(o, jnp.subtract)

    def __rsub__(self, o):
        return self._binary(o, jnp.subtract, reverse=True)

    def __mul__(self, o):
        return self._binary(o, jnp.multiply)

    __rmul__ = __mul__

    def __truediv__(self, o):
        return self._binary(o, jnp.divide)

    def __rtruediv__(self, o):
        return self._binary(o, jnp.divide, reverse=True)

    def __pow__(self, o):
        return self._binary(o, jnp.power)

    def __rpow__(self, o):
        return self._binary(o, jnp.power, reverse=True)

    def __neg__(self):
        return self.map_blocks(jnp.negative)

    def map_blocks(self, fn: Callable[[jnp.ndarray], jnp.ndarray]) -> "DsArray":
        """Apply an elementwise function to every block (one 'task' per block);
        re-masks to preserve the pad-is-zero invariant."""
        out = fn(self.blocks)
        if out.shape != self.blocks.shape:
            raise ValueError("map_blocks must preserve block shapes")
        res = DsArray(out, self.grid)
        return res._with_blocks(res._remask())

    def sqrt(self) -> "DsArray":
        return self.map_blocks(jnp.sqrt)

    def exp(self) -> "DsArray":
        return self.map_blocks(jnp.exp)

    def abs(self) -> "DsArray":
        return self.map_blocks(jnp.abs)

    def astype(self, dtype) -> "DsArray":
        return DsArray(self.blocks.astype(dtype), self.grid)

    # -- structural ops ---------------------------------------------------------
    def transpose(self) -> "DsArray":
        """Paper §5.2: local per-block transpose + block-grid permutation.

        One fused op over the stacked tensor; on a sharded array XLA lowers the
        grid-dim swap to a single all-to-all (vs. the Dataset baseline's
        N^2 + N scatter/gather — see core/dataset_baseline.py).
        """
        out = jnp.swapaxes(jnp.swapaxes(self.blocks, 0, 1), 2, 3)
        return DsArray(out, self.grid.transpose())

    def _pad_grid_to(self, stacked_grid: Tuple[int, int]) -> "DsArray":
        gn, gm = self.stacked_grid
        tn, tm = stacked_grid
        if (tn, tm) == (gn, gm):
            return self
        if tn < gn or tm < gm:
            raise ValueError("can only grow the stacked grid")
        out = jnp.pad(self.blocks, ((0, tn - gn), (0, tm - gm), (0, 0), (0, 0)))
        return DsArray(out, self.grid)

    def rechunk(self, block_shape: Tuple[int, int]) -> "DsArray":
        """Re-block to a new block size (the paper's 'arbitrary block size'
        flexibility; Datasets cannot do this at all).

        Block-native: evenly-dividing shapes regroup the stacked tensor in a
        single reshape; the general case is a windowed per-block gather.  No
        global ``(n, m)`` intermediate is formed either way (see
        ``core.structural.rechunk``).
        """
        from repro.core import structural
        return structural.rechunk(self, tuple(block_shape))

    def __matmul__(self, other: "DsArray") -> "DsArray":
        """Blocked matmul: C[i,j] = sum_k A[i,k] @ B[k,j].

        The einsum over (grid-k, block-k) is exactly the paper's per-block
        task graph; under pjit the grid contraction becomes a psum/SUMMA
        schedule chosen by SPMD partitioning (see core/shmap_ops.py for the
        explicitly-scheduled version used in §Perf).
        """
        if not isinstance(other, DsArray):
            return NotImplemented
        if self.shape[1] != other.shape[0]:
            raise ValueError(f"matmul shape mismatch {self.shape} @ {other.shape}")
        if self.block_shape[1] != other.block_shape[0]:
            other = other.rechunk((self.block_shape[1], other.block_shape[1]))
        if self.stacked_grid[1] != other.stacked_grid[0]:
            k = max(self.stacked_grid[1], other.stacked_grid[0])
            a = self._pad_grid_to((self.stacked_grid[0], k))
            b = other._pad_grid_to((k, other.stacked_grid[1]))
        else:
            a, b = self, other
        out = jnp.einsum("ikab,kjbc->ijac", a.blocks, b.blocks,
                         preferred_element_type=jnp.promote_types(a.dtype, jnp.float32)
                         if jnp.issubdtype(a.dtype, jnp.floating) else None)
        out = out.astype(jnp.promote_types(a.dtype, b.dtype))
        grid = BlockGrid((self.shape[0], other.shape[1]),
                         (self.block_shape[0], other.block_shape[1]))
        return DsArray(out, grid)

    # -- reductions ---------------------------------------------------------
    def _reduce(self, op: str, axis: Optional[int]) -> Union["DsArray", jnp.ndarray]:
        fill = {"sum": 0, "max": -jnp.inf, "min": jnp.inf}[op]
        if jnp.issubdtype(self.dtype, jnp.integer):
            fill = {"sum": 0,
                    "max": jnp.iinfo(self.dtype).min,
                    "min": jnp.iinfo(self.dtype).max}[op]
        x = self._remask(fill)
        red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
        if axis is None:
            return red(x)
        if axis == 0:
            # Paper Fig. 5: one task per *column* of blocks, then a psum over
            # the `data` mesh axis — possible only because ds-arrays block
            # both axes (Datasets must gather everything; Fig. 3).
            out = red(x, axis=(0, 2))  # (gm, bm)
            gm, bm = out.shape
            blocks = out.reshape(1, gm, 1, bm)
            grid = BlockGrid((1, self.shape[1]), (1, bm))
        elif axis == 1:
            out = red(x, axis=(1, 3))  # (gn, bn)
            gn, bn = out.shape
            blocks = out.reshape(gn, 1, bn, 1)
            grid = BlockGrid((self.shape[0], 1), (bn, 1))
        else:
            raise ValueError(f"axis must be 0, 1 or None, got {axis}")
        res = DsArray(blocks, grid)
        return res._with_blocks(res._remask())

    def sum(self, axis: Optional[int] = None):
        return self._reduce("sum", axis)

    def max(self, axis: Optional[int] = None):
        return self._reduce("max", axis)

    def min(self, axis: Optional[int] = None):
        return self._reduce("min", axis)

    def mean(self, axis: Optional[int] = None):
        n, m = self.shape
        denom = {None: n * m, 0: n, 1: m}[axis]
        me = self
        if not jnp.issubdtype(self.dtype, jnp.floating):
            # promote BEFORE summing: an int32/int8 accumulator overflows long
            # before the divide would have promoted the result
            me = self.astype(jnp.promote_types(self.dtype, jnp.float32))
        s = me.sum(axis)
        if isinstance(s, DsArray):
            return s / float(denom)
        return s / denom

    def norm(self, axis: Optional[int] = None):
        """Euclidean norm along an axis (paper's ``w.norm(axis=1)`` example)."""
        sq = self._binary(self, jnp.multiply)  # x*x keeps pad zero
        s = sq.sum(axis)
        if isinstance(s, DsArray):
            return s.sqrt()
        return jnp.sqrt(s)

    # -- indexing ------------------------------------------------------------
    def __getitem__(self, key) -> "DsArray":
        """NumPy-style indexing returning a new ds-array (paper §4.2.3).

        Supports ``A[r]``, ``A[r0:r1]``, ``A[r0:r1, c0:c1]``, integer rows/
        cols, and integer-array row selection (the paper's 'filtering').

        Block-aligned slices are a pure grid slice + edge remask; unaligned
        slices, strides and index arrays lower to one per-block gather per
        axis (``core.structural.getitem``) — the global array is never
        materialized and sharding survives.
        """
        from repro.core import structural
        return structural.getitem(self, key)

    # -- distribution ---------------------------------------------------------
    def distribute(self, mesh: Mesh, axes: Tuple[Optional[str], Optional[str]] = ("data", "model")) -> "DsArray":
        """Place blocks onto a device mesh: grid dims sharded over named axes.

        Pads the grid to mesh-axis multiples first (all-pad blocks mask out),
        the SPMD analogue of PyCOMPSs assigning whole blocks to workers.
        """
        dn = mesh.shape[axes[0]] if axes[0] else 1
        dm = mesh.shape[axes[1]] if axes[1] else 1
        gn, gm = self.stacked_grid
        padded = self._pad_grid_to((round_up(gn, dn), round_up(gm, dm)))
        sharding = NamedSharding(mesh, P(axes[0], axes[1], None, None))
        blocks = jax.device_put(padded.blocks, sharding)
        return DsArray(blocks, self.grid)

    def sharding_spec(self, axes=("data", "model")) -> P:
        return P(axes[0], axes[1], None, None)


# ---------------------------------------------------------------------------
# Creation routines (paper §4.2.2: "one task per block", here one fused op).
# ---------------------------------------------------------------------------


def from_array(arr, block_shape: Tuple[int, int]) -> DsArray:
    """Block a local 2-D array into a ds-array."""
    arr = jnp.asarray(arr)
    if arr.ndim == 1:
        arr = arr.reshape(-1, 1)
    if arr.ndim != 2:
        raise ValueError(f"ds-arrays are 2-D, got shape {arr.shape}")
    grid = BlockGrid(tuple(arr.shape), tuple(block_shape))
    (gn, gm), (bn, bm) = grid.grid, grid.block_shape
    pn, pm = grid.padded_shape
    padded = jnp.pad(arr, ((0, pn - arr.shape[0]), (0, pm - arr.shape[1])))
    blocks = padded.reshape(gn, bn, gm, bm).transpose(0, 2, 1, 3)
    return DsArray(blocks, grid)


def zeros(shape: Tuple[int, int], block_shape: Tuple[int, int], dtype=jnp.float32) -> DsArray:
    grid = BlockGrid(tuple(shape), tuple(block_shape))
    return DsArray(jnp.zeros(grid.stacked_shape, dtype), grid)


def full(shape, block_shape, fill_value, dtype=jnp.float32) -> DsArray:
    z = zeros(shape, block_shape, dtype)
    return z + fill_value


def eye(n: int, block_shape: Tuple[int, int], dtype=jnp.float32) -> DsArray:
    grid = BlockGrid((n, n), tuple(block_shape))
    gn, gm, bn, bm = grid.stacked_shape
    shape = (gn, gm, bn, bm)
    gi = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    gj = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    bi = jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    bj = jax.lax.broadcasted_iota(jnp.int32, shape, 3)
    row = gi * bn + bi
    col = gj * bm + bj
    blocks = ((row == col) & (row < n)).astype(dtype)
    return DsArray(blocks, grid)


def random_array(key, shape: Tuple[int, int], block_shape: Tuple[int, int],
                 dtype=jnp.float32, distribution: str = "uniform") -> DsArray:
    """Paper §4.2.2 ``random_array``: one independent RNG stream per block
    ("one task per block"), so the result is identical however the grid is
    later re-distributed."""
    grid = BlockGrid(tuple(shape), tuple(block_shape))
    gn, gm = grid.grid
    bn, bm = grid.block_shape
    keys = jax.random.split(key, gn * gm)
    keys = keys.reshape((gn, gm) + keys.shape[1:])  # raw uint32 keys keep a trailing dim
    sampler = {"uniform": jax.random.uniform, "normal": jax.random.normal}[distribution]
    blocks = jax.vmap(jax.vmap(lambda k: sampler(k, (bn, bm), dtype)))(keys)
    res = DsArray(blocks, grid)
    return res._with_blocks(res._remask())


def identity_like(a: DsArray) -> DsArray:
    if a.shape[0] != a.shape[1]:
        raise ValueError("identity_like needs a square array")
    return eye(a.shape[0], a.block_shape, a.dtype)


def concat_rows(arrays: Sequence[DsArray]) -> DsArray:
    """Vertical concatenation (the paper Dataset ``append`` generalized).

    Block-native: when part row counts align to the block size the grids are
    stacked directly (O(1) data movement); otherwise parts are re-tiled with
    per-block gathers.  See ``core.structural.concat_rows``.
    """
    from repro.core import structural
    return structural.concat_rows(arrays)
