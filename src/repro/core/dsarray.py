"""DsArray: the paper's distributed array, adapted to JAX/TPU.

A ds-array is a 2-D array divided into blocks of arbitrary size that live on
different workers and are operated on by per-block parallel tasks behind a
NumPy-like API (paper §4.2).  The TPU-native representation used here is a
single **stacked block tensor** of shape ``(gn, gm, bn, bm)`` — grid dims
first, block dims last — which is the direct SPMD analogue of the paper's
list-of-lists-of-blocks:

* grid cell (i, j)               <->  paper block (i, j)
* sharding grid dims over a mesh <->  PyCOMPSs placing blocks on workers
* vectorized op over grid dims   <->  one PyCOMPSs task per block
* XLA collective                 <->  inter-worker future transfer

Everything is a pure function of the stacked tensor, so a DsArray traces
through ``jax.jit`` and shards with ``NamedSharding(P(axis0, axis1))`` on the
grid dims.  Edge blocks are zero-padded, and each array carries a static
**pad state** — ``ZERO`` (pad exactly 0), ``FILL(v)`` (pad is the known
constant v) or ``DIRTY`` (unknown) — propagated at trace time by probing
each op on the pad constants.  Consumers that need the pad-is-zero invariant
(reductions, matmul, structural ops) enforce it lazily via
``ensure_zero_pad()``, so zero-preserving op chains emit **no** mask pass at
all and a chain ending in a consumer pays at most one.

Hot-path complexity (paper §5 claims, as implemented by ``core.structural``
and ``kernels.matmul``; N = n*m elements, "seed" = the path each row
replaced):

======================  ========================  ==========================
op                      seed path                 block-native path
======================  ========================  ==========================
aligned ``A[r0:r1,...]``  O(N) gather + repack      O(selected blocks) view
unaligned slice/stride  O(N) + gather             O(out) single block gather
row filter ``A[idx]``   O(N) + gather             O(out) single block gather
``rechunk`` (dividing)  O(N) two global layouts   O(N) one regroup reshape
``rechunk`` (general)   O(N) two global layouts   O(N) two block gathers
``concat_rows`` aligned O(sum N_i) x2             O(1) block-grid stack
``A @ B`` local GEMM    O(gk) einsum/kernel       1 fused Pallas launch,
                        launches + partial-C        grid-k x block-k in one
                        HBM round-trips             VMEM fp32 accumulator
elementwise chain (L)   L remask passes           0 remask passes (ZERO-
                                                    preserving) or 1 at the
                                                    consuming reduction
``_reduce`` refill      1 select pass always      0 when pad == identity
``apply_along_axis``    collect + host loop       1 nested-vmap call in
                                                    block layout
``exact_shuffle``       O(N) collect + take       1 per-block row gather
======================  ========================  ==========================

Block formats (paper §4.2: blocks are NumPy arrays OR scipy.sparse CSR):
``block_format`` names the storage — ``"dense"`` is the rank-4 stacked
tensor above; ``"bcoo"`` stores the same grid as one
``jax.experimental.sparse.BCOO`` with batch dims (gn, gm) and element-
sparse (bn, bm) blocks (see ``core.sparse``).  Sparse arrays are
ZERO-padded **by construction** (pad positions own no entry), so
``ensure_zero_pad`` is free.  Per-op storage behaviour:

======================  ======================================================
op on a bcoo array      behaviour
======================  ======================================================
``* / scalar``, ``-x``  sparse-native data map (index-preserving) when
``abs``, ``sqrt``         ``op(0) == 0``; result stays bcoo
``+ scalar``, ``exp``   densify (implicit zeros would change value)
``sp ± sp``, ``sp*sp``  sparse-native index merge (nse grows on ±;
                          ``sparse.canonicalize`` re-packs)
``sp * dense``          sparse-native index-gather of the dense operand
``sp / dense``          sparse-native (sparse side is the numerator)
``dense / sp``          densify (division by implicit zero)
``astype``              sparse-native data cast
``transpose``           sparse-native batch+index swap — O(nnz), no relayout
``sp @ dense``          ONE ``bcoo_dot_general`` over (grid-k, block-k); the
``spᵀ @ dense``           sparse operand is never densified (jaxpr-asserted);
                          dense result
``x @ sp``, ``sp @ sp`` right operand densifies
``sum``                 sparse-native ``bcoo_reduce_sum`` (identity == the
                          implicit zeros); small result is dense
``max``/``min``/mean    max/min densify (implicit zeros compete); mean is
                          sum-based and stays sparse-native
aligned slice           sparse-native batch-dim slice of the stacked BCOO
                          (start on block boundary, unit step; a mid-block
                          stop zero-masks the tail entries' data) — no
                          ``bcoo_todense`` (``sparse.aligned_slice_sparse``)
other slice/rechunk/    densify, then the dense block-native path
concat/shuffle/apply
======================  ======================================================

Estimator layer (``repro.estimators`` + ``repro.algorithms``; the dislib
collection the ds-array exists to power — every class implements the
``BaseEstimator`` contract of fit/predict/score + get_params/set_params,
accepts dense AND bcoo inputs, and records its fit-loop body lazily so
iterations hit the structural plan caches):

======================  ======================================================
estimator               data-matrix path per fit/predict
======================  ======================================================
``CascadeSVM``          chunking = aligned row slices (batch-dim slices of
                          the stacked BCOO — x never densifies, asserted);
                          kernel block ``X @ SVᵀ`` = ONE recorded plan per
                          iteration (sparse-lhs ``bcoo_dot_general``),
                          cache-hit from iteration 2 (``opt_runs == 1``)
``LinearRegression``/   normal equations ``XᵀX``/``Xᵀy`` in one recorded
``Ridge``                 multi-root plan (transpose folded; sparse-lhs for
                          bcoo); TSQR fallback on ill-conditioned inputs
``RandomForest-``       quantize blocks once (dense path; bcoo densifies by
``Classifier``            policy), one histogram einsum per level; predict =
                          one ``apply_along_axis`` vote pass
``KMeans``              ‖x‖² hoisted through one lazy plan; Lloyd
                          contractions sparse-native (``bcoo_dot_general``)
``PCA``                 power iteration records ``xᵀ(x·q)`` (sparse-native
                          with ``center=False``); ``pca()`` is a thin alias
``ALS``                 ``R@V`` / ``Rᵀ@U`` ds-array matmuls (sp @ dense)
======================  ======================================================

Lazy plans record the same classification (``core.expr``): sparse Blockwise
nodes carry BCOO-consuming fns and are **fusion boundaries** — the
optimizer never composes them with dense elementwise chains (``core.plan``)
— but they still CSE and their compiled plans cache by structure + nse.

``check_invariants()`` validates the claims above on concrete arrays (pad
region matches ``pad_state``, grid/shape consistency, BCOO indices
in-bounds-or-zero); exported for tests and run at every construction under
``REPRO_DEBUG=1`` (``pytest --repro-debug`` arms it for a whole test run).
Violations name the offending block: ``block (gi, gj) at offset (bi, bj)``
for dense pads, ``block (gi, gj) slot k`` for BCOO entries.

Numerical guards and resilience (``repro.resilience``) ride the same
block-granular conventions:

======================  ======================================================
entry point             what it does
======================  ======================================================
``finite_report()``     per-block NaN/Inf diagnosis (pad-state aware: FILL/
                          DIRTY pads never false-positive); offending blocks
                          named ``block (gi, gj)`` in ``check_invariants``
                          style — also ``resilience.guards.finite_report``
``guard_finite(...)``   cheap whole-value post-condition: ONE fused
                          reduction per value, raising
                          ``NumericalDivergence`` with the block report
``run_resilient(...)``  guarded plan execution: transient errors retry with
                          backoff, OOM degrades fused → per-node eager →
                          einsum GEMM backend, deterministic errors raise;
                          ``resilience.stats()`` counts recoveries
``inject(FaultSpec)``   deterministic fault injection (chaos harness) at
                          ``plan_execute`` / ``gemm_dispatch`` /
                          ``fit_iteration`` / ``io_load`` /
                          ``serve_dispatch`` sites
======================  ======================================================

Predict serving (``repro.serve``) turns fitted estimators into a
low-latency request loop over the same plan machinery:

======================  ======================================================
entry point             what it does
======================  ======================================================
``ModelRegistry``       named + versioned fitted models — ``register`` an
``.register/.load``       in-process estimator or ``load`` a ``save_model``
                          checkpoint (versions = checkpoint steps); params
                          pinned on device, per-bucket predict plans
                          AOT-compiled at load (``Plan.compile_aot``)
``PredictServer``       micro-batches requests into declared geometry
``.submit/.pump``         buckets (tail rows PAD_ZERO, results sliced back
                          per request; bcoo stays sparse at fixed nse);
                          every plan launch rides ``run_resilient``, and
                          dispatch faults shed batching -> unbatched
                          predict (request-level isolation)
``serve.stats()``       request/latency/queue counters + the plan-cache
                          discipline: steady state serves with ZERO XLA
                          recompiles (``cache_hits == requests``)
======================  ======================================================

Ingestion (``repro.core.io`` + ``core.readers``; paper §4.2.2 — arrays are
built one block-row at a time, so no process ever holds the full matrix):

==========================  ==================================================
entry point                 what it does
==========================  ==================================================
``load_txt_file``           streaming delimited-text loader: line-aligned
                              byte-range chunks (dask ``read_block`` idiom)
                              fill one block-row buffer; peak host memory
                              O(block-row), bitwise-equal to ``from_array``
                              of the full parse
``load_svmlight_file``      streaming svmlight -> ``(x, y)``; per-block-row
                              COO triplets pack into ONE stacked BCOO at
                              shared nse (``sparse.StackedBCOOBuilder``) —
                              larger-than-dense-RAM sparse data never
                              densifies
``load_npy_rows``           memory-mapped ``.npy`` row range streamed block
                              row by block row; untouched pages never fault
                              in (density scan only under ``"auto"``)
``load_npz_sparse``         scipy ``.npz`` -> BCOO ds-array (``from_scipy``)
``save_blocks`` /           one file per block row, dense or sparse
``load_blocks``               (data+indices+nse round-trip) — the spill /
                              checkpoint format
``save_npy``                dense global array; raises on bcoo (explicit
                              ``todense()`` instead of a silent densify)
==========================  ==================================================

Observability (``repro.obs``): one telemetry surface over every layer
above — tracing is OFF by default and allocation-free while off, so the
hot paths are byte-identical to the uninstrumented code:

==========================  ==================================================
entry point                 what it does
==========================  ==================================================
``obs.trace_to(path)``      arm tracing for a ``with`` block and export the
                              captured spans as Chrome trace-event JSON
                              (``chrome://tracing`` / Perfetto-loadable);
                              ``obs.summary()`` renders the same spans as an
                              aggregated terminal tree
``obs.span/@obs.traced``    the span primitives the instrumented sites use;
                              spans fence with ``block_until_ready`` so they
                              time device work, not dispatch.  Span names:
                              ``plan.optimize`` / ``plan.aot_compile`` /
                              ``plan.launch``; ``fit.loop`` /
                              ``fit.iteration``; ``resilience.rung`` (one per
                              attempt, failures tagged ``error=``);
                              ``serve.submit`` / ``serve.batch`` /
                              ``serve.dispatch`` / ``serve.slice``;
                              ``ingest.load`` / ``ingest.chunk``
``obs.registry``            the process-wide Counter/Gauge/Histogram
                              registry; ``plan.cache_stats()``,
                              ``resilience.stats()`` and ``serve.stats()``
                              are views over it (metric names ``plan.*``,
                              ``resilience.*``, ``serve.*``, ``gemm.*`` —
                              all increments locked, safe from server
                              worker threads)
``obs.snapshot()``          flat ``{metric: value}`` across the registry
``obs.reset_all()``           (benchmarks embed a slice of it per record);
                              reset zeroes every metric + the trace buffer
``obs.profile(plan)``       per-node measured wall time + actual output
                              bytes vs the ``costmodel`` byte laws; nodes
                              beyond ``COSTMODEL_DRIFT_FACTOR`` feed the
                              ``costmodel-drift`` analysis rule
==========================  ==================================================

Each claim in the tables above is machine-checked by ``repro.analysis``
(``analysis.check(plan_or_dsarray)``, CLI ``python -m repro.analysis``).
Rule ids per op row:

======================  ======================================================
op family               analyzer rules that police it
======================  ======================================================
sparse op rows          ``no-densify`` — a bcoo operand reaching a dense
(``sp @ dense``, maps,    kernel without a recorded ``Densify``/documented
sums, slices)             sink is an error on both the plan and the jaxpr
elementwise chains      ``remask-budget`` — select/mask passes in the trace
(L ops, ≤1 remask)        vs ``costmodel.chain_remask_passes``;
                          ``no-full-grid-intermediate`` — the fused chain
                          must compile to one body, no full-grid HBM def
pad-state rows          ``pad-soundness`` — a recorded Blockwise may not
(ZERO/FILL/DIRTY)         claim a stronger pad than its fn probe derives
scalar ops / map_blocks ``recompile-hazard`` — baked scalars with weak-type
                          drift, raw lambdas in plan keys, captured arrays
any multi-node plan     ``peak-hbm-liveness`` — naive emission order vs the
                          liveness-minimizing topological order (bytes from
                          ``costmodel.node_live_bytes``); warns at ≥2x
======================  ======================================================

Remask-elision rules: a binary/unary op on known pad states yields the op of
the pad constants (probed on 0-d values at trace time) — nan or a traced
operand demotes to DIRTY; ``_reduce`` refills only when the pad state
differs from the reduction identity; ``__matmul__`` and every structural op
call ``ensure_zero_pad()`` (a no-op on ZERO) before touching raw blocks.

Lazy plans (``core.expr`` / ``core.plan``): inside ``repro.lazy():`` — or
from ``a.lazy()`` — every op above records an ``Expr`` node instead of
dispatching, and ``compute()`` optimizes the whole DAG before lowering it
back onto these eager primitives in one ``jax.jit``.  Fusion rules:

* a run of elementwise/``map_blocks`` nodes whose intermediates have a
  single consumer composes into ONE per-block function — an L-op chain is
  one launch, one HBM read + one write (eager: L dispatches, 2·L passes);
* pad states propagate symbolically across the plan (the composed function
  is re-probed on the leaf pad constants), so a chain pays at most one
  remask at its consumer — zero when it stays ZERO-preserving;
* ``T(T(x)) → x``; elementwise over all-transposed operands hoists the
  transpose; ``(A.T) @ B`` folds into the fused Pallas GEMM with the
  transpose absorbed by block-index maps (``matmul_ta`` — the transposed
  stacked tensor never materializes);
* hash-consing shares identical subexpressions, so sibling reductions over
  the same operand evaluate it once; compiled plans are cached by
  structural hash (node kinds + static params + leaf geometry/dtype/pad,
  never data), so hot-loop bodies compile once and replay.

None of the block-native paths form a rank-2 global ``(n, m)`` tensor, so
they compose with ``jit``/sharding without pulling the array onto one host,
and on ``NamedSharding`` inputs the result is re-placed on the same mesh.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import sys
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.sparse import BCOO
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.blocking import BlockGrid, ceil_div, round_up

Number = Union[int, float]


def _debug_validate() -> bool:
    """True when REPRO_DEBUG=1: every DsArray construction self-checks."""
    import os
    return os.environ.get("REPRO_DEBUG") == "1"


def _lazy_mode() -> bool:
    """True when ``repro.lazy()`` recording is armed (see ``core.expr``).

    Checked at the top of every recordable op; resolved through
    ``sys.modules`` so arrays never pay an import until the lazy layer has
    actually been loaded (it cannot be active before it is imported).
    """
    expr = sys.modules.get("repro.core.expr")
    return expr is not None and expr.lazy_active()


def _axis_mask(size: int, g: int, b: int) -> jnp.ndarray:
    """(g, b) bool mask: True where global index g*b_idx + offset < size."""
    gi = jax.lax.broadcasted_iota(jnp.int32, (g, b), 0)
    bi = jax.lax.broadcasted_iota(jnp.int32, (g, b), 1)
    return (gi * b + bi) < size


def _valid_mask(grid: BlockGrid, stacked_grid: Tuple[int, int]) -> jnp.ndarray:
    """Boolean mask over the stacked tensor marking logically-valid elements.

    ``stacked_grid`` may exceed ``grid.grid`` when the grid was padded to a
    mesh multiple; the extra all-pad blocks mask out naturally because their
    global indices exceed the logical shape.  Built from two small per-axis
    masks broadcast together (never four full-size iotas — the broadcast
    keeps the eager cost at ~one pass over the tensor).
    """
    n, m = grid.shape
    bn, bm = grid.block_shape
    gn, gm = stacked_grid
    rows = _axis_mask(n, gn, bn)                 # (gn, bn)
    cols = _axis_mask(m, gm, bm)                 # (gm, bm)
    return rows[:, None, :, None] & cols[None, :, None, :]


# ---------------------------------------------------------------------------
# Pad-state tracking.
#
# The pad region of a stacked tensor is data the logical array does not own;
# instead of forcing it to zero after EVERY op (one full select pass per op,
# the seed behaviour), each DsArray carries a static claim about it.  The
# claim is aux data on the pytree, so it is known at trace time and the
# remask simply does not appear in the jaxpr when it is not needed.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PadState:
    """Static claim about the pad region: "zero" | "fill" (constant
    ``fill``) | "dirty" (unknown).  Hashable aux data, so differing states
    trace/compile separately — which is the point: the mask pass exists only
    in the traces that need it."""

    kind: str
    fill: Optional[Any] = None

    @property
    def value(self):
        """The pad constant (only meaningful for zero/fill)."""
        return 0 if self.kind == "zero" else self.fill


PAD_ZERO = PadState("zero")
PAD_DIRTY = PadState("dirty")


def pad_state_of(val) -> PadState:
    """PadState for a known constant pad value; nan demotes to DIRTY (it
    compares unequal to every reduction identity and would poison pytree
    equality)."""
    try:
        item = np.asarray(val).item()
    except Exception:
        return PAD_DIRTY
    if item != item:  # nan (works for complex too)
        return PAD_DIRTY
    if item == 0:
        return PAD_ZERO
    return PadState("fill", item)


def _probe_scalar(val, dtype):
    """A 0-d concrete array holding ``val`` in ``dtype`` for pad probing."""
    return jnp.asarray(np.asarray(val).item(), dtype=dtype)


def _probe_binary_pad(op, lhs_state: PadState, lhs_dtype, rhs,
                      reverse: bool = False) -> PadState:
    """Pad state of ``op(lhs, rhs)`` from the operands' pad constants.

    ``rhs`` is a PadState+dtype pair (DsArray operand) or a raw scalar.  The
    probe runs on concrete 0-d values, so it stays concrete even while
    tracing — unless an operand IS a tracer, which demotes to DIRTY.
    """
    if lhs_state.kind == "dirty":
        return PAD_DIRTY
    try:
        lv = _probe_scalar(lhs_state.value, lhs_dtype)
        if isinstance(rhs, tuple):
            rstate, rdtype = rhs
            if rstate.kind == "dirty":
                return PAD_DIRTY
            rv = _probe_scalar(rstate.value, rdtype)
        else:
            if isinstance(rhs, jax.core.Tracer):
                return PAD_DIRTY
            rv = rhs
        out = op(rv, lv) if reverse else op(lv, rv)
        if isinstance(out, jax.core.Tracer):
            return PAD_DIRTY
        return pad_state_of(out)
    except Exception:
        return PAD_DIRTY


def _probe_map_pad(fn, state: PadState, dtype) -> PadState:
    """Pad state of ``fn(blocks)`` for an elementwise ``fn``: probe it on a
    (1,1,1,1) constant holding the pad value.  Anything that fails, returns
    a tracer, or changes shape demotes to DIRTY (``map_blocks`` callers with
    non-elementwise fns should pass ``pad=PAD_DIRTY`` explicitly)."""
    if state.kind == "dirty":
        return PAD_DIRTY
    try:
        probe = jnp.full((1, 1, 1, 1), np.asarray(state.value).item(), dtype)
        out = fn(probe)
        if isinstance(out, jax.core.Tracer) or \
                getattr(out, "shape", None) != (1, 1, 1, 1):
            return PAD_DIRTY
        return pad_state_of(out)
    except Exception:
        return PAD_DIRTY


@jax.tree_util.register_pytree_node_class
class DsArray:
    """2-D blocked distributed array with a NumPy-like API (paper §4.2.3).

    Do not call the constructor with unpadded data; use :func:`from_array`,
    :func:`zeros`, :func:`random_array` etc.
    """

    __slots__ = ("blocks", "grid", "pad_state")

    def __init__(self, blocks: jnp.ndarray, grid: BlockGrid,
                 pad_state: PadState = PAD_ZERO):
        if blocks.ndim != 4:
            raise ValueError(f"stacked block tensor must be rank 4, got {blocks.shape}")
        bn, bm = grid.block_shape
        if blocks.shape[2:] != (bn, bm):
            raise ValueError(
                f"block dims {blocks.shape[2:]} != block_shape {grid.block_shape}"
            )
        gn, gm = grid.grid
        if blocks.shape[0] < gn or blocks.shape[1] < gm:
            raise ValueError(
                f"stacked grid {blocks.shape[:2]} smaller than logical grid {grid.grid}"
            )
        if isinstance(blocks, BCOO) and pad_state.kind != "zero":
            # sparse blocks have NO entries in the pad region — the pad is
            # exactly zero by construction, any other claim is a bug
            raise ValueError(
                f"bcoo-blocked ds-arrays are zero-padded by construction, "
                f"got pad_state={pad_state}")
        self.blocks = blocks
        self.grid = grid
        self.pad_state = pad_state
        if _debug_validate():
            self.check_invariants()

    # -- pytree protocol ------------------------------------------------------
    def tree_flatten(self):
        return (self.blocks,), (self.grid, self.pad_state)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (blocks,) = children
        grid, pad_state = aux
        return cls(blocks, grid, pad_state)

    # -- basic properties -----------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return self.grid.shape

    @property
    def block_shape(self) -> Tuple[int, int]:
        return self.grid.block_shape

    @property
    def stacked_grid(self) -> Tuple[int, int]:
        return self.blocks.shape[:2]

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def block_format(self) -> str:
        """Storage of the stacked blocks: ``"dense"`` | ``"bcoo"``.

        Derived from the blocks' pytree type, so it is static under tracing
        (a BCOO stays a BCOO-of-tracers) and can never disagree with the
        data it describes.
        """
        return "bcoo" if isinstance(self.blocks, BCOO) else "dense"

    @property
    def is_sparse(self) -> bool:
        return self.block_format == "bcoo"

    @property
    def ndim(self) -> int:
        return 2

    @property
    def T(self) -> "DsArray":
        return self.transpose()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"DsArray(shape={self.shape}, block_shape={self.block_shape}, "
            f"grid={self.stacked_grid}, dtype={self.dtype})"
        )

    # -- masking --------------------------------------------------------------
    def _mask(self) -> jnp.ndarray:
        return _valid_mask(self.grid, self.stacked_grid)

    def _remask(self, fill: Number = 0) -> jnp.ndarray:
        """Blocks with the pad region forced to ``fill``."""
        if self.is_sparse:
            raise RuntimeError("sparse blocks are zero-padded by construction"
                               " — there is nothing to remask")
        fill_v = jnp.asarray(fill, dtype=self.blocks.dtype)
        return jnp.where(self._mask(), self.blocks, fill_v)

    def ensure_zero_pad(self) -> "DsArray":
        """Self if the pad is known zero, else a re-masked copy.

        The single enforcement point of the pad-is-zero invariant: consumers
        that read raw blocks (matmul, reductions with 0-identity, structural
        ops, kernels) call this, so op chains pay at most one mask pass at
        the consumer instead of one per op."""
        if self.pad_state.kind == "zero":
            return self
        return DsArray(self._remask(), self.grid, PAD_ZERO)

    def _with_blocks(self, blocks: jnp.ndarray, grid: Optional[BlockGrid] = None,
                     pad_state: PadState = PAD_ZERO) -> "DsArray":
        return DsArray(blocks, grid if grid is not None else self.grid, pad_state)

    # -- materialization ------------------------------------------------------
    def collect(self) -> jnp.ndarray:
        """Paper §4.2.3 ``collect``: merge the blocks into one local array."""
        if self.is_sparse:
            return self.todense().collect()
        gn, gm, bn, bm = self.blocks.shape
        n, m = self.shape
        global_form = self.blocks.transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)
        return global_form[:n, :m]

    def _global_padded(self) -> jnp.ndarray:
        """Global layout including pad (pad forced zero)."""
        me = self.ensure_zero_pad()
        if me.is_sparse:
            me = me.todense()
        gn, gm, bn, bm = me.blocks.shape
        return me.blocks.transpose(0, 2, 1, 3).reshape(gn * bn, gm * bm)

    # -- block-format conversions (paper: NumPy OR scipy.sparse blocks) ------
    def todense(self) -> "DsArray":
        """This array with dense stacked blocks (identity when dense)."""
        from repro.core import sparse as sparse_mod
        return sparse_mod.todense(self)

    def tosparse(self, nse: Optional[int] = None) -> "DsArray":
        """This array with BCOO blocks (identity when sparse).  See
        ``core.sparse`` for the representation and op policy."""
        from repro.core import sparse as sparse_mod
        return sparse_mod.tosparse(self, nse=nse)

    # -- debug validation ------------------------------------------------------
    def check_invariants(self) -> "DsArray":
        """Validate the static claims against the concrete data; raises on
        violation, returns self for chaining.  Checked: grid/shape/block
        geometry, the pad region actually matching ``pad_state``, and (for
        bcoo) indices in-bounds-or-zero-data plus the zero-pad construction
        invariant.  A no-op on traced/abstract blocks.  Runs at every
        construction under ``REPRO_DEBUG=1``; the differential harness calls
        it after every op.
        """
        gn, gm = self.grid.grid
        bn, bm = self.grid.block_shape
        n, m = self.shape
        if n > gn * bn or m > gm * bm:
            raise AssertionError(f"grid {self.grid} does not cover shape")
        leaf = self.blocks.data if self.is_sparse else self.blocks
        if isinstance(leaf, jax.core.Tracer) or not isinstance(leaf, jax.Array):
            return self          # abstract/traced: nothing concrete to check
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            sparse_mod.check_bcoo_invariants(self)
            return self
        sgn, sgm = self.stacked_grid
        g = np.asarray(self.blocks).transpose(0, 2, 1, 3)
        g = g.reshape(sgn * bn, sgm * bm)
        pad_mask = (np.arange(sgn * bn)[:, None] >= n) | \
                   (np.arange(sgm * bm)[None, :] >= m)
        if self.pad_state.kind == "zero":
            bad = pad_mask & (g != 0)
        elif self.pad_state.kind == "fill":
            want = np.asarray(self.pad_state.fill, self.blocks.dtype)
            bad = pad_mask & (g != want)
        else:
            return self
        if bad.any():
            r, c = (int(v) for v in np.argwhere(bad)[0])
            gi, bi = divmod(r, bn)
            gj, bj = divmod(c, bm)
            raise AssertionError(
                f"pad_state={self.pad_state} but pad region differs: "
                f"{int(bad.sum())} violation(s), first in block "
                f"({gi}, {gj}) at offset ({bi}, {bj}) "
                f"(global ({r}, {c}), value {g[r, c]!r})")
        return self

    def finite_report(self):
        """Block-granular NaN/Inf diagnosis (``resilience.guards``): which
        blocks hold non-finite values, with counts and the first offending
        in-block offset (dense) or entry slot (bcoo).  Pad-state aware — a
        DIRTY or FILL pad region never false-positives.  Returns a
        ``FiniteReport`` (``.ok`` / ``.describe()``); blocks are named
        ``block (gi, gj)`` in the ``check_invariants`` style."""
        from repro.resilience import guards
        return guards.finite_report(self)

    # -- laziness -------------------------------------------------------------
    def lazy(self) -> "LazyDsArray":
        """This array lifted into the lazy expression layer: subsequent ops
        record an ``Expr`` plan that ``compute()`` optimizes (elementwise
        fusion, transpose-folded GEMM, plan-wide pad propagation) before
        running.  See ``core.expr`` / ``core.plan``."""
        from repro.core import expr
        return expr.lift_lazy(self)

    # -- elementwise ----------------------------------------------------------
    def _binary(self, other, op: Callable, reverse: bool = False) -> "DsArray":
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self)._binary(other, op, reverse)
        if self.is_sparse or (isinstance(other, DsArray) and other.is_sparse):
            from repro.core import sparse as sparse_mod
            return sparse_mod.binary(self, other, op, reverse)
        me = self
        if isinstance(other, DsArray):
            if other.shape != self.shape or other.block_shape != self.block_shape:
                if other.shape != self.shape:
                    raise ValueError(
                        f"shape mismatch {self.shape} vs {other.shape}")
                other = other.rechunk(self.block_shape)
            if other.stacked_grid != self.stacked_grid:
                # pad whichever operand has the smaller stacked grid (either
                # may have been grown, e.g. by distribute()'s mesh padding)
                common = (max(me.stacked_grid[0], other.stacked_grid[0]),
                          max(me.stacked_grid[1], other.stacked_grid[1]))
                me = me._pad_grid_to(common)
                other = other._pad_grid_to(common)
            rhs = other.blocks
            probe_rhs = (other.pad_state, other.blocks.dtype)
        elif isinstance(other, (int, float, jnp.ndarray, np.ndarray)) and jnp.ndim(other) == 0:
            rhs = other
            probe_rhs = other
        else:
            return NotImplemented
        out = op(rhs, me.blocks) if reverse else op(me.blocks, rhs)
        # both pad regions hold known constants at the SAME positions, so the
        # result pad is the op of the constants — no remask, just bookkeeping
        pad = _probe_binary_pad(op, me.pad_state, me.blocks.dtype, probe_rhs,
                                reverse)
        return DsArray(out, BlockGrid(me.shape, me.block_shape), pad)

    def __add__(self, o):
        return self._binary(o, jnp.add)

    __radd__ = __add__

    def __sub__(self, o):
        return self._binary(o, jnp.subtract)

    def __rsub__(self, o):
        return self._binary(o, jnp.subtract, reverse=True)

    def __mul__(self, o):
        return self._binary(o, jnp.multiply)

    __rmul__ = __mul__

    def __truediv__(self, o):
        return self._binary(o, jnp.divide)

    def __rtruediv__(self, o):
        return self._binary(o, jnp.divide, reverse=True)

    def __pow__(self, o):
        return self._binary(o, jnp.power)

    def __rpow__(self, o):
        return self._binary(o, jnp.power, reverse=True)

    def __neg__(self):
        return self.map_blocks(jnp.negative)

    def map_blocks(self, fn: Callable[[jnp.ndarray], jnp.ndarray],
                   pad: Optional[PadState] = None) -> "DsArray":
        """Apply an elementwise function to every block (one 'task' per block).

        The pad state is propagated by probing ``fn`` on the pad constant —
        zero-preserving fns (neg, sqrt, abs, ...) keep ZERO with no mask pass.
        Non-elementwise fns must pass ``pad=`` explicitly (``PAD_DIRTY`` when
        unknown); the probe cannot see position dependence."""
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self).map_blocks(fn, pad=pad)
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            return sparse_mod.map_blocks_sparse(self, fn, pad)
        out = fn(self.blocks)
        if out.shape != self.blocks.shape:
            raise ValueError("map_blocks must preserve block shapes")
        if pad is None:
            pad = _probe_map_pad(fn, self.pad_state, self.blocks.dtype)
        return DsArray(out, self.grid, pad)

    def sqrt(self) -> "DsArray":
        return self.map_blocks(jnp.sqrt)

    def exp(self) -> "DsArray":
        return self.map_blocks(jnp.exp)

    def abs(self) -> "DsArray":
        return self.map_blocks(jnp.abs)

    def astype(self, dtype) -> "DsArray":
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self).astype(dtype)
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            return sparse_mod.astype_sparse(self, dtype)
        pad = self.pad_state
        if pad.kind == "fill":
            # the physical pad is cast too; re-derive the constant the same
            # way — in NumPy, NOT jnp: under the lazy layer this method runs
            # inside eval_shape, where a jnp op on the constant would be
            # staged into a tracer and wrongly demote the claim to DIRTY
            try:
                pad = pad_state_of(
                    np.asarray(pad.fill, dtype=np.dtype(self.dtype))
                    .astype(np.dtype(dtype)))
            except Exception:
                pad = PAD_DIRTY
        return DsArray(self.blocks.astype(dtype), self.grid, pad)

    # -- structural ops ---------------------------------------------------------
    def transpose(self) -> "DsArray":
        """Paper §5.2: local per-block transpose + block-grid permutation.

        One fused op over the stacked tensor; on a sharded array XLA lowers the
        grid-dim swap to a single all-to-all (vs. the Dataset baseline's
        N^2 + N scatter/gather — see core/dataset_baseline.py).
        """
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self).transpose()
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            return sparse_mod.transpose_sparse(self)
        out = jnp.swapaxes(jnp.swapaxes(self.blocks, 0, 1), 2, 3)
        return DsArray(out, self.grid.transpose(), self.pad_state)

    def _pad_grid_to(self, stacked_grid: Tuple[int, int]) -> "DsArray":
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            return sparse_mod.pad_grid_sparse(self, stacked_grid)
        gn, gm = self.stacked_grid
        tn, tm = stacked_grid
        if (tn, tm) == (gn, gm):
            return self
        if tn < gn or tm < gm:
            raise ValueError("can only grow the stacked grid")
        # grow with the array's own pad constant so the pad state survives
        cv = 0 if self.pad_state.kind != "fill" else self.pad_state.fill
        out = jnp.pad(self.blocks, ((0, tn - gn), (0, tm - gm), (0, 0), (0, 0)),
                      constant_values=np.asarray(cv, self.blocks.dtype))
        return DsArray(out, self.grid, self.pad_state)

    def rechunk(self, block_shape: Tuple[int, int]) -> "DsArray":
        """Re-block to a new block size (the paper's 'arbitrary block size'
        flexibility; Datasets cannot do this at all).

        Block-native: evenly-dividing shapes regroup the stacked tensor in a
        single reshape; the general case is a windowed per-block gather.  No
        global ``(n, m)`` intermediate is formed either way (see
        ``core.structural.rechunk``).
        """
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self).rechunk(block_shape)
        from repro.core import structural
        return structural.rechunk(self, tuple(block_shape))

    def __matmul__(self, other: "DsArray") -> "DsArray":
        """Blocked matmul: C[i,j] = sum_k A[i,k] @ B[k,j].

        The local contraction over (grid-k, block-k) — exactly the paper's
        per-block task graph — lowers through the fused Pallas MXU kernel
        (``kernels.matmul.stacked_matmul``: one launch, fp32 VMEM accumulator,
        one HBM write per C tile) on TPU, with a stacked-block einsum fallback
        off-TPU / for non-MXU shapes; under pjit the grid contraction becomes
        a psum/SUMMA schedule chosen by SPMD partitioning (see
        core/shmap_ops.py for the explicitly-scheduled version used in §Perf).
        Zero pad on both operands makes the padded contraction exact; the
        result pad is therefore exactly zero.
        """
        from repro.kernels.matmul.ops import local_matmul
        if _lazy_mode():
            from repro.core import expr
            if isinstance(other, (DsArray, expr.LazyDsArray)):
                return expr.lift_lazy(self) @ other
        if not isinstance(other, DsArray):
            return NotImplemented
        if other.is_sparse:
            # sparse is supported on the LEFT (sp @ dense through
            # bcoo_dot_general); a sparse right operand densifies
            other = other.todense()
        if self.shape[1] != other.shape[0]:
            raise ValueError(f"matmul shape mismatch {self.shape} @ {other.shape}")
        if self.block_shape[1] != other.block_shape[0]:
            other = other.rechunk((self.block_shape[1], other.block_shape[1]))
        if self.stacked_grid[1] != other.stacked_grid[0]:
            k = max(self.stacked_grid[1], other.stacked_grid[0])
            a = self._pad_grid_to((self.stacked_grid[0], k))
            b = other._pad_grid_to((k, other.stacked_grid[1]))
        else:
            a, b = self, other
        a, b = a.ensure_zero_pad(), b.ensure_zero_pad()
        out = local_matmul(a.blocks, b.blocks,
                           out_dtype=jnp.promote_types(a.dtype, b.dtype))
        grid = BlockGrid((self.shape[0], other.shape[1]),
                         (self.block_shape[0], other.block_shape[1]))
        return DsArray(out, grid, PAD_ZERO)

    # -- reductions ---------------------------------------------------------
    def _reduce(self, op: str, axis: Optional[int]) -> Union["DsArray", jnp.ndarray]:
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self)._reduce(op, axis)
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            return sparse_mod.reduce_sparse(self, op, axis)
        fill = {"sum": 0, "max": -jnp.inf, "min": jnp.inf}[op]
        if jnp.issubdtype(self.dtype, jnp.integer):
            fill = {"sum": 0,
                    "max": int(jnp.iinfo(self.dtype).min),
                    "min": int(jnp.iinfo(self.dtype).max)}[op]
        # refill only when the pad is not already the reduction identity —
        # ZERO input + sum (the common case) emits no mask pass at all
        ps = self.pad_state
        if (ps.kind == "zero" and fill == 0) or \
                (ps.kind == "fill" and ps.fill == fill):
            x = self.blocks
        else:
            x = self._remask(fill)
        red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
        if axis is None:
            return red(x)
        if axis == 0:
            # Paper Fig. 5: one task per *column* of blocks, then a psum over
            # the `data` mesh axis — possible only because ds-arrays block
            # both axes (Datasets must gather everything; Fig. 3).
            out = red(x, axis=(0, 2))  # (gm, bm)
            gm, bm = out.shape
            blocks = out.reshape(1, gm, 1, bm)
            grid = BlockGrid((1, self.shape[1]), (1, bm))
        elif axis == 1:
            out = red(x, axis=(1, 3))  # (gn, bn)
            gn, bn = out.shape
            blocks = out.reshape(gn, 1, bn, 1)
            grid = BlockGrid((self.shape[0], 1), (bn, 1))
        else:
            raise ValueError(f"axis must be 0, 1 or None, got {axis}")
        # pad lines of the result reduce over identity-only values, so the
        # result pad IS the identity: bookkeep it instead of re-masking
        return DsArray(blocks, grid, pad_state_of(fill))

    def sum(self, axis: Optional[int] = None):
        return self._reduce("sum", axis)

    def max(self, axis: Optional[int] = None):
        return self._reduce("max", axis)

    def min(self, axis: Optional[int] = None):
        return self._reduce("min", axis)

    def mean(self, axis: Optional[int] = None):
        n, m = self.shape
        denom = {None: n * m, 0: n, 1: m}[axis]
        me = self
        if not jnp.issubdtype(self.dtype, jnp.floating):
            # promote BEFORE summing: an int32/int8 accumulator overflows long
            # before the divide would have promoted the result
            me = self.astype(jnp.promote_types(self.dtype, jnp.float32))
        s = me.sum(axis)
        if isinstance(s, DsArray):
            return s / float(denom)
        return s / denom

    def norm(self, axis: Optional[int] = None):
        """Euclidean norm along an axis (paper's ``w.norm(axis=1)`` example).

        Per-axis norms are expressed through :func:`apply_along_axis` (the
        paper's 1-D-slice API): one vmapped per-slice call in block layout,
        no ``collect()``.  The all-elements norm stays a fused square+sum.
        """
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self).norm(axis)
        if axis is None:
            sq = self._binary(self, jnp.multiply)  # x*x keeps pad zero
            return jnp.sqrt(sq.sum())
        return apply_along_axis(lambda v: jnp.sqrt(jnp.sum(v * v)), axis, self)

    # -- indexing ------------------------------------------------------------
    def __getitem__(self, key) -> "DsArray":
        """NumPy-style indexing returning a new ds-array (paper §4.2.3).

        Supports ``A[r]``, ``A[r0:r1]``, ``A[r0:r1, c0:c1]``, integer rows/
        cols, and integer-array row selection (the paper's 'filtering').

        Block-aligned slices are a pure grid slice + edge remask; unaligned
        slices, strides and index arrays lower to one per-block gather per
        axis (``core.structural.getitem``) — the global array is never
        materialized and sharding survives.
        """
        if _lazy_mode():
            from repro.core import expr
            return expr.lift_lazy(self)[key]
        from repro.core import structural
        return structural.getitem(self, key)

    # -- distribution ---------------------------------------------------------
    def distribute(self, mesh: Mesh, axes: Tuple[Optional[str], Optional[str]] = ("data", "model")) -> "DsArray":
        """Place blocks onto a device mesh: grid dims sharded over named axes.

        Pads the grid to mesh-axis multiples first (all-pad blocks mask out),
        the SPMD analogue of PyCOMPSs assigning whole blocks to workers.
        """
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            return sparse_mod.distribute_sparse(self, mesh, axes)
        dn = mesh.shape[axes[0]] if axes[0] else 1
        dm = mesh.shape[axes[1]] if axes[1] else 1
        gn, gm = self.stacked_grid
        padded = self._pad_grid_to((round_up(gn, dn), round_up(gm, dm)))
        sharding = NamedSharding(mesh, P(axes[0], axes[1], None, None))
        blocks = jax.device_put(padded.blocks, sharding)
        return DsArray(blocks, self.grid, padded.pad_state)

    def sharding_spec(self, axes=("data", "model")) -> P:
        return P(axes[0], axes[1], None, None)


# ---------------------------------------------------------------------------
# Derived block-native routines
# ---------------------------------------------------------------------------


def matmul_ta(a: DsArray, b: DsArray) -> DsArray:
    """``Aᵀ @ B`` with the transpose folded into the GEMM.

    The lazy optimizer rewrites ``MatMul(Transpose(a), b)`` to this: ``a``
    stays in its untransposed stacked layout and ``local_matmul`` absorbs
    the transpose into the contraction (block-index maps on the Pallas path,
    a relabeled einsum otherwise), so the transposed stacked tensor — a full
    HBM relayout under eager ``a.T @ b`` — is never materialized.  Also
    callable eagerly (the PCA Gram-vector products use it every iteration).
    """
    from repro.core import structural
    from repro.kernels.matmul.ops import local_matmul
    if not isinstance(b, DsArray):
        raise TypeError("matmul_ta wants DsArray operands")
    if b.is_sparse:
        b = b.todense()    # sparse is supported on the (transposed) left
    if a.shape[0] != b.shape[0]:
        raise ValueError(f"matmul_ta shape mismatch {a.shape}ᵀ @ {b.shape}")
    if a.block_shape[0] != b.block_shape[0]:
        b = structural.rechunk(b, (a.block_shape[0], b.block_shape[1]))
    if a.stacked_grid[0] != b.stacked_grid[0]:
        k = max(a.stacked_grid[0], b.stacked_grid[0])
        a = a._pad_grid_to((k, a.stacked_grid[1]))
        b = b._pad_grid_to((k, b.stacked_grid[1]))
    a, b = a.ensure_zero_pad(), b.ensure_zero_pad()
    out = local_matmul(a.blocks, b.blocks,
                       out_dtype=jnp.promote_types(a.dtype, b.dtype),
                       transpose_a=True)
    grid = BlockGrid((a.shape[1], b.shape[1]),
                     (a.block_shape[1], b.block_shape[1]))
    return DsArray(out, grid, PAD_ZERO)


def apply_along_axis(fn: Callable[[jnp.ndarray], jnp.ndarray], axis: int,
                     a: DsArray) -> DsArray:
    """Paper §4.2.3 ``apply_along_axis``: ``fn`` over every 1-D slice.

    ``axis=1`` applies ``fn`` to each row, ``axis=0`` to each column; ``fn``
    must map a 1-D vector to a scalar or a fixed-length 1-D vector.  Block-
    native: the stacked tensor is regrouped so each slice is contiguous in
    block layout (rank-3, grid dim leading — never the global ``(n, m)``
    rank-2 form) and ``fn`` runs as ONE nested-vmap call over all slices; no
    ``collect()``, and sharding is re-placed on the result.
    """
    from repro.core import structural
    if axis not in (0, 1):
        raise ValueError(f"axis must be 0 or 1, got {axis}")
    a2 = a.ensure_zero_pad()
    if a2.is_sparse:
        a2 = a2.todense()      # per-slice fns need the dense block layout
    gn, gm, bn, bm = a2.blocks.shape
    n, m = a2.shape
    if axis == 1:
        rows = a2.blocks.transpose(0, 2, 1, 3).reshape(gn, bn, gm * bm)[..., :m]
        out = jax.vmap(jax.vmap(fn))(rows)              # (gn, bn[, k])
        if out.ndim not in (2, 3):
            raise ValueError("fn must return a scalar or 1-D vector")
        if out.ndim == 2:
            out = out[..., None]
        k = out.shape[-1]
        blocks = out[:, None]                           # (gn, 1, bn, k)
        if gn * bn > n:     # fn of an all-pad row is garbage: mask it
            blocks = structural._mask_axes(blocks, n=n)
        res = DsArray(blocks, BlockGrid((n, k), (bn, k)), PAD_ZERO)
    else:
        cols = a2.blocks.transpose(1, 3, 0, 2).reshape(gm, bm, gn * bn)[..., :n]
        out = jax.vmap(jax.vmap(fn))(cols)              # (gm, bm[, k])
        if out.ndim not in (2, 3):
            raise ValueError("fn must return a scalar or 1-D vector")
        if out.ndim == 2:
            out = out[..., None]
        k = out.shape[-1]
        blocks = out.transpose(0, 2, 1)[None]           # (1, gm, k, bm)
        if gm * bm > m:
            blocks = structural._mask_axes(blocks, m=m)
        res = DsArray(blocks, BlockGrid((k, m), (k, bm)), PAD_ZERO)
    return structural.preserve_sharding(res, a.blocks)


# ---------------------------------------------------------------------------
# Creation routines (paper §4.2.2: "one task per block", here one fused op).
# ---------------------------------------------------------------------------


def from_array(arr, block_shape: Tuple[int, int]) -> DsArray:
    """Block a local 2-D array into a ds-array."""
    arr = jnp.asarray(arr)
    if arr.ndim == 1:
        arr = arr.reshape(-1, 1)
    if arr.ndim != 2:
        raise ValueError(f"ds-arrays are 2-D, got shape {arr.shape}")
    grid = BlockGrid(tuple(arr.shape), tuple(block_shape))
    (gn, gm), (bn, bm) = grid.grid, grid.block_shape
    pn, pm = grid.padded_shape
    padded = jnp.pad(arr, ((0, pn - arr.shape[0]), (0, pm - arr.shape[1])))
    blocks = padded.reshape(gn, bn, gm, bm).transpose(0, 2, 1, 3)
    return DsArray(blocks, grid)


def zeros(shape: Tuple[int, int], block_shape: Tuple[int, int], dtype=jnp.float32) -> DsArray:
    grid = BlockGrid(tuple(shape), tuple(block_shape))
    return DsArray(jnp.zeros(grid.stacked_shape, dtype), grid)


def full(shape, block_shape, fill_value, dtype=jnp.float32) -> DsArray:
    # built directly (not zeros+add) so creation stays eager under repro.lazy()
    grid = BlockGrid(tuple(shape), tuple(block_shape))
    blocks = jnp.full(grid.stacked_shape, fill_value, dtype)
    return DsArray(blocks, grid, pad_state_of(fill_value))


def eye(n: int, block_shape: Tuple[int, int], dtype=jnp.float32) -> DsArray:
    grid = BlockGrid((n, n), tuple(block_shape))
    gn, gm, bn, bm = grid.stacked_shape
    shape = (gn, gm, bn, bm)
    gi = jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    gj = jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    bi = jax.lax.broadcasted_iota(jnp.int32, shape, 2)
    bj = jax.lax.broadcasted_iota(jnp.int32, shape, 3)
    row = gi * bn + bi
    col = gj * bm + bj
    blocks = ((row == col) & (row < n)).astype(dtype)
    return DsArray(blocks, grid)


def random_array(key, shape: Tuple[int, int], block_shape: Tuple[int, int],
                 dtype=jnp.float32, distribution: str = "uniform") -> DsArray:
    """Paper §4.2.2 ``random_array``: one independent RNG stream per block
    ("one task per block"), so the result is identical however the grid is
    later re-distributed."""
    grid = BlockGrid(tuple(shape), tuple(block_shape))
    gn, gm = grid.grid
    bn, bm = grid.block_shape
    keys = jax.random.split(key, gn * gm)
    keys = keys.reshape((gn, gm) + keys.shape[1:])  # raw uint32 keys keep a trailing dim
    sampler = {"uniform": jax.random.uniform, "normal": jax.random.normal}[distribution]
    blocks = jax.vmap(jax.vmap(lambda k: sampler(k, (bn, bm), dtype)))(keys)
    res = DsArray(blocks, grid)
    return res._with_blocks(res._remask())


def identity_like(a: DsArray) -> DsArray:
    if a.shape[0] != a.shape[1]:
        raise ValueError("identity_like needs a square array")
    return eye(a.shape[0], a.block_shape, a.dtype)


def concat_rows(arrays: Sequence[DsArray]) -> DsArray:
    """Vertical concatenation (the paper Dataset ``append`` generalized).

    Block-native: when part row counts align to the block size the grids are
    stacked directly (O(1) data movement); otherwise parts are re-tiled with
    per-block gathers.  See ``core.structural.concat_rows``.
    """
    arrays = list(arrays)
    expr_m = sys.modules.get("repro.core.expr")
    if _lazy_mode() or (expr_m is not None and
                        any(isinstance(a, expr_m.LazyDsArray)
                            for a in arrays)):
        from repro.core import expr
        return expr.record_concat(arrays)
    from repro.core import structural
    return structural.concat_rows(arrays)
