"""Explicitly-scheduled collective implementations of ds-array ops.

``DsArray`` ops are written as pure per-block math and let SPMD partitioning
choose the collective schedule.  For the §Perf hillclimb we also provide
hand-scheduled ``shard_map`` versions with explicit collectives so the HLO
contains exactly the collective pattern we intend:

* ``summa_matmul``     — SUMMA (gather form): all-gather the A panel along the
  ``model`` axis and the B panel along the ``data`` axis, local GEMM.
  Communication per device: n*k/dn + k*m/dm elements (see
  ``core.costmodel.tpu_summa_bytes``).
* ``cannon_matmul``    — Cannon's algorithm: one-shot skew ppermute, then d-1
  neighbour ``ppermute`` steps of both operands, each overlapping the local
  GEMM.  Same total bytes as SUMMA but all steady-state traffic is
  nearest-neighbour over ICI — the beyond-paper schedule evaluated in §Perf.
* ``transpose_pp``     — local block transpose + ONE mirrored ``ppermute``
  across the square (data × model) mesh: the minimal-communication transpose
  (each shard moves exactly once).  The paper's N-task transpose maps to this.

All bodies take/return *mesh-local* stacked block tensors inside
``shard_map``; wrappers handle DsArray packing/padding.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.blocking import BlockGrid, round_up
from repro.core.compat import shard_map
from repro.core.dsarray import DsArray
from repro.core import structural


def _shmap(body, mesh, in_specs, out_specs):
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_vma=False)


def _local_gemm(a: jnp.ndarray, b: jnp.ndarray,
                gemm: Union[str, Callable, None] = None) -> jnp.ndarray:
    """Local blocked GEMM on stacked tiles: (gi,gk,bn,bk) x (gk,gj,bk,bm).

    Dispatches through ``kernels.matmul.local_matmul`` — on TPU the whole
    shard contracts in ONE fused Pallas launch with the (grid-k x block-k)
    reduction accumulating in a VMEM fp32 tile.  This replaces the old
    per-grid-k Python loop of vmapped 2-D kernels, which launched O(gk)
    kernels and round-tripped the full C partial through HBM at every step.
    ``gemm`` selects a backend ("pallas" / "interpret" / "einsum", None =
    auto) or is a callable taking the two stacked tensors.
    """
    from repro.kernels.matmul.ops import local_matmul
    if callable(gemm):
        return gemm(a, b)
    return local_matmul(a, b, out_dtype=a.dtype, backend=gemm)


def _prep_matmul(a: DsArray, b: DsArray, mesh: Mesh, axes):
    if a.shape[1] != b.shape[0] or a.block_shape[1] != b.block_shape[0]:
        raise ValueError("distributed matmul requires matching inner grid/block dims")
    # shard bodies read raw blocks; the padded contraction is exact only
    # with zero pads (enforced once here, not per schedule step)
    a = a.ensure_zero_pad().distribute(mesh, axes)
    b = b.ensure_zero_pad().distribute(mesh, axes)
    dn, dm = mesh.shape[axes[0]], mesh.shape[axes[1]]
    gk = round_up(max(a.stacked_grid[1], b.stacked_grid[0]), dn * dm)
    a = a._pad_grid_to((a.stacked_grid[0], gk))
    b = b._pad_grid_to((gk, b.stacked_grid[1]))
    return a, b


def summa_matmul(a: DsArray, b: DsArray, mesh: Mesh,
                 axes: Tuple[str, str] = ("data", "model"),
                 gemm: Union[str, Callable, None] = None) -> DsArray:
    """C = A @ B with an explicit SUMMA (gather-form) schedule."""
    a, b = _prep_matmul(a, b, mesh, axes)

    def body(ab, bb):
        a_full = jax.lax.all_gather(ab, axes[1], axis=1, tiled=True)  # (gi/dn, gk, ., .)
        b_full = jax.lax.all_gather(bb, axes[0], axis=0, tiled=True)  # (gk, gj/dm, ., .)
        return _local_gemm(a_full, b_full, gemm)

    spec = P(axes[0], axes[1], None, None)
    out_blocks = _shmap(body, mesh, (spec, spec), spec)(a.blocks, b.blocks)
    grid = BlockGrid((a.shape[0], b.shape[1]),
                     (a.block_shape[0], b.block_shape[1]))
    return DsArray(out_blocks, grid)


def cannon_matmul(a: DsArray, b: DsArray, mesh: Mesh,
                  axes: Tuple[str, str] = ("data", "model"),
                  gemm: Union[str, Callable, None] = None) -> DsArray:
    """Cannon's algorithm on a square (d × d) mesh slice.

    Steady state: per step, every device ppermutes its A panel one hop left
    and its B panel one hop up while computing the local GEMM — compute/comm
    overlap with only nearest-neighbour ICI traffic.
    """
    dn, dm = mesh.shape[axes[0]], mesh.shape[axes[1]]
    if dn != dm:
        raise ValueError("cannon_matmul requires a square mesh slice")
    d = dn
    a, b = _prep_matmul(a, b, mesh, axes)
    joint = (axes[0], axes[1])

    left = [(c, (c - 1) % d) for c in range(d)]   # along axes[1]
    up = [(r, (r - 1) % d) for r in range(d)]     # along axes[0]
    skew_a = [(r * d + c, r * d + ((c - r) % d)) for r in range(d) for c in range(d)]
    skew_b = [(r * d + c, ((r - c) % d) * d + c) for r in range(d) for c in range(d)]

    def body(ab, bb):
        ab = jax.lax.ppermute(ab, joint, skew_a)
        bb = jax.lax.ppermute(bb, joint, skew_b)
        acc = _local_gemm(ab, bb, gemm)
        for _ in range(d - 1):
            ab = jax.lax.ppermute(ab, axes[1], left)
            bb = jax.lax.ppermute(bb, axes[0], up)
            acc = acc + _local_gemm(ab, bb, gemm)
        return acc

    spec = P(axes[0], axes[1], None, None)
    out_blocks = _shmap(body, mesh, (spec, spec), spec)(a.blocks, b.blocks)
    grid = BlockGrid((a.shape[0], b.shape[1]),
                     (a.block_shape[0], b.block_shape[1]))
    return DsArray(out_blocks, grid)


def transpose_pp(a: DsArray, mesh: Mesh,
                 axes: Tuple[str, str] = ("data", "model")) -> DsArray:
    """Transpose = local block transpose + ONE mirrored ppermute (square mesh).

    Device (r, c) locally transposes its shard and sends it to device (c, r);
    every byte crosses the mesh exactly once — strictly cheaper than the
    all-to-all XLA emits for the einsum formulation (measured in §Perf).
    """
    dn, dm = mesh.shape[axes[0]], mesh.shape[axes[1]]
    if dn != dm:
        raise ValueError("transpose_pp requires a square mesh slice; use the "
                         "default DsArray.transpose() under pjit otherwise")
    d = dn
    a = a.distribute(mesh, axes)
    gn, gm = a.stacked_grid
    a = a._pad_grid_to((round_up(gn, d), round_up(gm, d)))
    mirror = [(r * d + c, c * d + r) for r in range(d) for c in range(d)]

    def body(x):  # (gn/d, gm/d, bn, bm) local
        xt = jnp.swapaxes(jnp.swapaxes(x, 0, 1), 2, 3)
        return jax.lax.ppermute(xt, (axes[0], axes[1]), mirror)

    spec = P(axes[0], axes[1], None, None)
    out_blocks = _shmap(body, mesh, (spec,), spec)(a.blocks)
    # pure permutation: the pad region maps onto the transposed pad region,
    # so the operand's pad state (and constant) carries over
    return DsArray(out_blocks, a.grid.transpose(), a.pad_state)


def colsum_psum(a: DsArray, mesh: Mesh,
                axes: Tuple[str, str] = ("data", "model")) -> DsArray:
    """Paper Fig. 5 column-of-blocks summation with an explicit psum over the
    `data` axis (one partial-sum 'task' per device, one reduction)."""
    a = a.distribute(mesh, axes)

    def body(x):  # (gn/dn, gm/dm, bn, bm)
        partial = x.sum(axis=(0, 2))          # (gm/dm, bm)
        total = jax.lax.psum(partial, axes[0])
        return total[None, :, None, :]        # (1, gm/dm, 1, bm)

    spec = P(axes[0], axes[1], None, None)
    out_spec = P(None, axes[1], None, None)
    out_blocks = _shmap(body, mesh, (spec,), out_spec)(a.ensure_zero_pad().blocks)
    grid = BlockGrid((1, a.shape[1]), (1, a.block_shape[1]))
    return DsArray(out_blocks, grid)


# ---------------------------------------------------------------------------
# Sharding-preserving structural ops.
#
# The block-native structural ops in ``core.structural`` are pure jnp, so
# under jit SPMD keeps blocks in place automatically, and eagerly they re-put
# the result on the operand's mesh.  The wrappers below are the explicit
# distributed entry points: they first place the operand on ``mesh`` (padding
# the grid to mesh multiples), run the block-native op, and guarantee the
# result carries a ``NamedSharding`` over the same axes — the SPMD analogue
# of the paper's "slicing returns a ds-array with the same worker placement".
# ---------------------------------------------------------------------------


def _redistribute(out: DsArray, mesh: Mesh, axes) -> DsArray:
    from jax.sharding import NamedSharding
    spec = P(axes[0], axes[1], None, None)
    dn = mesh.shape[axes[0]] if axes[0] else 1
    dm = mesh.shape[axes[1]] if axes[1] else 1
    gn, gm = out.stacked_grid
    padded = out._pad_grid_to((round_up(gn, dn), round_up(gm, dm)))
    blocks = jax.device_put(padded.blocks, NamedSharding(mesh, spec))
    return DsArray(blocks, out.grid, padded.pad_state)


def slice_sharded(a: DsArray, key, mesh: Mesh,
                  axes: Tuple[str, str] = ("data", "model")) -> DsArray:
    """``A[key]`` on a mesh: block-native selection, result resharded."""
    a = a.distribute(mesh, axes)
    return _redistribute(structural.getitem(a, key), mesh, axes)


def rechunk_sharded(a: DsArray, block_shape: Tuple[int, int], mesh: Mesh,
                    axes: Tuple[str, str] = ("data", "model")) -> DsArray:
    """Re-block on a mesh: grid-local regroup, result resharded."""
    a = a.distribute(mesh, axes)
    return _redistribute(structural.rechunk(a, block_shape), mesh, axes)


def concat_rows_sharded(arrays, mesh: Mesh,
                        axes: Tuple[str, str] = ("data", "model")) -> DsArray:
    """Vertical concat on a mesh: grid stack, result resharded."""
    arrays = [a.distribute(mesh, axes) for a in arrays]
    return _redistribute(structural.concat_rows(arrays), mesh, axes)
