"""Lazy expression IR for ds-array op chains: record now, optimize+fuse later.

The paper's ds-array is lazy by construction — every op is a PyCOMPSs task
returning futures, and the runtime sees the whole task graph before anything
runs.  The eager stacked-tensor port lost that: each ``DsArray`` op was its
own dispatch (its own XLA program when un-jitted), and the pad-state
machinery of PR 2 could only elide masks one op at a time.  This module
restores the graph view, in the style of dask's ``dask_expr``: ops are
**recorded** as ``Expr`` nodes, a :class:`LazyDsArray` facade mirrors the
``DsArray`` API over an expression, and ``compute()`` (in ``core.plan``)
optimizes the whole DAG — fusing elementwise runs into one per-block
function, folding transposes into GEMM index maps, sharing subexpressions —
before lowering it onto the existing eager block-native primitives inside a
single ``jax.jit``.

Opt-in is explicit, two ways::

    with repro.lazy():                # every DsArray op records
        y = ((a + b) * 2.0).abs().sum(axis=0)
    y = y.compute()

    y = a.lazy() + b                  # or lift one array into the lazy world
    y.compute()

Node inventory (each node knows how to ``lower`` itself onto the eager
primitives, and its output metadata — grid, dtype, pad state — is inferred
at record time by running that lowering under ``jax.eval_shape``, so the
static pad-state propagation of the eager layer carries over symbolically to
whole plans):

==============  ==========================================================
node            records
==============  ==========================================================
``Leaf``        a concrete DsArray (plan input)
``ArrayLeaf``   a raw array input (index vectors, PRNG keys)
``Blockwise``   elementwise / map_blocks over aligned operands (fusible)
``Transpose``   block transpose + grid swap
``PadGrid``     stacked-grid growth (operand alignment)
``AsType``      dtype cast
``MatMul``      blocked GEMM, optionally with A-transpose folded in
``Reduce``      sum/max/min over an axis (or all)
``GetItem``     slice / integer-array selection
``Rechunk``     re-blocking
``ConcatRows``  vertical concat
``Shuffle``     pseudo / exact row shuffle
==============  ==========================================================
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.sparse import BCOO

from repro.core.blocking import BlockGrid
from repro.core.dsarray import (DsArray, PAD_DIRTY, PAD_ZERO, PadState,
                                matmul_ta, pad_state_of)

Number = Union[int, float]

# ---------------------------------------------------------------------------
# Lazy-mode switch.
#
# ``lazy()`` arms recording: DsArray entry points (``_binary``, ``map_blocks``,
# ``transpose``, ``__matmul__``, ``_reduce``, ``__getitem__``, ``rechunk``,
# ``astype``, ``norm``) check ``lazy_active()`` and return LazyDsArray
# recordings instead of eager results.  ``suspend_lazy()`` masks the flag —
# used by metadata inference and plan execution, which trace the very same
# eager methods and must not re-enter the recorder.
# ---------------------------------------------------------------------------

_STATE = threading.local()


def _depth(name: str) -> int:
    return getattr(_STATE, name, 0)


def lazy_active() -> bool:
    return _depth("lazy") > 0 and _depth("suspend") == 0


@contextlib.contextmanager
def lazy():
    """Context manager arming lazy recording for DsArray ops (re-entrant)."""
    _STATE.lazy = _depth("lazy") + 1
    try:
        yield
    finally:
        _STATE.lazy = _depth("lazy") - 1


@contextlib.contextmanager
def suspend_lazy():
    """Mask ``lazy_active()`` while tracing/lowering eager primitives."""
    _STATE.suspend = _depth("suspend") + 1
    try:
        yield
    finally:
        _STATE.suspend = _depth("suspend") - 1


# ---------------------------------------------------------------------------
# Expression nodes
# ---------------------------------------------------------------------------


def _is_ds(meta) -> bool:
    return isinstance(meta, DsArray)


def _is_sparse(meta) -> bool:
    """True for a ds-shaped meta whose blocks are (abstract) BCOO — the
    ``block_format`` the lazy layer carries along every node."""
    return _is_ds(meta) and isinstance(meta.blocks, BCOO)


def _meta_sig(meta) -> tuple:
    """Hashable signature of a node's output metadata.  Sparse metas add
    the block format and nse: two plans whose arrays differ only in stored
    entry count must neither share memoized metadata nor a compiled plan."""
    if _is_ds(meta):
        fmt = ("bcoo", meta.blocks.nse) if _is_sparse(meta) else ("dense",)
        return ("ds", tuple(meta.blocks.shape), str(meta.blocks.dtype),
                meta.grid, meta.pad_state) + fmt
    return ("arr", tuple(meta.shape), str(meta.dtype))


# Metadata memo: a node's meta is a pure function of (class, static params,
# child metas), so inference runs ONCE per distinct structure instead of per
# recorded node — re-recording the same hot-loop body (pca power iteration,
# kmeans ‖x‖²) and the optimizer's rebuilds all hit this instead of paying
# a fresh jax.eval_shape (~ms) per node.  Bounded by wholesale clearing.
_META_MEMO: dict = {}
_META_MEMO_MAX = 4096


class Expr:
    """A node of the recorded DAG.

    ``children`` are input Exprs; ``meta`` is the output described as a
    DsArray whose ``blocks`` is a ShapeDtypeStruct (so grid/pad-state ride
    along as static aux data) or a bare ShapeDtypeStruct for scalar results.
    ``lower(*vals)`` maps child values to the output value using ONLY the
    eager block-native primitives — it is the single source of truth for
    both metadata inference (under ``eval_shape``) and plan execution
    (under ``jit``).
    """

    __slots__ = ("children", "meta")

    def lower(self, *vals):
        raise NotImplementedError

    def local_key(self):
        """Hashable structural identity of this node EXCLUDING children."""
        raise NotImplementedError

    def _meta_key_extra(self) -> tuple:
        """Extra memo-key state that affects ``lower`` but is not (always)
        part of ``local_key`` — e.g. a Blockwise's resolved pad."""
        return ()

    def _infer_meta(self) -> None:
        try:
            key = (type(self), self.local_key(), self._meta_key_extra(),
                   tuple(_meta_sig(c.meta) for c in self.children))
        except TypeError:           # unhashable param: infer uncached
            key = None
        if key is not None:
            hit = _META_MEMO.get(key)
            if hit is not None:
                self.meta = hit
                return
        with suspend_lazy():
            self.meta = jax.eval_shape(self.lower, *[c.meta for c in self.children])
        if key is not None:
            if len(_META_MEMO) >= _META_MEMO_MAX:
                _META_MEMO.clear()
            _META_MEMO[key] = self.meta

    # rebuild with new children (used by the optimizer); subclasses with
    # extra state override
    def rebuild(self, children: Sequence["Expr"]) -> "Expr":
        raise NotImplementedError

    @property
    def kind(self) -> str:
        """The node's class name — the stable kind id the analysis layer
        keys rules and finding sites on."""
        return type(self).__name__

    def describe(self) -> str:
        """Short human-stable label for findings: ``Kind`` or ``Kind[tag]``
        where the tag is the leading element of ``local_key()``."""
        try:
            lk = self.local_key()
        except NotImplementedError:
            return self.kind
        tag = lk[0] if isinstance(lk, tuple) and lk else lk
        return f"{self.kind}[{tag}]"


class Leaf(Expr):
    """A concrete DsArray: a plan input.  Identity (not data) keyed — two
    plans over different arrays with the same structural signature share one
    compiled program."""

    __slots__ = ("value",)

    def __init__(self, value: DsArray):
        self.value = value
        self.children = ()
        if isinstance(value.blocks, BCOO):
            # BCOO coerces constructor args, so build the abstract form via
            # an identity eval_shape (returns a BCOO of ShapeDtypeStructs)
            with suspend_lazy():
                abstract = jax.eval_shape(lambda blk: blk, value.blocks)
        else:
            abstract = jax.ShapeDtypeStruct(value.blocks.shape,
                                            value.blocks.dtype)
        self.meta = DsArray(abstract, value.grid, value.pad_state)

    def signature(self):
        g = self.value.grid
        fmt = ("bcoo", self.value.blocks.nse) if self.value.is_sparse \
            else ("dense",)
        return ("leaf", g.shape, g.block_shape, self.value.stacked_grid,
                str(self.value.dtype), self.value.pad_state) + fmt

    def local_key(self):
        return self.signature()

    def rebuild(self, children):
        return self


class ArrayLeaf(Expr):
    """A raw array plan input (index vectors, shuffle PRNG keys): its VALUES
    are runtime data, so re-running a structurally-identical plan with a new
    index array hits the compiled-plan cache."""

    __slots__ = ("value",)

    def __init__(self, value):
        self.value = jnp.asarray(value)
        self.children = ()
        self.meta = jax.ShapeDtypeStruct(self.value.shape, self.value.dtype)

    def signature(self):
        return ("aleaf", self.value.shape, str(self.value.dtype))

    def local_key(self):
        return self.signature()

    def rebuild(self, children):
        return self


class Blockwise(Expr):
    """Elementwise / map_blocks op over grid-aligned operands.

    ``fn(*blocks)`` consumes the children's stacked block tensors (plus any
    0-d scalar-expression values) and returns one stacked tensor of the same
    shape.  The optimizer fuses chains of these into ONE composed per-block
    function, and the output pad state is re-derived by probing the composed
    function on the leaf pad constants — pad-state propagation over the
    whole plan, paying at most one remask at the eventual consumer.

    With no ds-array children (all 0-d operands) the node is a scalar
    computation and ``lower`` returns the raw array.

    ``elementwise`` marks fns known to be position-independent (everything
    the facade records itself); user ``map_blocks`` fns are conservatively
    NOT, which gates the optimizer's transpose-hoist rule — a
    position-dependent fn does not commute with the block transpose.
    """

    __slots__ = ("fn", "key", "pad", "elementwise")

    def __init__(self, fn: Callable, children: Sequence[Expr], key,
                 pad: Optional[PadState] = None, elementwise: bool = False):
        self.fn = fn
        self.key = key
        self.elementwise = elementwise
        self.children = tuple(children)
        self.pad = self._probe_pad() if pad is None else pad
        self._infer_meta()

    def _probe_pad(self) -> PadState:
        metas = [c.meta for c in self.children]
        if not any(_is_ds(m) for m in metas):
            return PAD_DIRTY     # scalar node: pad meaningless
        if any(_is_sparse(m) for m in metas):
            # sparse-consuming fns are recorded only by the facade's
            # zero-preserving classification, and sparse results are
            # zero-padded by construction — the only legal claim
            return PAD_ZERO
        probes = []
        for m in metas:
            if not _is_ds(m):
                return PAD_DIRTY  # 0-d expr operand: value unknown statically
            if m.pad_state.kind == "dirty":
                return PAD_DIRTY
            try:
                probes.append(jnp.full((1, 1, 1, 1),
                                       np.asarray(m.pad_state.value).item(),
                                       m.blocks.dtype))
            except Exception:
                return PAD_DIRTY
        try:
            out = self.fn(*probes)
            if isinstance(out, jax.core.Tracer) or \
                    getattr(out, "shape", None) != (1, 1, 1, 1):
                return PAD_DIRTY
            return pad_state_of(out)
        except Exception:
            return PAD_DIRTY

    def lower(self, *vals):
        blocks = [v.blocks if isinstance(v, DsArray) else v for v in vals]
        out = self.fn(*blocks)
        ref = next((v for v in vals if isinstance(v, DsArray)), None)
        if ref is None:
            return out
        # a BCOO result is zero-padded by construction whatever the
        # resolved pad claim says (the claim is for the dense fns)
        pad = PAD_ZERO if isinstance(out, BCOO) else self.pad
        return DsArray(out, ref.grid, pad)

    def local_key(self):
        return ("bw", self.key)

    def _meta_key_extra(self):
        return (self.pad,)

    def rebuild(self, children):
        # keep the RESOLVED pad: an explicit pad (e.g. PAD_DIRTY on a
        # position-dependent map_blocks) must survive DAG rewrites — the
        # probe cannot re-derive it
        return Blockwise(self.fn, children, self.key, pad=self.pad,
                         elementwise=self.elementwise)


class Transpose(Expr):
    __slots__ = ()

    def __init__(self, child: Expr):
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        return v.transpose()

    def local_key(self):
        return ("T",)

    def rebuild(self, children):
        return Transpose(children[0])


class PadGrid(Expr):
    """Grow the stacked grid (operand alignment before a Blockwise)."""

    __slots__ = ("target",)

    def __init__(self, child: Expr, target: Tuple[int, int]):
        self.target = tuple(target)
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        return v._pad_grid_to(self.target)

    def local_key(self):
        return ("padgrid", self.target)

    def rebuild(self, children):
        return PadGrid(children[0], self.target)


class AsType(Expr):
    __slots__ = ("dtype",)

    def __init__(self, child: Expr, dtype):
        self.dtype = jnp.dtype(dtype)
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        return v.astype(self.dtype)

    def local_key(self):
        return ("astype", str(self.dtype))

    def rebuild(self, children):
        return AsType(children[0], self.dtype)


class Densify(Expr):
    """Block-format conversion bcoo -> dense.  Inserted by the facade in
    front of ops with no zero-preserving sparse form (``+ scalar``, ``exp``,
    dense/sp division, ...) — an explicit plan node, so the conversion is
    visible to the optimizer and a sparse Blockwise chain never silently
    densifies inside a fused body."""

    __slots__ = ()

    def __init__(self, child: Expr):
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        return v.todense()

    def local_key(self):
        return ("densify",)

    def rebuild(self, children):
        return Densify(children[0])


class ToSparse(Expr):
    """Block-format conversion dense -> bcoo with a STATIC ``nse`` (entry
    capacity per block): the lazy layer cannot measure nnz at record time,
    so callers choose the capacity — ``costmodel.tosparse_pays`` says when
    the conversion is worth it at all."""

    __slots__ = ("nse",)

    def __init__(self, child: Expr, nse: int):
        self.nse = int(nse)
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        return v.tosparse(nse=self.nse)

    def local_key(self):
        return ("tosparse", self.nse)

    def rebuild(self, children):
        return ToSparse(children[0], self.nse)


class Canonicalize(Expr):
    """nse re-compaction inside a plan: merge duplicate BCOO indices and
    shrink the entry capacity to a STATIC ``nse`` bound.

    Recorded sparse± Blockwise nodes concatenate entry lists, so a chain's
    capacity grows as the sum of its operands' nse — unboundedly, since the
    recorder cannot measure nnz (the ROADMAP PR-4 follow-on).  A block can
    hold at most ``bn*bm`` distinct positions though, so compacting to that
    bound is always value-preserving and statically shaped (jittable inside
    the plan, unlike a data-dependent shrink).  The facade inserts this node
    when ``costmodel.bcoo_recompaction_pays`` says the accumulated capacity
    passed the bound; like every sparse node it is a fusion boundary but
    still CSEs and plan-caches by structure + nse."""

    __slots__ = ("nse",)

    def __init__(self, child: Expr, nse: int):
        self.nse = int(nse)
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        from repro.core import sparse as sparse_mod
        return sparse_mod.canonicalize(v, nse=self.nse)

    def local_key(self):
        return ("canon", self.nse)

    def rebuild(self, children):
        return Canonicalize(children[0], self.nse)


def _maybe_compact(node: Expr) -> Expr:
    """Wrap a sparse-producing node in :class:`Canonicalize` when its
    accumulated nse passed the per-block position bound (pigeonhole: the
    excess slots are duplicates, every consumer pays their bytes for
    nothing)."""
    if not _is_sparse(node.meta):
        return node
    bn, bm = node.meta.block_shape
    from repro.core import costmodel
    if costmodel.bcoo_recompaction_pays(node.meta.blocks.nse, bn * bm):
        return Canonicalize(node, bn * bm)
    return node


class MatMul(Expr):
    """Blocked GEMM.  ``transpose_a=True`` is the optimizer's folded form of
    ``MatMul(Transpose(x), y)``: it lowers through ``matmul_ta`` → the fused
    Pallas kernel with the transpose absorbed into block-index maps, never
    materializing the transposed stacked tensor."""

    __slots__ = ("transpose_a",)

    def __init__(self, a: Expr, b: Expr, transpose_a: bool = False):
        self.transpose_a = transpose_a
        self.children = (a, b)
        self._infer_meta()

    def lower(self, a, b):
        if self.transpose_a:
            return matmul_ta(a, b)
        return a @ b

    def local_key(self):
        return ("mm", self.transpose_a)

    def rebuild(self, children):
        return MatMul(children[0], children[1], self.transpose_a)


class Reduce(Expr):
    __slots__ = ("op", "axis")

    def __init__(self, child: Expr, op: str, axis: Optional[int]):
        self.op = op
        self.axis = axis
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        return v._reduce(self.op, self.axis)

    def local_key(self):
        return ("reduce", self.op, self.axis)

    def rebuild(self, children):
        return Reduce(children[0], self.op, self.axis)


def _norm_index(k, size: int):
    """Record-time normalization of one axis of a getitem key.

    -> ("static", hashable descriptor, rebuild value) for ints/slices, or
       ("array", ArrayLeaf) for integer/bool array selection (the values
       stay runtime inputs, so structurally-identical selections share a
       compiled plan).
    """
    if isinstance(k, (int, np.integer)):
        return ("static", ("i", int(k)), int(k))
    if isinstance(k, slice):
        desc = ("s", k.start, k.stop, k.step)
        return ("static", desc, k)
    if isinstance(k, jax.core.Tracer):
        raise TypeError("lazy getitem needs concrete index arrays "
                        "(record outside jit or pass an eager index)")
    arr = np.asarray(k) if not isinstance(k, jnp.ndarray) else k
    if getattr(arr, "dtype", None) is not None and arr.dtype == bool:
        arr = np.flatnonzero(np.asarray(arr))
    return ("array", ArrayLeaf(jnp.asarray(arr)), None)


class GetItem(Expr):
    """Slice / filter.  Static parts (ints, slices) are plan structure;
    index arrays become ``ArrayLeaf`` children."""

    __slots__ = ("rows_desc", "cols_desc")

    def __init__(self, child: Expr, rows, cols):
        self.rows_desc = rows
        self.cols_desc = cols
        kids = [child]
        for d in (rows, cols):
            if d[0] == "array":
                kids.append(d[1])
        self.children = tuple(kids)
        self._infer_meta()

    def _key_of(self, desc, arrays):
        if desc[0] == "static":
            return desc[2]
        return arrays.pop(0)

    def lower(self, v, *idx_arrays):
        arrays = list(idx_arrays)
        rows = self._key_of(self.rows_desc, arrays)
        cols = self._key_of(self.cols_desc, arrays)
        from repro.core import structural
        return structural.getitem(v, (rows, cols))

    def local_key(self):
        def part(desc):
            return desc[1] if desc[0] == "static" else ("a",)
        return ("getitem", part(self.rows_desc), part(self.cols_desc))

    def rebuild(self, children):
        rows, cols = self.rows_desc, self.cols_desc
        kids = list(children)
        child = kids.pop(0)
        if rows[0] == "array":
            rows = ("array", kids.pop(0), None)
        if cols[0] == "array":
            cols = ("array", kids.pop(0), None)
        return GetItem(child, rows, cols)


class Rechunk(Expr):
    __slots__ = ("block_shape",)

    def __init__(self, child: Expr, block_shape: Tuple[int, int]):
        self.block_shape = (int(block_shape[0]), int(block_shape[1]))
        self.children = (child,)
        self._infer_meta()

    def lower(self, v):
        from repro.core import structural
        return structural.rechunk(v, self.block_shape)

    def local_key(self):
        return ("rechunk", self.block_shape)

    def rebuild(self, children):
        return Rechunk(children[0], self.block_shape)


class ConcatRows(Expr):
    __slots__ = ()

    def __init__(self, parts: Sequence[Expr]):
        self.children = tuple(parts)
        self._infer_meta()

    def lower(self, *vals):
        from repro.core import structural
        return structural.concat_rows(list(vals))

    def local_key(self):
        return ("concat", len(self.children))

    def rebuild(self, children):
        return ConcatRows(children)


class Shuffle(Expr):
    """Row shuffle; the PRNG key is an ``ArrayLeaf`` runtime input."""

    __slots__ = ("kind",)

    def __init__(self, child: Expr, key_leaf: ArrayLeaf, kind: str):
        assert kind in ("pseudo", "exact"), kind
        self.kind = kind
        self.children = (child, key_leaf)
        self._infer_meta()

    def lower(self, v, key):
        from repro.core import shuffle as _shuffle
        fn = (_shuffle.pseudo_shuffle if self.kind == "pseudo"
              else _shuffle.exact_shuffle)
        return fn(key, v)

    def local_key(self):
        return ("shuffle", self.kind)

    def rebuild(self, children):
        return Shuffle(children[0], children[1], self.kind)


# ---------------------------------------------------------------------------
# Recording helpers
# ---------------------------------------------------------------------------


def lift(x) -> Expr:
    """x as an Expr: LazyDsArray → its expr, DsArray → Leaf."""
    if isinstance(x, (LazyDsArray, LazyScalar)):
        return x.expr
    if isinstance(x, DsArray):
        return Leaf(x)
    raise TypeError(f"cannot lift {type(x).__name__} into the lazy IR")


def lift_lazy(x: DsArray) -> "LazyDsArray":
    return LazyDsArray(Leaf(x))


def _scalar_key(v):
    """Hashable identity of a baked scalar operand, INCLUDING its dtype:
    plan-cache keys must not collide across `1`, `1.0` and `True` (tuple
    hashing treats them as equal), or an int plan would answer a float
    recording with wrongly-typed cached results."""
    try:
        arr = np.asarray(v)
        return (arr.item(), str(arr.dtype))
    except Exception:
        return None


def _align(a: Expr, b: Expr) -> Tuple[Expr, Expr]:
    """Insert Rechunk/PadGrid so both operands have identical stacked shapes
    (the recorded mirror of eager ``_binary``'s alignment)."""
    am, bm = a.meta, b.meta
    if am.shape != bm.shape:
        raise ValueError(f"shape mismatch {am.shape} vs {bm.shape}")
    if am.block_shape != bm.block_shape:
        b = Rechunk(b, am.block_shape)
        bm = b.meta
    if am.stacked_grid != bm.stacked_grid:
        common = (max(am.stacked_grid[0], bm.stacked_grid[0]),
                  max(am.stacked_grid[1], bm.stacked_grid[1]))
        if am.stacked_grid != common:
            a = PadGrid(a, common)
        if bm.stacked_grid != common:
            b = PadGrid(b, common)
    return a, b


def _wrap(e: Expr):
    """Expr → LazyDsArray for ds-shaped results, LazyScalar otherwise."""
    return LazyDsArray(e) if _is_ds(e.meta) else LazyScalar(e)


class LazyScalar:
    """A 0-d expression (whole-array reduction).  Supports the small algebra
    the ds-array API needs (scale, sqrt) and ``compute()``."""

    __slots__ = ("expr",)

    def __init__(self, expr: Expr):
        self.expr = expr

    @property
    def dtype(self):
        return self.expr.meta.dtype

    def _map(self, fn: Callable, key) -> "LazyScalar":
        return LazyScalar(Blockwise(fn, (self.expr,), key, elementwise=True))

    def _binary(self, other, op: Callable, reverse: bool, name: str):
        if isinstance(other, (LazyDsArray, LazyScalar, DsArray)):
            oe = lift(other)
            fn = (lambda x, y: op(y, x)) if reverse else (lambda x, y: op(x, y))
            return _wrap(Blockwise(fn, (self.expr, oe), (name, reverse),
                                   elementwise=True))
        sk = _scalar_key(other)
        if sk is None:
            return NotImplemented
        if reverse:
            return self._map(lambda x: op(other, x), (name, True, sk))
        return self._map(lambda x: op(x, other), (name, False, sk))

    def __add__(self, o):
        return self._binary(o, jnp.add, False, "add")

    __radd__ = __add__

    def __sub__(self, o):
        return self._binary(o, jnp.subtract, False, "sub")

    def __rsub__(self, o):
        return self._binary(o, jnp.subtract, True, "sub")

    def __mul__(self, o):
        return self._binary(o, jnp.multiply, False, "mul")

    __rmul__ = __mul__

    def __truediv__(self, o):
        return self._binary(o, jnp.divide, False, "div")

    def __rtruediv__(self, o):
        return self._binary(o, jnp.divide, True, "div")

    def sqrt(self) -> "LazyScalar":
        return self._map(jnp.sqrt, ("sqrt",))

    def compute(self):
        from repro.core import plan
        return plan.compute(self.expr)

    def __float__(self) -> float:
        return float(self.compute())


class LazyDsArray:
    """Recorded ds-array: mirrors the ``DsArray`` API, but every op appends
    an ``Expr`` node instead of dispatching.  ``compute()`` optimizes and
    runs the whole recorded plan (see ``core.plan``)."""

    __slots__ = ("expr",)

    def __init__(self, expr: Expr):
        if not _is_ds(expr.meta):
            raise TypeError("expression does not produce a ds-array")
        self.expr = expr

    # -- metadata (from the symbolically-propagated meta) --------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return self.expr.meta.shape

    @property
    def block_shape(self) -> Tuple[int, int]:
        return self.expr.meta.block_shape

    @property
    def grid(self) -> BlockGrid:
        return self.expr.meta.grid

    @property
    def stacked_grid(self) -> Tuple[int, int]:
        return self.expr.meta.stacked_grid

    @property
    def dtype(self):
        return self.expr.meta.dtype

    @property
    def pad_state(self) -> PadState:
        return self.expr.meta.pad_state

    @property
    def block_format(self) -> str:
        return "bcoo" if _is_sparse(self.expr.meta) else "dense"

    @property
    def is_sparse(self) -> bool:
        return self.block_format == "bcoo"

    @property
    def ndim(self) -> int:
        return 2

    @property
    def T(self) -> "LazyDsArray":
        return self.transpose()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"LazyDsArray(shape={self.shape}, "
                f"block_shape={self.block_shape}, dtype={self.dtype})")

    # -- materialization -----------------------------------------------------
    def compute(self) -> DsArray:
        from repro.core import plan
        return plan.compute(self.expr)

    def collect(self) -> jnp.ndarray:
        return self.compute().collect()

    def lazy(self) -> "LazyDsArray":
        return self

    # -- block-format conversions --------------------------------------------
    def todense(self) -> "LazyDsArray":
        if not self.is_sparse:
            return self
        return LazyDsArray(Densify(self.expr))

    def tosparse(self, nse: Optional[int] = None) -> "LazyDsArray":
        if self.is_sparse:
            return self
        if nse is None:
            raise ValueError(
                "lazy tosparse needs an explicit nse= (stored entries per "
                "block): nnz is runtime data the recorder cannot see — "
                "convert eagerly or pass a capacity")
        return LazyDsArray(ToSparse(self.expr, nse))

    # -- elementwise ---------------------------------------------------------
    def _binary(self, other, op: Callable, reverse: bool = False,
                name: Optional[str] = None):
        name = name or getattr(op, "__name__", "op")
        if isinstance(other, (LazyDsArray, DsArray)):
            a, b = _align(self.expr, lift(other))
            fa, fb = _is_sparse(a.meta), _is_sparse(b.meta)
            if fa or fb:
                # record the SAME classification the eager dispatch uses;
                # sparse Blockwise nodes carry BCOO-consuming fns and are
                # fusion boundaries in core.plan
                from repro.core import sparse as sparse_mod
                mode = sparse_mod.classify_binary(
                    op, fa, ("ds", fb, b.meta.dtype), reverse, a.meta.dtype)
                if mode == "pair":
                    # sparse± concatenates entry lists: compact the capacity
                    # back to the block bound once growth stops paying
                    return LazyDsArray(_maybe_compact(Blockwise(
                        sparse_mod.pair_fn(op, reverse), (a, b),
                        ("sp-pair", name, reverse), pad=PAD_ZERO,
                        elementwise=True)))
                if mode == "gather":
                    op2 = (lambda u, v: op(v, u)) if reverse else op
                    return LazyDsArray(Blockwise(
                        sparse_mod.gather_fn(op2, fa), (a, b),
                        ("sp-gather", name, reverse), pad=PAD_ZERO,
                        elementwise=True))
                if fa:
                    a = Densify(a)
                if fb:
                    b = Densify(b)
            fn = (lambda x, y: op(y, x)) if reverse else (lambda x, y: op(x, y))
            return LazyDsArray(Blockwise(fn, (a, b), (name, reverse),
                                         elementwise=True))
        if isinstance(other, LazyScalar):
            # the scalar's VALUE is unknown at record time, so there is no
            # zero-preservation proof: a sparse operand densifies
            me = self.todense().expr
            fn = (lambda x, s: op(s, x)) if reverse else (lambda x, s: op(x, s))
            return LazyDsArray(Blockwise(fn, (me, other.expr),
                                         (name, reverse), elementwise=True))
        if isinstance(other, (int, float, jnp.ndarray, np.ndarray)) \
                and jnp.ndim(other) == 0:
            sk = _scalar_key(other)
            if sk is None:
                return NotImplemented
            if self.is_sparse:
                from repro.core import sparse as sparse_mod
                mode = sparse_mod.classify_binary(op, True, other, reverse,
                                                  self.dtype)
                if mode == "data":
                    return LazyDsArray(Blockwise(
                        sparse_mod.data_map_fn(op, other, reverse),
                        (self.expr,), ("sp-data", name, reverse, sk),
                        pad=PAD_ZERO, elementwise=True))
                return self.todense()._binary(other, op, reverse, name)
            if reverse:
                fn = lambda x: op(other, x)          # noqa: E731
            else:
                fn = lambda x: op(x, other)          # noqa: E731
            return LazyDsArray(Blockwise(fn, (self.expr,),
                                         (name, reverse, sk),
                                         elementwise=True))
        return NotImplemented

    def __add__(self, o):
        return self._binary(o, jnp.add)

    __radd__ = __add__

    def __sub__(self, o):
        return self._binary(o, jnp.subtract)

    def __rsub__(self, o):
        return self._binary(o, jnp.subtract, reverse=True)

    def __mul__(self, o):
        return self._binary(o, jnp.multiply)

    __rmul__ = __mul__

    def __truediv__(self, o):
        return self._binary(o, jnp.divide)

    def __rtruediv__(self, o):
        return self._binary(o, jnp.divide, reverse=True)

    def __pow__(self, o):
        return self._binary(o, jnp.power)

    def __rpow__(self, o):
        return self._binary(o, jnp.power, reverse=True)

    def __neg__(self):
        return self.map_blocks(jnp.negative, _key=("neg",), _elementwise=True)

    def map_blocks(self, fn: Callable, pad: Optional[PadState] = None,
                   _key=None, _elementwise: bool = False) -> "LazyDsArray":
        # the fn OBJECT is part of the key (functions hash by identity, and
        # holding it in the cached-plan key keeps the id stable); user fns
        # are NOT marked elementwise — they may be position-dependent, which
        # must block the optimizer's transpose-hoist rule
        key = _key if _key is not None else ("map", fn, pad)
        if self.is_sparse:
            from repro.core import sparse as sparse_mod
            if pad is None and sparse_mod.zero_preserving_map(fn, self.dtype):
                return LazyDsArray(Blockwise(
                    sparse_mod.sparse_map_fn(fn), (self.expr,),
                    ("sp",) + (key if isinstance(key, tuple) else (key,)),
                    pad=PAD_ZERO, elementwise=_elementwise))
            return self.todense().map_blocks(fn, pad=pad, _key=_key,
                                             _elementwise=_elementwise)
        return LazyDsArray(Blockwise(fn, (self.expr,), key, pad=pad,
                                     elementwise=_elementwise))

    def sqrt(self) -> "LazyDsArray":
        return self.map_blocks(jnp.sqrt, _key=("sqrt",), _elementwise=True)

    def exp(self) -> "LazyDsArray":
        return self.map_blocks(jnp.exp, _key=("exp",), _elementwise=True)

    def abs(self) -> "LazyDsArray":
        return self.map_blocks(jnp.abs, _key=("abs",), _elementwise=True)

    def astype(self, dtype) -> "LazyDsArray":
        return LazyDsArray(AsType(self.expr, dtype))

    # -- structural ----------------------------------------------------------
    def transpose(self) -> "LazyDsArray":
        return LazyDsArray(Transpose(self.expr))

    def rechunk(self, block_shape: Tuple[int, int]) -> "LazyDsArray":
        bs = (int(block_shape[0]), int(block_shape[1]))
        if bs == self.block_shape:
            return self
        return LazyDsArray(Rechunk(self.expr, bs))

    def __getitem__(self, key) -> "LazyDsArray":
        if not isinstance(key, tuple):
            key = (key, slice(None))
        if len(key) != 2:
            raise IndexError("ds-arrays are 2-D")
        return LazyDsArray(GetItem(self.expr, _norm_index(key[0], self.shape[0]),
                                   _norm_index(key[1], self.shape[1])))

    def __matmul__(self, other):
        if not isinstance(other, (LazyDsArray, DsArray)):
            return NotImplemented
        return LazyDsArray(MatMul(self.expr, lift(other)))

    def __rmatmul__(self, other):
        if not isinstance(other, DsArray):
            return NotImplemented
        return LazyDsArray(MatMul(lift(other), self.expr))

    # -- reductions ----------------------------------------------------------
    def _reduce(self, op: str, axis: Optional[int]):
        return _wrap(Reduce(self.expr, op, axis))

    def sum(self, axis: Optional[int] = None):
        return self._reduce("sum", axis)

    def max(self, axis: Optional[int] = None):
        return self._reduce("max", axis)

    def min(self, axis: Optional[int] = None):
        return self._reduce("min", axis)

    def mean(self, axis: Optional[int] = None):
        n, m = self.shape
        denom = {None: n * m, 0: n, 1: m}[axis]
        me = self
        if not jnp.issubdtype(self.dtype, jnp.floating):
            me = self.astype(jnp.promote_types(self.dtype, jnp.float32))
        return me.sum(axis) / float(denom)

    def norm(self, axis: Optional[int] = None):
        sq = self._binary(self, jnp.multiply)
        s = sq.sum(axis)
        return s.sqrt()


def record_shuffle(key, a, kind: str):
    """Record a shuffle of a lazy (or lazily-flagged) operand."""
    return LazyDsArray(Shuffle(lift(a), ArrayLeaf(key), kind))


def record_concat(arrays: Sequence) -> LazyDsArray:
    return LazyDsArray(ConcatRows(tuple(lift(a) for a in arrays)))
