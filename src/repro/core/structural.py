"""Block-native structural ops for ds-arrays (slice / filter / rechunk / concat).

The paper's complexity claims (§5) rest on structural ops being expressed
**per block**: a slice touches only the blocks it selects, a rechunk moves
each element once, a concat stacks block grids.  This module is the stacked-
tensor realisation of that contract.  Every op here:

* consumes and produces the ``(gn, gm, bn, bm)`` stacked block tensor —
  **never** the ``(n, m)`` global layout (no ``collect``/``_global_padded``);
* is a pure jax function, so it traces through ``jit`` and, on sharded
  inputs, lets SPMD partitioning keep blocks where they live;
* re-establishes the pad-is-zero invariant before returning;
* when executed eagerly on a ``NamedSharding``-placed operand, re-places the
  result with the same mesh/spec (sharding would otherwise be silently
  dropped by eager ops).

Op inventory and costs (elements touched; N = n*m global elements):

====================  =========================  =======================
op                    seed (materialize) cost     block-native cost
====================  =========================  =======================
aligned slice         O(N) reshape + repack      O(selected blocks) view
unaligned slice       O(N) + gather              O(out) one gather
row filter A[idx]     O(N) + gather              O(out) one gather
rechunk (dividing)    O(N) x2 (two layouts)      O(N) single regroup reshape
rechunk (general)     O(N) x2                    O(N) two block gathers
concat (aligned)      O(sum N_i) x2              O(1) grid stack
concat (general)      O(sum N_i) x2              O(sum N_i) block gathers
====================  =========================  =======================

The crucial difference is not only the constant: the seed path builds a
rank-2 ``(n, m)`` intermediate (single-host memory, sharding destroyed),
while every intermediate here keeps the block layout (rank-3/4, grid dims
leading), which is exactly what the no-global-intermediate tests assert on
the jaxpr.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.blocking import (BlockGrid, ceil_div, grid_span,
                                 is_aligned_slice, can_regroup)


def _as_dense(a: "DsArray") -> "DsArray":
    """Dense view of the operand: structural ops (slice/rechunk/concat) are
    per-position data movement, which the element-sparse BCOO layout cannot
    express without re-bucketing every entry — they densify by policy (see
    the ``core.dsarray`` op table) and the dense block-native path runs."""
    if getattr(a, "is_sparse", False):
        from repro.core import sparse as sparse_mod
        return sparse_mod.todense(a)
    return a


def _mask_axes(blocks: jnp.ndarray, n: Optional[int] = None,
               m: Optional[int] = None) -> jnp.ndarray:
    """Zero the pad region along the given logical extents, cheaply.

    Pass ``n`` to mask rows beyond it, ``m`` for columns; ``None`` skips the
    axis (its pad is already known-zero via the invariant).  Masks are small
    per-axis tensors broadcast into a single ``where`` — O(1) mask setup and
    one pass over the data, vs. the full-size 4-iota mask this replaces.
    """
    from repro.core.dsarray import _axis_mask
    gn, gm, bn, bm = blocks.shape
    mask = None
    if n is not None:
        mask = _axis_mask(n, gn, bn)[:, None, :, None]
    if m is not None:
        cm = _axis_mask(m, gm, bm)[None, :, None, :]
        mask = cm if mask is None else (mask & cm)
    if mask is None:
        return blocks
    return jnp.where(mask, blocks, jnp.zeros((), blocks.dtype))


# ---------------------------------------------------------------------------
# Sharding preservation
# ---------------------------------------------------------------------------


def preserve_sharding(out: "DsArray", ref_blocks) -> "DsArray":
    """Re-place ``out`` with the NamedSharding of ``ref_blocks`` (eager only).

    Inside ``jit`` both are tracers and SPMD propagation handles placement;
    eagerly, jax ops drop shardings, so we put the result back on the mesh
    the operand lived on.  Falls back to default placement when the grid no
    longer fits the mesh.
    """
    if isinstance(ref_blocks, jax.core.Tracer) or isinstance(out.blocks, jax.core.Tracer):
        return out
    sharding = getattr(ref_blocks, "sharding", None)
    if not isinstance(sharding, NamedSharding):
        return out
    try:
        blocks = jax.device_put(out.blocks, NamedSharding(sharding.mesh, sharding.spec))
        return type(out)(blocks, out.grid, out.pad_state)
    except Exception:  # grid not placeable on that mesh anymore
        return out


# ---------------------------------------------------------------------------
# Row/col gather kernels (the per-block lowering for unaligned selection)
# ---------------------------------------------------------------------------


def _gather_block_rows(blocks: jnp.ndarray, idx: jnp.ndarray,
                       out_bn: int) -> jnp.ndarray:
    """Select global rows ``idx`` from a stacked tensor as ONE ``lax.gather``.

    Source row ``s`` lives at ``blocks[s // bn, :, s % bn, :]``; advanced
    indexing with the two derived index vectors emits a single gather whose
    output is already in block-row-major order — no ``(n, m)`` intermediate.
    Returns ``(out_gn, gm, out_bn, bm)``; caller re-masks.
    """
    gn, gm, bn, bm = blocks.shape
    p = idx.shape[0]
    out_gn = max(1, ceil_div(p, out_bn))
    pad = out_gn * out_bn - p
    if pad:
        idx = jnp.concatenate([idx, jnp.zeros((pad,), idx.dtype)])
    src_grid = idx // bn
    src_off = idx % bn
    rows = blocks[src_grid, :, src_off, :]          # (out_gn*out_bn, gm, bm)
    return rows.reshape(out_gn, out_bn, gm, bm).transpose(0, 2, 1, 3)


def take_rows(a: "DsArray", idx, out_bn: Optional[int] = None) -> "DsArray":
    """Integer-array row selection (the paper's 'filtering'), block-native.

    ``idx`` may be a traced jnp array — the selection shape is static
    (``len(idx)``) while the selected rows stay dynamic, so this jits.
    """
    a = _as_dense(a).ensure_zero_pad()  # gathers re-use the source col pad
    idx = jnp.asarray(idx)
    if idx.ndim != 1:
        raise IndexError(f"row index must be 1-D, got shape {idx.shape}")
    n, m = a.shape
    idx = jnp.where(idx < 0, idx + n, idx).astype(jnp.int32)
    if not isinstance(idx, jax.core.Tracer):
        vals = np.asarray(idx)
        if vals.size and (vals.min() < 0 or vals.max() >= n):
            raise IndexError(f"row index out of range for {n} rows")
    p = int(idx.shape[0])
    bn = a.block_shape[0]
    out_bn = out_bn or min(bn, max(1, p))
    # gathered rows are valid source rows (col pad zero via the invariant);
    # only the row pad introduced by tiling to out_bn needs masking
    out = _gather_block_rows(a.blocks, idx, out_bn)
    if out.shape[0] * out_bn > p:
        out = _mask_axes(out, n=p)
    grid = BlockGrid((p, m), (out_bn, a.block_shape[1]))
    return preserve_sharding(type(a)(out, grid), a.blocks)


def take_cols(a: "DsArray", idx, out_bm: Optional[int] = None) -> "DsArray":
    """Column analogue of :func:`take_rows` (gather on the transposed grid)."""
    a = _as_dense(a).ensure_zero_pad()
    idx = jnp.asarray(idx)
    if idx.ndim != 1:
        raise IndexError(f"col index must be 1-D, got shape {idx.shape}")
    n, m = a.shape
    idx = jnp.where(idx < 0, idx + m, idx).astype(jnp.int32)
    if not isinstance(idx, jax.core.Tracer):
        vals = np.asarray(idx)
        if vals.size and (vals.min() < 0 or vals.max() >= m):
            raise IndexError(f"col index out of range for {m} cols")
    p = int(idx.shape[0])
    bm = a.block_shape[1]
    out_bm = out_bm or min(bm, max(1, p))
    flipped = a.blocks.transpose(1, 0, 3, 2)
    out = _gather_block_rows(flipped, idx, out_bm).transpose(1, 0, 3, 2)
    if out.shape[1] * out_bm > p:
        out = _mask_axes(out, m=p)
    grid = BlockGrid((n, p), (a.block_shape[0], out_bm))
    return preserve_sharding(type(a)(out, grid), a.blocks)


# ---------------------------------------------------------------------------
# Aligned slicing: pure grid slice + edge remask
# ---------------------------------------------------------------------------


def aligned_slice(a: "DsArray", rows: slice, cols: slice) -> "DsArray":
    """``A[r0:r1, c0:c1]`` with r0/c0 on block boundaries and unit step.

    Pure ``blocks[g0:g1, h0:h1]`` grid slice — O(selected blocks), zero data
    movement beyond the selected blocks, then an edge remask for the (possibly
    partial) last block row/col.
    """
    a = _as_dense(a).ensure_zero_pad()  # edge blocks re-use the source pad
    n, m = a.shape                      # when the slice stops at n/m
    bn, bm = a.block_shape
    r0, r1, rs = rows.indices(n)
    c0, c1, cs = cols.indices(m)
    assert rs == 1 and cs == 1 and r0 % bn == 0 and c0 % bm == 0
    g0, g1 = (0, 1) if r1 <= r0 else grid_span(r0, r1, bn)
    h0, h1 = (0, 1) if c1 <= c0 else grid_span(c0, c1, bm)
    out = a.blocks[g0:g1, h0:h1]
    nr, nc = max(0, r1 - r0), max(0, c1 - c0)
    # the edge blocks need re-masking only when the slice STOPS mid-block
    # before the end of the data (stopping at n reuses the source pad, which
    # is already zero); a fully aligned slice is a pure grid slice.
    need_r = nr if (r1 % bn != 0 and r1 < n) or nr == 0 else None
    need_c = nc if (c1 % bm != 0 and c1 < m) or nc == 0 else None
    out = _mask_axes(out, n=need_r, m=need_c)
    grid = BlockGrid((nr, nc), (bn, bm))
    return preserve_sharding(type(a)(out, grid), a.blocks)


def getitem(a: "DsArray", key) -> "DsArray":
    """NumPy-style ``A[key]`` lowered to block-native ops (paper §4.2.3).

    Aligned unit-step slices take the grid-slice path; everything else
    (unaligned starts, strides, negative steps, int arrays, bool masks)
    lowers to one per-block gather per affected axis.
    """
    if not isinstance(key, tuple):
        key = (key, slice(None))
    if len(key) != 2:
        raise IndexError("ds-arrays are 2-D")
    rows, cols = key

    def classify(k, size: int, block: int):
        """-> ("aligned", slice) | ("gather", idx)"""
        if isinstance(k, (int, np.integer)):
            k = int(k)
            if k < -size or k >= size:
                raise IndexError(f"index {k} out of range for size {size}")
            if k < 0:
                k += size
            if k % block == 0:
                return ("aligned", slice(k, k + 1))
            return ("gather", jnp.asarray([k], jnp.int32))
        if isinstance(k, slice):
            if is_aligned_slice(k, size, block):
                return ("aligned", k)
            start, stop, step = k.indices(size)
            return ("gather", jnp.arange(start, stop, step, dtype=jnp.int32))
        arr = np.asarray(k) if not isinstance(k, (jnp.ndarray, jax.core.Tracer)) else k
        if getattr(arr, "dtype", None) is not None and arr.dtype == bool:
            arr = np.flatnonzero(np.asarray(arr))
        return ("gather", jnp.asarray(arr))

    rkind, rsel = classify(rows, a.shape[0], a.block_shape[0])
    ckind, csel = classify(cols, a.shape[1], a.block_shape[1])

    def is_full(kind, sel, size):
        return kind == "aligned" and sel.indices(size) == (0, size, 1)

    if getattr(a, "is_sparse", False) and rkind == "aligned" \
            and ckind == "aligned":
        # pure block-aligned selection of a BCOO array: slice the stacked
        # BCOO's batch dims directly — no densify (ROADMAP PR-4 follow-on)
        if is_full(rkind, rsel, a.shape[0]) and is_full(ckind, csel,
                                                        a.shape[1]):
            return a
        from repro.core import sparse as sparse_mod
        return sparse_mod.aligned_slice_sparse(a, rsel, csel)

    out = a
    # grid slices first (cheapest: shrink before gathering)
    if ((rkind == "aligned" and not is_full(rkind, rsel, a.shape[0]))
            or (ckind == "aligned" and not is_full(ckind, csel, a.shape[1]))):
        out = aligned_slice(out,
                            rsel if rkind == "aligned" else slice(None),
                            csel if ckind == "aligned" else slice(None))
    if rkind == "gather":
        out = take_rows(out, rsel)
    if ckind == "gather":
        out = take_cols(out, csel)
    return out


# ---------------------------------------------------------------------------
# Rechunk: grid-local regroup when block shapes divide, gather repack else
# ---------------------------------------------------------------------------


def _split_rows(blocks: jnp.ndarray, new_bn: int) -> jnp.ndarray:
    gn, gm, bn, bm = blocks.shape
    f = bn // new_bn
    out = blocks.reshape(gn, gm, f, new_bn, bm).transpose(0, 2, 1, 3, 4)
    return out.reshape(gn * f, gm, new_bn, bm)


def _merge_rows(blocks: jnp.ndarray, new_bn: int) -> jnp.ndarray:
    gn, gm, bn, bm = blocks.shape
    f = new_bn // bn
    pad = (-gn) % f
    if pad:
        blocks = jnp.pad(blocks, ((0, pad), (0, 0), (0, 0), (0, 0)))
    gn2 = (gn + pad) // f
    out = blocks.reshape(gn2, f, gm, bn, bm).transpose(0, 2, 1, 3, 4)
    return out.reshape(gn2, gm, new_bn, bm)


def _regroup_rows(blocks: jnp.ndarray, new_bn: int) -> jnp.ndarray:
    bn = blocks.shape[2]
    if new_bn == bn:
        return blocks
    return _split_rows(blocks, new_bn) if bn % new_bn == 0 else _merge_rows(blocks, new_bn)


def _split_cols(blocks: jnp.ndarray, new_bm: int) -> jnp.ndarray:
    gn, gm, bn, bm = blocks.shape
    f = bm // new_bm
    out = blocks.reshape(gn, gm, bn, f, new_bm).transpose(0, 1, 3, 2, 4)
    return out.reshape(gn, gm * f, bn, new_bm)


def _merge_cols(blocks: jnp.ndarray, new_bm: int) -> jnp.ndarray:
    gn, gm, bn, bm = blocks.shape
    f = new_bm // bm
    pad = (-gm) % f
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, pad), (0, 0), (0, 0)))
    gm2 = (gm + pad) // f
    out = blocks.reshape(gn, gm2, f, bn, bm).transpose(0, 1, 3, 2, 4)
    return out.reshape(gn, gm2, bn, new_bm)


def _regroup_cols(blocks: jnp.ndarray, new_bm: int) -> jnp.ndarray:
    bm = blocks.shape[3]
    if new_bm == bm:
        return blocks
    return _split_cols(blocks, new_bm) if bm % new_bm == 0 else _merge_cols(blocks, new_bm)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _rechunk_blocks(blocks: jnp.ndarray, shape: Tuple[int, int],
                    new_bs: Tuple[int, int]) -> jnp.ndarray:
    """The pure regroup/repack math, jitted so the reshape→transpose→reshape
    chain (or the gather fallback) dispatches as one fused kernel even when
    ``rechunk`` is called eagerly — repeated calls hit the jit cache."""
    n, m = shape
    bn, bm = blocks.shape[2:]
    nbn, nbm = new_bs
    if can_regroup((bn, bm), new_bs):
        # regrouping preserves the padded-global coordinate of every element,
        # so the pad-is-zero invariant carries over — no remask needed
        blocks = _regroup_rows(blocks, nbn)
        return _regroup_cols(blocks, nbm)
    # windowed repack: one row gather + one col gather in block layout;
    # tiling pad slots replicate row/col 0 and must be re-masked
    need_r = need_c = None
    if nbn != bn:
        blocks = _gather_block_rows(blocks, jnp.arange(max(1, n), dtype=jnp.int32), nbn)
        need_r = n if blocks.shape[0] * nbn > n else None
    if nbm != bm:
        flipped = blocks.transpose(1, 0, 3, 2)
        blocks = _gather_block_rows(
            flipped, jnp.arange(max(1, m), dtype=jnp.int32), nbm
        ).transpose(1, 0, 3, 2)
        need_c = m if blocks.shape[1] * nbm > m else None
    return _mask_axes(blocks, n=need_r, m=need_c)


def rechunk(a: "DsArray", block_shape: Tuple[int, int]) -> "DsArray":
    """Re-block to a new block size without materializing the global array.

    Evenly-dividing cases (either direction, per axis independently) are a
    reshape/transpose **regroup** of the stacked tensor: the padded global
    coordinate of every element is invariant under splitting a block into
    tiles or fusing a tile neighbourhood, so the regroup is exact and moves
    each element once.  Non-dividing block shapes fall back to the windowed
    per-block gather used for unaligned slicing (still no rank-2 global
    intermediate).
    """
    block_shape = (int(block_shape[0]), int(block_shape[1]))
    if block_shape == a.block_shape:
        return a
    a = _as_dense(a).ensure_zero_pad()  # regroup/gather carry the pad along
    grid = BlockGrid(a.shape, block_shape)   # validates block_shape > 0
    blocks = _rechunk_blocks(a.blocks, a.shape, block_shape)
    return preserve_sharding(type(a)(blocks, grid), a.blocks)


# ---------------------------------------------------------------------------
# Concatenation
# ---------------------------------------------------------------------------


def concat_rows(arrays: Sequence["DsArray"]) -> "DsArray":
    """Vertical concat, block-native.

    When every part (after rechunking to a common block shape) has a row
    count divisible by ``bn`` — except possibly the last — the result is a
    plain stack of block grids: O(1) ops, no element is re-addressed.  The
    general case gathers each part's valid rows in block layout and re-tiles.
    """
    arrays = list(arrays)
    if not arrays:
        raise ValueError("concat_rows of empty sequence")
    m = arrays[0].shape[1]
    for a in arrays[1:]:
        if a.shape[1] != m:
            raise ValueError(
                f"concat_rows column mismatch: {a.shape[1]} != {m}")
    bs = arrays[0].block_shape
    parts = [rechunk(a, bs) if a.block_shape != bs else _as_dense(a)
             for a in arrays]
    parts = [p.ensure_zero_pad() for p in parts]   # grid stack keeps tail pads
    nonempty = [p for p in parts if p.shape[0] > 0]
    parts = nonempty or parts[:1]
    bn, bm = bs
    total = sum(p.shape[0] for p in parts)
    grid = BlockGrid((total, m), bs)
    gm = max(1, ceil_div(m, bm))

    def trimmed(p: "DsArray") -> jnp.ndarray:
        """Valid grid rows only, stacked gm normalized (drop mesh padding)."""
        return p.blocks[: max(1, ceil_div(p.shape[0], bn)), :gm]

    if all(p.shape[0] % bn == 0 for p in parts[:-1]):
        # interior parts contribute only full blocks, the final part keeps its
        # own (already-zero) pad: a pure grid stack, invariant preserved
        blocks = jnp.concatenate([trimmed(p) for p in parts], axis=0)
    else:
        rows = []
        for p in parts:
            b = trimmed(p)
            idx = jnp.arange(p.shape[0], dtype=jnp.int32)
            rows.append(b[idx // bn, :, idx % bn, :])    # (n_i, gm, bm)
        flat = jnp.concatenate(rows, axis=0)
        out_gn = max(1, ceil_div(total, bn))
        pad = out_gn * bn - total
        if pad:
            flat = jnp.pad(flat, ((0, pad), (0, 0), (0, 0)))
        blocks = flat.reshape(out_gn, bn, gm, bm).transpose(0, 2, 1, 3)
    return preserve_sharding(type(arrays[0])(blocks, grid),
                             arrays[0].blocks)


# ---------------------------------------------------------------------------
# Block-native Gram matrix (used by ALS instead of collect())
# ---------------------------------------------------------------------------


def gram(a: "DsArray") -> jnp.ndarray:
    """``AᵀA`` as a replicated dense ``(m, m)`` matrix, computed per block.

    One einsum over the stacked tensor — partial Gram per block row, summed
    over the grid (a psum over ``data`` when sharded).  Never forms the
    ``(n, m)`` global layout; intended for skinny operands (m = latent
    factors) where the Gram is small and replicated.
    """
    if getattr(a, "is_sparse", False):
        # AᵀA with the sparse operand on the (transposed) left: one
        # bcoo_dot_general, the BCOO side is never densified — only the
        # skinny rhs takes its dense form
        from repro.core.dsarray import matmul_ta
        g = matmul_ta(a, _as_dense(a))
        return jnp.asarray(g.collect()).astype(a.dtype)
    b = a.ensure_zero_pad().blocks  # zero pad contributes nothing to the Gram
    g = jnp.einsum("ijab,ikac->jbkc", b, b,
                   preferred_element_type=jnp.float32)
    gm, bm = b.shape[1], b.shape[3]
    m = a.shape[1]
    return g.reshape(gm * bm, gm * bm)[:m, :m].astype(a.dtype)
