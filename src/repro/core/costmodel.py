"""The paper's task-complexity laws + their TPU collective-byte analogues.

On PyCOMPSs the cost of a distributed-array op is (a) the number of tasks the
scheduler must dispatch (~milliseconds each at scale, the paper's dominant
overhead in Figs. 6/8) and (b) the bytes moved between workers.  On a TPU pod
dispatch is compiled away, so the surviving analogue of (a)+(b) is the bytes
crossing ICI links per collective.  Benchmarks plot BOTH models: the task law
reproduces the paper's figures; the byte law predicts the TPU behaviour that
the roofline harness then measures from compiled HLO.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Task-count laws, verbatim from the paper.
# ---------------------------------------------------------------------------


def dataset_transpose_tasks(n_subsets: int) -> int:
    """Paper §5.2: split each Subset into N parts (N^2) + merge (N)."""
    return n_subsets * n_subsets + n_subsets


def dsarray_transpose_tasks(grid_rows: int, grid_cols: int) -> int:
    """Paper §5.2: one task per row of blocks (local transpose + grid
    permutation; the permutation is metadata-only)."""
    del grid_cols
    return grid_rows


def dataset_shuffle_tasks(n_subsets: int, subset_size: int) -> int:
    """Paper §5.4: N * min(N, S) splits + N merges."""
    return n_subsets * min(n_subsets, subset_size) + n_subsets


def dsarray_shuffle_tasks(grid_rows: int) -> int:
    """Paper §5.4: 2N thanks to COLLECTION_IN/OUT multi-I/O tasks."""
    return 2 * grid_rows


def dataset_rowsum_tasks(n_subsets: int) -> int:
    """Paper Fig. 3: one partial-sum task per Subset + a reduction tree."""
    return n_subsets + (n_subsets - 1)


def dsarray_colsum_tasks(grid_rows: int, grid_cols: int) -> int:
    """Paper Fig. 5: one task per column of blocks."""
    del grid_rows
    return grid_cols


def dataset_slice_tasks(n_subsets: int) -> int:
    """Row-partitioned Datasets must gather every Subset, slice the merged
    copy, then re-split: N gathers + 1 slice + N splits (paper Fig. 3
    structure applied to selection)."""
    return 2 * n_subsets + 1


def dsarray_slice_tasks(sel_grid_rows: int, sel_grid_cols: int) -> int:
    """Block-aligned slice: one task per SELECTED block; unselected blocks are
    never touched (paper §5: per-block ops)."""
    return sel_grid_rows * sel_grid_cols


def dsarray_filter_tasks(out_grid_rows: int, grid_cols: int) -> int:
    """Integer-array row selection: one gather task per output block row,
    across each block column."""
    return out_grid_rows * grid_cols


def dsarray_rechunk_tasks(grid_rows: int, grid_cols: int) -> int:
    """Evenly-dividing rechunk: one regroup task per source block (each
    element moves exactly once).  The seed materialize path was 2 global
    relayouts (O(N) twice) plus a host gather."""
    return grid_rows * grid_cols


def dsarray_concat_tasks(n_parts: int) -> int:
    """Aligned concat: one grid-stack task per part (metadata + placement);
    the Dataset append must copy every Subset of both operands."""
    return n_parts


def dataset_als_tasks(n_subsets: int, iters: int) -> int:
    """ALS on Datasets: transpose copy up front + per-iteration row/col solves.
    The transpose dominates (paper §5.3)."""
    return dataset_transpose_tasks(n_subsets) + iters * 2 * n_subsets


def dsarray_als_tasks(grid: int, iters: int) -> int:
    return iters * 2 * grid


# ---------------------------------------------------------------------------
# PyCOMPSs wall-time model (fits the paper's figures):
#   t = tasks * overhead / min(cores, parallel_width) + compute + bytes/bw
# The paper attributes the Dataset collapse to scheduler overhead growing with
# task count; overhead_s ~ 2-10 ms/task reproduces the reported 4.5 h -> 7 s.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SchedulerModel:
    overhead_s: float = 4e-3        # per-task scheduling cost (master-side, serial)
    worker_overhead_s: float = 1e-3  # per-task worker-side launch cost


def pycompss_time(
    tasks: int,
    compute_s_per_task: float,
    cores: int,
    model: SchedulerModel = SchedulerModel(),
) -> float:
    serial = tasks * model.overhead_s  # master dispatch is serial
    parallel = tasks * (compute_s_per_task + model.worker_overhead_s) / max(1, cores)
    return serial + parallel


# ---------------------------------------------------------------------------
# TPU collective-byte laws for the same ops (what the roofline measures).
# All are per-device bytes; mesh (dn, dm), element size e.
# ---------------------------------------------------------------------------


def tpu_transpose_bytes(n: int, m: int, e: int, dn: int, dm: int) -> float:
    """all_to_all over both mesh axes: each device keeps 1/(dn*dm) of its shard
    and sends the rest; per-device shard is n*m*e/(dn*dm)."""
    shard = n * m * e / (dn * dm)
    return shard * (1.0 - 1.0 / (dn * dm))


def tpu_colsum_bytes(n: int, m: int, e: int, dn: int, dm: int) -> float:
    """psum over the `data` axis of a (1, m/dm) partial per device:
    ring all-reduce moves 2*(dn-1)/dn of the reduced tensor."""
    del n
    reduced = m * e / dm
    return reduced * 2.0 * (dn - 1) / dn


def tpu_shuffle_bytes(n: int, m: int, e: int, dn: int, dm: int) -> float:
    """row pseudo-shuffle = all_to_all along `data`: ~full shard leaves."""
    del dm
    shard = n * m * e / dn
    return shard * (1.0 - 1.0 / dn)


def tpu_summa_bytes(n: int, k: int, m: int, e: int, dn: int, dm: int) -> float:
    """SUMMA C[n,m] = A[n,k] @ B[k,m] on an (dn, dm) mesh: every device
    receives the A-panel row broadcast (n*k/dn per step, dm steps → n*k*e/dn)
    and the B-panel column broadcast (k*m*e/dm)."""
    return n * k * e / dn + k * m * e / dm


def tpu_aligned_slice_bytes(rows: int, cols: int, e: int, dn: int, dm: int) -> float:
    """Block-aligned slice on an unchanged mesh: a grid slice keeps every
    selected block on its device — zero collective bytes.  (Rebalancing the
    smaller grid across the mesh, if requested, moves at most the selected
    bytes once: rows*cols*e/(dn*dm) per device.)"""
    del rows, cols, e, dn, dm
    return 0.0


def tpu_filter_bytes(out_rows: int, m: int, e: int, dn: int, dm: int) -> float:
    """Row gather: each output row is fetched from the device owning its
    source block — worst case the full output crosses the mesh once."""
    return out_rows * m * e / (dn * dm)


def tpu_rechunk_bytes(n: int, m: int, e: int, dn: int, dm: int,
                      dividing: bool = True) -> float:
    """Evenly-dividing rechunk is a local regroup (0 bytes — the grid->device
    map is refined in place); the gather fallback moves each shard once."""
    if dividing:
        return 0.0
    return n * m * e / (dn * dm)


def collective_time_s(bytes_per_device: float, link_bw: float = 50e9) -> float:
    return bytes_per_device / link_bw


# ---------------------------------------------------------------------------
# Local-GEMM laws: fused stacked Pallas kernel vs the per-grid-k loop.
#
# The loop-of-vmaps path launched one 2-D GEMM per grid-k step and
# accumulated C in HBM (write the partial, read it back next step); the
# fused stacked kernel reduces grid-k x block-k inside one launch with the
# accumulator resident in VMEM, so C is written exactly once.
# ---------------------------------------------------------------------------


def stacked_gemm_flops(gi: int, gj: int, gk: int,
                       bn: int, bk: int, bm: int) -> float:
    """MACs x2 for C(gi*bn, gj*bm) = A(gi*bn, gk*bk) @ B(gk*bk, gj*bm)."""
    return 2.0 * gi * gj * gk * bn * bk * bm


def stacked_gemm_hbm_bytes(gi: int, gj: int, gk: int, bn: int, bk: int,
                           bm: int, e: int, fused: bool = True) -> float:
    """HBM traffic of the local blocked GEMM, element size ``e``.

    Every C tile streams its A panel row (re-read per gj) and B panel column
    (re-read per gi).  Fused: C written once.  Unfused (the old loop): every
    grid-k step writes the full C partial and re-reads it for the add —
    (2*gk - 1)x the C traffic, the term the fused kernel deletes.
    """
    a_reads = gi * gk * bn * bk * gj * e
    b_reads = gk * gj * bk * bm * gi * e
    c_bytes = gi * gj * bn * bm * e
    if fused:
        return a_reads + b_reads + c_bytes
    return a_reads + b_reads + (2 * gk - 1) * c_bytes


def gemm_kernel_launches(gk: int, fused: bool = True) -> int:
    """Kernel-dispatch law: the fused kernel is 1 launch however deep the
    grid-k reduction; the loop path paid one per grid-k step."""
    return 1 if fused else gk


# ---------------------------------------------------------------------------
# Remask laws: pad-state tracking vs unconditional per-op re-masking.
# ---------------------------------------------------------------------------


def remask_pass_bytes(n: int, m: int, e: int) -> float:
    """One mask pass = read + write of the padded tensor (the per-axis masks
    are O(sqrt N) and free by comparison)."""
    return 2.0 * n * m * e


def chain_remask_passes(n_ops: int, pad_tracked: bool = True,
                        zero_preserving: bool = True) -> int:
    """Mask passes over an ``n_ops``-long elementwise chain ending in a
    consumer (reduction / matmul / structural op).

    Untracked (seed): one pass per op.  Tracked: zero-preserving chains pay
    none (the consumer sees pad_state == identity); otherwise the consumer
    pays exactly one deferred pass, regardless of chain length.
    """
    if not pad_tracked:
        return n_ops
    return 0 if zero_preserving else min(1, n_ops)


# ---------------------------------------------------------------------------
# Sparse-block (BCOO) laws: when does the bcoo format pay?
#
# A stacked BCOO stores, per entry, the value plus a 2-D block-local index
# (element size e + 2*idx_e bytes vs e for dense), so storage — and the HBM
# traffic of every streaming op, which is what bounds elementwise/matvec on
# TPU — shrinks only below a crossover density.  FLOP-wise spmm scales with
# nnz directly.  ``core.io.from_array_auto`` consults these laws to pick a
# ``block_format``, and ``benchmarks/bench_sparse.py`` measures the real
# crossover against them.
# ---------------------------------------------------------------------------


def bcoo_bytes(nnz: int, e: int, idx_e: int = 4) -> float:
    """Stored bytes of a stacked BCOO with ``nnz`` entries (data + the
    per-entry (row, col) block-local index pair)."""
    return nnz * (e + 2.0 * idx_e)


def dense_stacked_bytes(gn: int, gm: int, bn: int, bm: int, e: int) -> float:
    return float(gn) * gm * bn * bm * e


def sparse_storage_crossover_density(e: int, idx_e: int = 4) -> float:
    """Density below which bcoo storage (and thus the bytes every streaming
    op moves) beats dense: d* = e / (e + 2*idx_e) — 1/3 for f32 data with
    i32 indices.  This is the io auto-pick default threshold."""
    return e / (e + 2.0 * idx_e)


def spmm_flops(nnz: int, out_cols: int) -> float:
    """MACs x2 of ``sp @ dense``: each stored entry multiplies one dense
    row-slice of the rhs (``out_cols`` wide) — nnz-proportional, vs the
    dense ``2*n*k*out_cols``."""
    return 2.0 * nnz * out_cols


def spmm_hbm_bytes(nnz: int, k: int, m: int, out_rows: int, e: int,
                   idx_e: int = 4) -> float:
    """HBM traffic of ``sp[out_rows, k] @ dense[k, m]``: stream the stored
    entries once (value + index), the dense rhs once, write the dense
    result once."""
    return bcoo_bytes(nnz, e, idx_e) + float(k) * m * e + float(out_rows) * m * e


def sparse_matmul_crossover_density(k: int, m: int, out_rows: int, e: int,
                                    idx_e: int = 4) -> float:
    """Density where spmm HBM bytes equal the dense GEMM's A-read bytes
    (rhs/result traffic is common to both): nnz*(e+2*idx_e) = out_rows*k*e
    → d* = e/(e+2*idx_e), the storage crossover again — spmm is
    memory-bound at ds-array block sizes, so bytes ARE the model."""
    del k, m, out_rows
    return sparse_storage_crossover_density(e, idx_e)


def bcoo_recompaction_saved_bytes(nse: int, block_elems: int, n_blocks: int,
                                  e: int = 4, idx_e: int = 4) -> float:
    """Bytes a lazy nse re-compaction deletes from every later streaming op.

    Recorded sparse± nodes CONCATENATE entry lists, so a chain's capacity
    grows as the sum of its operands' nse — but a block can hold at most
    ``block_elems`` distinct positions, so ``sparse.canonicalize`` with a
    static ``nse = block_elems`` target always preserves the values while
    capping the capacity.  Everything past the compaction point streams
    ``bcoo_bytes(target)`` instead of ``bcoo_bytes(nse)`` per block.
    """
    target = min(nse, block_elems)
    return n_blocks * (bcoo_bytes(nse, e, idx_e) - bcoo_bytes(target, e, idx_e))


def bcoo_recompaction_pays(nse: int, block_elems: int, e: int = 4,
                           idx_e: int = 4) -> bool:
    """Should the lazy recorder insert an nse-shrinking canonicalize node
    after a sparse± Blockwise?  Iff the accumulated capacity exceeds the
    per-block position bound — beyond it the extra slots are duplicates by
    pigeonhole and every consumer of the chain pays their bytes for nothing
    (at ``nse = block_elems`` the BCOO already stores ``(e + 2*idx_e)/e``x
    the dense block, so growth past the bound is pure waste)."""
    return bcoo_recompaction_saved_bytes(nse, block_elems, 1, e, idx_e) > 0


def tosparse_pays(density: float, e: int = 4, idx_e: int = 4,
                  streaming_ops: int = 1) -> bool:
    """Should an array be converted to bcoo?  The conversion itself costs
    one dense read; it pays when the per-op byte saving, times the number
    of streaming ops that will consume the sparse form, beats that.  With
    ``streaming_ops >= 1`` the break-even is the storage crossover shifted
    by the one-off read: d* * s/(s+1) is conservative; for the io auto-pick
    (arrays loaded once, consumed many times) the plain crossover is used.
    """
    d_star = sparse_storage_crossover_density(e, idx_e)
    return density < d_star * streaming_ops / (streaming_ops + 1.0) \
        if streaming_ops < 4 else density < d_star


# ---------------------------------------------------------------------------
# Lazy-plan laws: what record→optimize→fuse buys over eager dispatch.
#
# An eager elementwise chain of L ops issues L dispatches, each reading and
# writing the full padded stacked tensor in HBM; the lazy plan fuses the
# chain into ONE per-block function inside one jit, so the tensor is read
# once and only the final result is written.  These laws quantify the three
# axes the optimizer reports: plan size (nodes), HBM traffic, and dispatch
# (launch) count.
# ---------------------------------------------------------------------------


def plan_nodes_after_fusion(n_elementwise: int, n_other: int = 0) -> int:
    """Non-leaf plan nodes after optimization: a run of ``n_elementwise``
    fusible Blockwise nodes collapses to 1; reductions/matmuls/structural
    nodes (``n_other``) survive as fusion barriers."""
    return (1 if n_elementwise else 0) + n_other


def lazy_chain_hbm_bytes(n_ops: int, n: int, m: int, e: int,
                         fused: bool = True) -> float:
    """HBM traffic of an ``n_ops`` elementwise chain over an (n, m) array,
    element size ``e``.  Eager: every op reads its input and writes its
    result — ``2·L`` passes.  Fused: one read of the operand + one write of
    the result, independent of chain length (intermediates live in
    registers/VMEM inside the single fused body)."""
    per_pass = float(n) * m * e
    if fused:
        return 2.0 * per_pass
    return 2.0 * n_ops * per_pass


def lazy_chain_hbm_saved(n_ops: int, n: int, m: int, e: int) -> float:
    """Bytes the fused plan deletes vs eager dispatch (the headline the
    ``bench_lazy`` speedup should track on memory-bound chains)."""
    return (lazy_chain_hbm_bytes(n_ops, n, m, e, fused=False)
            - lazy_chain_hbm_bytes(n_ops, n, m, e, fused=True))


def lazy_chain_launches(n_ops: int, fused: bool = True) -> int:
    """Dispatch law: the compiled plan is ONE launch however long the chain
    (and a cache hit skips re-tracing); eager pays one per op — the TPU
    analogue of the paper's per-task scheduler overhead (Figs. 6/8)."""
    return 1 if fused else n_ops


def merged_reduction_passes(n_reductions: int, merged: bool = True) -> int:
    """Sibling reductions over the same operand: the plan evaluates the
    shared operand (and any fused chain feeding it) once for all of them;
    eager evaluates it per reduction."""
    return 1 if merged else max(1, n_reductions)


# ---------------------------------------------------------------------------
# Estimator laws: CSVM cascade + random-forest histogram growth.
#
# The estimator layer (repro.estimators) expresses whole fit loops over the
# ds-array primitives above; these laws predict the per-iteration cost the
# benchmarks (benchmarks/bench_estimators.py) then measure.  The cascade's
# dominant op is the data-vs-model kernel matrix K(X, SV) — one sp@dense
# bcoo_dot_general for BCOO-blocked X, so its bytes follow the spmm laws —
# and the forest's is one histogram contraction per tree level.
# ---------------------------------------------------------------------------


def csvm_kernel_flops(n: int, m: int, n_sv: int) -> float:
    """MACs x2 of the cascade's global kernel block K(X, SV) = X @ SVᵀ
    (dense X); the RBF exponentiation adds O(n*n_sv), negligible."""
    return 2.0 * n * m * n_sv


def csvm_kernel_flops_sparse(nnz: int, n_sv: int) -> float:
    """Sparse X: each stored entry meets every SV column once —
    nnz-proportional, the reason the cascade was the sparse backend's
    target workload (paper §6)."""
    return spmm_flops(nnz, n_sv)


def csvm_kernel_hbm_bytes(n: int, m: int, n_sv: int, e: int,
                          nnz: int = 0, idx_e: int = 4) -> float:
    """HBM traffic of one K(X, SV) evaluation: stream the data matrix once
    (value+index stream for BCOO when ``nnz`` > 0, dense rows otherwise),
    the small SV panel once, write the (n, n_sv) kernel block."""
    data = bcoo_bytes(nnz, e, idx_e) if nnz else float(n) * m * e
    return data + float(n_sv) * m * e + float(n) * n_sv * e


def csvm_cascade_node_flops(s: int, m: int, solver_iters: int) -> float:
    """One cascade node: an (s, s) kernel build (2*s²*m) plus
    ``solver_iters`` dual projected-gradient steps (one (s, s) matvec
    each)."""
    return 2.0 * s * s * m + solver_iters * 2.0 * s * s


def csvm_cascade_fit_flops(n: int, m: int, arity: int, sv_cap: int,
                           solver_iters: int, chunks: int) -> float:
    """One cascade pass: ``chunks`` level-0 nodes of ~n/chunks (+fed-back
    SV) rows, then a merge tree of arity-way nodes over capped SV sets
    (node size ≤ arity * sv_cap, ~chunks/(arity-1) merge nodes)."""
    s0 = n // max(1, chunks) + sv_cap
    level0 = chunks * csvm_cascade_node_flops(s0, m, solver_iters)
    merge_nodes = max(0, (chunks - 1) // max(1, arity - 1))
    merges = merge_nodes * csvm_cascade_node_flops(arity * sv_cap, m,
                                                   solver_iters)
    return level0 + merges


def forest_histogram_passes(n_estimators: int, max_depth: int) -> int:
    """Histogram tree growth reads the binned code tensor once per level per
    forest (trees share the pass: the level contraction carries the tree dim)
    — vs one pass per (tree, level, node) for naive per-node partitioning."""
    del n_estimators
    return max_depth


def forest_level_flops(n: int, m: int, bins: int, classes: int,
                       nodes: int, trees: int) -> float:
    """One level's histogram contraction: every (sample, feature) pair
    scatters its bin count into (tree, node, class) cells — the einsum is
    n*m*bins*classes*trees MACs x2 bounded by the one-hot sparsity (each
    sample hits ONE node and ONE class, so the effective work is
    n*m*bins*trees*2)."""
    del classes, nodes
    return 2.0 * n * m * bins * trees


# ---------------------------------------------------------------------------
# Liveness laws: peak HBM of a plan under a static execution order.
#
# Dispatch on TPU is compiled away, but HBM is not: a plan's intermediates
# are live from the eqn that defines them to their last consumer, so the
# EXECUTION ORDER decides the peak resident bytes — dask computes exactly
# this in order.py for its scheduler, and the ROADMAP's multi-host item
# needs it to bound per-host block footprint.  ``repro.analysis.liveness``
# simulates both the naive emission order and a Sethi-Ullman-style
# minimizing order using these byte laws per node.
# ---------------------------------------------------------------------------


def node_live_bytes(shape4, e: int, nse: int = None, idx_e: int = 4) -> float:
    """Resident HBM bytes of one plan node's output: dense stacked tensor,
    or per-block BCOO entries (value + 2-D index) when ``nse`` is given."""
    gn, gm, bn, bm = shape4
    if nse is not None:
        return float(gn) * gm * bcoo_bytes(nse, e, idx_e)
    return dense_stacked_bytes(gn, gm, bn, bm, e)


#: reordering is worth surfacing when the naive order's peak is at least
#: this factor above the liveness-minimizing order's.
PEAK_REORDER_FACTOR = 2.0


def liveness_reorder_pays(naive_peak: float, ordered_peak: float,
                          factor: float = PEAK_REORDER_FACTOR) -> bool:
    """Does a liveness-minimizing topological order pay?  True when the
    naive child-first emission order holds ``factor``x (default 2x) the
    peak bytes of the reordered schedule."""
    if ordered_peak <= 0:
        return False
    return naive_peak >= factor * ordered_peak


#: tolerated measured/predicted byte ratio per plan node before the
#: ``costmodel-drift`` analysis rule fires.  The byte laws above are exact
#: for the two block representations (dense stacked tensor, stacked BCOO
#: values + 2-D int32 indices), so on main the measured footprint matches
#: the prediction bit for bit and any drift means a representation or law
#: changed without the other — the factor only absorbs backend-padded
#: layouts, not modeling error.
COSTMODEL_DRIFT_FACTOR = 1.25


def costmodel_drift_ok(predicted_bytes: float, measured_bytes: float,
                       factor: float = COSTMODEL_DRIFT_FACTOR) -> bool:
    """Is one node's measured output footprint within the cost model's
    tolerance?  Symmetric in direction: both a law UNDER-predicting (hides
    an OOM the liveness analysis would have caught) and OVER-predicting
    (peak-HBM lints fire spuriously) count as drift."""
    if predicted_bytes <= 0 or measured_bytes <= 0:
        return predicted_bytes == measured_bytes
    ratio = measured_bytes / predicted_bytes
    return (1.0 / factor) <= ratio <= factor


# ---------------------------------------------------------------------------
# Ingestion laws: peak HOST memory of the streaming loaders (paper §4.2.2).
#
# The paper's creation routines build a ds-array one block-row at a time so
# no process holds the full matrix; the streaming loaders in ``core.io``
# realize that bound and these laws predict it.  ``benchmarks/bench_io.py``
# measures both sides with tracemalloc and records the ratio, and the
# ``tests/test_io.py`` acceptance asserts the streamed peak stays under
# ``INGEST_PEAK_FACTOR`` block-rows.
# ---------------------------------------------------------------------------


#: streamed-load acceptance bound, in units of one block-row's bytes: the
#: block-row buffer + the transient host copy the device transfer makes +
#: one raw chunk and its parsed slab.
INGEST_PEAK_FACTOR = 3.0


def ingest_blockrow_bytes(gm: int, bn: int, bm: int, e: int) -> float:
    """Host bytes of one assembled dense block row (the streaming unit)."""
    return float(gm) * bn * bm * e


def ingest_txt_file_bytes(n: int, m: int, chars_per_value: int = 8) -> float:
    """On-disk bytes of an (n, m) delimited text file — each value costs
    its digits plus one separator, the text-inflation the one-shot parser
    must additionally hold as pages."""
    return float(n) * m * (chars_per_value + 1)


def ingest_peak_host_bytes(gn: int, gm: int, bn: int, bm: int, e: int,
                           chunk_bytes: int, streamed: bool = True) -> float:
    """Predicted peak host bytes of a text/npy load.  Streamed: one raw
    chunk + ~2 block-rows (the fill buffer and the transient copy made by
    the host->device transfer).  Materialized: the full parsed (n, m)
    array — ``gn`` block-rows — before blocking even starts."""
    row = ingest_blockrow_bytes(gm, bn, bm, e)
    if streamed:
        return float(chunk_bytes) + 2.0 * row
    return float(gn) * row


def ingest_peak_ratio(gn: int, gm: int, bn: int, bm: int, e: int,
                      chunk_bytes: int) -> float:
    """Materialized/streamed peak-host-memory ratio — the law the
    ``BENCH_io.json`` streamed-vs-materialized measurement should track;
    grows linearly with the number of block rows."""
    return (ingest_peak_host_bytes(gn, gm, bn, bm, e, chunk_bytes, False)
            / ingest_peak_host_bytes(gn, gm, bn, bm, e, chunk_bytes, True))
