"""Byte-range file chunking + per-format line parsers (paper §4.2.2).

The streaming loaders in :mod:`repro.core.io` never hold more than one
chunk of raw text plus one block-row of parsed values on the host.  The
primitive that makes this safe is the dask ``bytes/core.py`` idiom: a byte
range ``[offset, offset + length)`` is grown to line boundaries by seeking
to the first line *start* at or after each end.  Because a line starts at
byte 0 or immediately after a delimiter, successive ranges tile the file
into whole-line chunks with no gaps, overlaps, or split records — the same
property lets independent hosts each read only their own shard's ranges.

Parsers are per-format and chunk-local: they return NumPy arrays (text) or
COO triplets with chunk-local row ids (svmlight), never touching global
state, so the loaders own all assembly and the memory accounting.
"""

from __future__ import annotations

import io as _io
from typing import Iterator, Optional, Tuple

import numpy as np

#: Default raw-text chunk size for the streaming loaders.  Small enough that
#: chunk + parsed values stay well under one block-row of a realistic
#: geometry; callers with big block rows can raise it to amortize parse
#: overhead (each chunk is one ``np.loadtxt`` / one Python line loop).
DEFAULT_CHUNK_BYTES = 1 << 16


def next_line_start(f, pos: int, delimiter: bytes = b"\n",
                    blocksize: int = 1 << 16) -> int:
    """Offset of the first line START at or after ``pos``.

    ``pos == 0`` is always a line start.  Otherwise scan forward from
    ``pos - 1`` for a delimiter — if the byte just before ``pos`` is one,
    the line starting exactly at ``pos`` is found (this is what makes the
    tiling gap-free).  Returns EOF when no further line starts.
    """
    if pos <= 0:
        return 0
    f.seek(pos - 1)
    while True:
        buf = f.read(blocksize)
        if not buf:
            return f.tell()
        i = buf.find(delimiter)
        if i >= 0:
            return f.tell() - len(buf) + i + len(delimiter)


def read_block(f, offset: int, length: int,
               delimiter: bytes = b"\n") -> bytes:
    """Bytes of every line that STARTS in ``[offset, offset + length)``.

    Both ends are advanced to the next line start (dask ``read_block``),
    so the returned bytes are whole lines; the final block of a file with
    no trailing newline runs to EOF.  Empty when no line starts in range.
    """
    start = next_line_start(f, offset, delimiter)
    end = next_line_start(f, offset + length, delimiter)
    if end <= start:
        return b""
    f.seek(start)
    return f.read(end - start)


def iter_line_chunks(path: str, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                     delimiter: bytes = b"\n") -> Iterator[bytes]:
    """Successive whole-line chunks of ``path``, each ~``chunk_bytes`` long
    (plus at most one line).  Union of chunks == file, each line exactly
    once — the sequential view of the per-host byte-range read."""
    chunk_bytes = max(1, int(chunk_bytes))
    with open(path, "rb") as f:
        f.seek(0, _io.SEEK_END)
        size = f.tell()
        for off in range(0, size, chunk_bytes):
            chunk = read_block(f, off, chunk_bytes, delimiter)
            if chunk:
                yield chunk


def parse_txt_chunk(chunk: bytes, delimiter: str = ",",
                    dtype=np.float32) -> Optional[np.ndarray]:
    """Whole-line text chunk -> ``(k, m)`` array (None if only blank lines).

    CRLF endings are normalized before the parse; blank lines (including an
    empty trailing line) contribute no rows.
    """
    if b"\r" in chunk:                      # only CRLF files pay the copy
        chunk = chunk.replace(b"\r\n", b"\n")
    if not chunk.strip():
        return None
    arr = np.loadtxt(_io.BytesIO(chunk), delimiter=delimiter, dtype=dtype,
                     ndmin=2)
    return arr if arr.size else None


def parse_svmlight_chunk(chunk: bytes, dtype=np.float32,
                         zero_based: bool = False,
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]:
    """Whole-line svmlight chunk -> ``(labels, rows, cols, vals)``.

    ``rows`` are chunk-local (0..k-1, one id per non-blank line, sorted
    non-decreasing), ``cols`` are global feature ids already shifted to
    0-based when ``zero_based=False`` (the svmlight convention: features
    count from 1).  Per-line ``#`` comments and ``qid:`` fields are
    dropped.  Memory stays compact: Python token lists live one line at a
    time; per-line triplets accumulate as small NumPy arrays.
    """
    labels = []
    row_parts, col_parts, val_parts = [], [], []
    shift = 0 if zero_based else 1
    if b"\r" in chunk:                      # only CRLF files pay the copy
        chunk = chunk.replace(b"\r\n", b"\n")
    for ln in chunk.split(b"\n"):
        hash_pos = ln.find(b"#")
        if hash_pos >= 0:
            ln = ln[:hash_pos]
        toks = ln.split()
        if not toks:
            continue
        r = len(labels)
        labels.append(float(toks[0]))
        cols, vals = [], []
        for t in toks[1:]:
            k, _, v = t.partition(b":")
            if k == b"qid":
                continue
            c = int(k) - shift
            if c < 0:
                raise ValueError(
                    f"svmlight feature id {int(k)} underflows with "
                    f"zero_based={zero_based} (1-based files count from 1; "
                    f"pass zero_based=True for 0-based files)")
            cols.append(c)
            vals.append(float(v))
        if cols:
            row_parts.append(np.full(len(cols), r, dtype=np.int32))
            col_parts.append(np.asarray(cols, dtype=np.int32))
            val_parts.append(np.asarray(vals, dtype=dtype))
    if row_parts:
        rows = np.concatenate(row_parts)
        cols = np.concatenate(col_parts)
        vals = np.concatenate(val_parts)
    else:
        rows = np.empty(0, np.int32)
        cols = np.empty(0, np.int32)
        vals = np.empty(0, dtype)
    return (np.asarray(labels, dtype=dtype), rows, cols, vals)
