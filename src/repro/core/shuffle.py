"""Row shuffles for ds-arrays (paper §5.4).

The paper's pseudo-shuffle splits every partition into random parts and
re-merges one part from each into new partitions; with COLLECTION multi-I/O
tasks it costs 2N tasks vs N·min(N,S)+N for Datasets.  On TPU the analogue is:

* ``pseudo_shuffle``   — two stages: (1) permute block-rows (grid metadata →
  a collective-permute when sharded), (2) an independent row permutation
  inside every block-row (local).  Exactly the paper's 2-stage structure,
  one all_to_all + one local op.
* ``exact_shuffle``    — a single global row permutation (gather), for when
  callers need a uniform shuffle; costs a full all-to-all like the paper's
  "extremely costly" exact shuffle.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dsarray import DsArray, _lazy_mode, from_array


def _maybe_record(key, a, kind: str):
    """Record a Shuffle node when the operand is lazy / recording is armed."""
    from repro.core import expr
    if isinstance(a, expr.LazyDsArray) or _lazy_mode():
        return expr.record_shuffle(key, a, kind)
    return None


def pseudo_shuffle(key, a: DsArray) -> DsArray:
    """Paper's 2-stage pseudo shuffle: permute block-rows, then rows within
    each block-row.  Not a uniform permutation, but 'sufficient for most use
    cases' (paper §5.4); every row keeps exactly one copy."""
    rec = _maybe_record(key, a, "pseudo")
    if rec is not None:
        return rec
    if getattr(a, "is_sparse", False):
        a = a.todense()     # shuffles are per-position movement: densify
    if a.shape[0] != a.grid.padded_shape[0]:
        # rows must tile evenly for the in-block stage to be a permutation
        return exact_shuffle(key, a)
    k1, k2 = jax.random.split(key)
    gn = a.stacked_grid[0]
    # stage 1: one "task" moving whole block-rows (a ppermute when sharded)
    perm = jax.random.permutation(k1, gn)
    blocks = a.blocks[perm]
    # stage 2: one task per block-row permuting rows locally across its blocks
    bn = a.block_shape[0]
    row_perms = jax.vmap(lambda k: jax.random.permutation(k, bn))(
        jax.random.split(k2, gn))
    blocks = jax.vmap(lambda b, p: b[:, p, :])(blocks, row_perms)
    # both stages permute pad columns among themselves (rows tile evenly
    # here), so the operand's pad state carries over untouched
    return DsArray(blocks, a.grid, a.pad_state)


def exact_shuffle(key, a: DsArray) -> DsArray:
    """Uniform random permutation of rows, block-native.

    One per-block row gather (the same ``lax.gather`` path behind
    ``A[idx]``/unaligned slicing, see ``structural.take_rows``) applied to a
    uniform permutation — still the paper's "extremely costly" full
    all-to-all in bytes, but no ``collect()``: the seed path materialized
    the global ``(n, m)`` array on one host and re-blocked it (the exact
    O(n·m)-materialize anti-pattern PR 1 removed from ``__getitem__``),
    destroying sharding.  Here every intermediate keeps block layout,
    sharding is re-placed on the result, and the output pad is ZERO.
    """
    rec = _maybe_record(key, a, "exact")
    if rec is not None:
        return rec
    from repro.core.structural import take_rows
    perm = jax.random.permutation(key, a.shape[0])
    return take_rows(a, perm, out_bn=a.block_shape[0])
