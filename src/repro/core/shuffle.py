"""Row shuffles for ds-arrays (paper §5.4).

The paper's pseudo-shuffle splits every partition into random parts and
re-merges one part from each into new partitions; with COLLECTION multi-I/O
tasks it costs 2N tasks vs N·min(N,S)+N for Datasets.  On TPU the analogue is:

* ``pseudo_shuffle``   — two stages: (1) permute block-rows (grid metadata →
  a collective-permute when sharded), (2) an independent row permutation
  inside every block-row (local).  Exactly the paper's 2-stage structure,
  one all_to_all + one local op.
* ``exact_shuffle``    — a single global row permutation (gather), for when
  callers need a uniform shuffle; costs a full all-to-all like the paper's
  "extremely costly" exact shuffle.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.dsarray import DsArray, from_array


def pseudo_shuffle(key, a: DsArray) -> DsArray:
    """Paper's 2-stage pseudo shuffle: permute block-rows, then rows within
    each block-row.  Not a uniform permutation, but 'sufficient for most use
    cases' (paper §5.4); every row keeps exactly one copy."""
    if a.shape[0] != a.grid.padded_shape[0]:
        # rows must tile evenly for the in-block stage to be a permutation
        return exact_shuffle(key, a)
    k1, k2 = jax.random.split(key)
    gn = a.stacked_grid[0]
    # stage 1: one "task" moving whole block-rows (a ppermute when sharded)
    perm = jax.random.permutation(k1, gn)
    blocks = a.blocks[perm]
    # stage 2: one task per block-row permuting rows locally across its blocks
    bn = a.block_shape[0]
    row_perms = jax.vmap(lambda k: jax.random.permutation(k, bn))(
        jax.random.split(k2, gn))
    blocks = jax.vmap(lambda b, p: b[:, p, :])(blocks, row_perms)
    # both stages permute pad columns among themselves (rows tile evenly
    # here), so the operand's pad state carries over untouched
    return DsArray(blocks, a.grid, a.pad_state)


def exact_shuffle(key, a: DsArray) -> DsArray:
    """Uniform random permutation of rows (global gather)."""
    g = a.collect()
    perm = jax.random.permutation(key, a.shape[0])
    return from_array(jnp.take(g, perm, axis=0), a.block_shape)
