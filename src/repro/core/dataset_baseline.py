"""The paper's baseline: dislib's row-partitioned Dataset/Subset structure.

Implemented with the *same task structure* the paper describes so that the
benchmarks reproduce the paper's complexity separation:

* a Dataset is a list of Subsets; each Subset holds a block of samples
  (rows) and a block of labels,
* ``transpose`` splits every Subset into N parts and merges them
  (N^2 + N tasks, paper §5.2),
* ``shuffle`` splits every Subset into min(N, S) random parts and merges
  (N·min(N,S) + N tasks, paper §5.4),
* row-wise ops are one task per Subset; column-wise ops require a gather
  (paper Fig. 3).

Tasks here execute eagerly as NumPy calls (we count them); on PyCOMPSs each
would be a scheduled remote task — the benchmark couples these counts with
``core.costmodel.pycompss_time`` to model cluster behaviour, and measures the
wall-clock of the real NumPy execution at container scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np


class TaskCounter:
    """Counts 'tasks' (units PyCOMPSs would schedule) and bytes moved."""

    def __init__(self):
        self.tasks = 0
        self.bytes_moved = 0

    def task(self, *arrays: np.ndarray, moved: Optional[int] = None) -> None:
        self.tasks += 1
        if moved is not None:
            self.bytes_moved += moved
        else:
            self.bytes_moved += sum(int(a.nbytes) for a in arrays)


@dataclasses.dataclass
class Subset:
    samples: np.ndarray            # (s, m)
    labels: Optional[np.ndarray]   # (s,) or None


class Dataset:
    """Row-partitioned collection of (samples, labels) Subsets."""

    def __init__(self, subsets: List[Subset], counter: Optional[TaskCounter] = None):
        self.subsets = subsets
        self.counter = counter or TaskCounter()

    # -- construction -------------------------------------------------------
    @classmethod
    def from_array(cls, samples: np.ndarray, n_subsets: int,
                   labels: Optional[np.ndarray] = None,
                   counter: Optional[TaskCounter] = None) -> "Dataset":
        rows = np.array_split(samples, n_subsets, axis=0)
        labs = (np.array_split(labels, n_subsets) if labels is not None
                else [None] * n_subsets)
        c = counter or TaskCounter()
        subsets = []
        for r, l in zip(rows, labs):
            c.task(r)  # one load task per Subset (paper §3.2.1)
            subsets.append(Subset(np.asarray(r), None if l is None else np.asarray(l)))
        return cls(subsets, c)

    @property
    def n_subsets(self) -> int:
        return len(self.subsets)

    def collect(self) -> np.ndarray:
        return np.concatenate([s.samples for s in self.subsets], axis=0)

    # -- paper §5.2: N^2 + N task transpose ---------------------------------
    def transpose(self) -> "Dataset":
        n = self.n_subsets
        # N^2 split tasks: each Subset is divided column-wise into N parts
        parts: List[List[np.ndarray]] = []
        for s in self.subsets:
            cols = np.array_split(s.samples, n, axis=1)
            row_parts = []
            for cpart in cols:
                self.counter.task(cpart)
                row_parts.append(cpart.T.copy())
            parts.append(row_parts)
        # N merge tasks: new Subset j concatenates part j of every old Subset
        new_subsets = []
        for j in range(n):
            pieces = [parts[i][j] for i in range(len(parts))]
            self.counter.task(*pieces)
            new_subsets.append(Subset(np.concatenate(pieces, axis=1), None))
        return Dataset(new_subsets, self.counter)

    # -- paper §5.4: N*min(N,S)+N task pseudo-shuffle ------------------------
    def shuffle(self, rng: np.random.Generator) -> "Dataset":
        n = self.n_subsets
        buckets: List[List[np.ndarray]] = [[] for _ in range(n)]
        lab_buckets: List[List[np.ndarray]] = [[] for _ in range(n)]
        for s in self.subsets:
            size = s.samples.shape[0]
            k = min(n, size)
            perm = rng.permutation(size)
            split_points = np.array_split(perm, k)
            targets = rng.choice(n, size=k, replace=False)
            for part_idx, idx in enumerate(split_points):
                piece = s.samples[idx]
                self.counter.task(piece)  # one split task per part
                buckets[targets[part_idx] % n].append(piece)
                if s.labels is not None:
                    lab_buckets[targets[part_idx] % n].append(s.labels[idx])
        new_subsets = []
        for j in range(n):
            pieces = buckets[j] or [np.zeros((0, self.subsets[0].samples.shape[1]),
                                             dtype=self.subsets[0].samples.dtype)]
            self.counter.task(*pieces)  # one merge task per new Subset
            labs = np.concatenate(lab_buckets[j]) if lab_buckets[j] else None
            new_subsets.append(Subset(np.concatenate(pieces, axis=0), labs))
        return Dataset(new_subsets, self.counter)

    # -- row-parallel map + reduction (paper Fig. 3) -------------------------
    def map_subsets(self, fn: Callable[[np.ndarray], np.ndarray]) -> List[np.ndarray]:
        out = []
        for s in self.subsets:
            self.counter.task(s.samples)
            out.append(fn(s.samples))
        return out

    def reduce(self, partials: List[np.ndarray],
               op: Callable[[np.ndarray, np.ndarray], np.ndarray]) -> np.ndarray:
        """Binary reduction tree: N-1 tasks (paper Fig. 3 right)."""
        level = list(partials)
        while len(level) > 1:
            nxt = []
            for i in range(0, len(level) - 1, 2):
                self.counter.task(level[i], level[i + 1])
                nxt.append(op(level[i], level[i + 1]))
            if len(level) % 2:
                nxt.append(level[-1])
            level = nxt
        return level[0]

    def sum_rows(self) -> np.ndarray:
        """Column-wise total (paper Fig. 3: map + reduction tree)."""
        partials = self.map_subsets(lambda x: x.sum(axis=0, keepdims=True))
        return self.reduce(partials, np.add)
