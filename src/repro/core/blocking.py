"""Block-grid geometry for ds-arrays.

The paper's ds-array is a 2-D array divided into blocks of an arbitrary,
user-chosen size ``(bn, bm)``; blocks are the unit of distribution and of
parallel work.  This module holds the pure geometry: grid shape, padded
extents, per-block logical extents, and divisibility padding needed to lay a
block grid onto a device mesh.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return ceil_div(a, b) * b


@dataclasses.dataclass(frozen=True)
class BlockGrid:
    """Geometry of a 2-D array of shape ``shape`` cut into ``block_shape`` tiles.

    Edge blocks may be logically smaller (the paper: "rightmost blocks and the
    blocks at the bottom can be smaller"); physically every block is stored at
    full ``block_shape`` with a zero pad, and masks recover logical extents.
    """

    shape: Tuple[int, int]
    block_shape: Tuple[int, int]

    def __post_init__(self):
        n, m = self.shape
        bn, bm = self.block_shape
        if n < 0 or m < 0:
            raise ValueError(f"negative array shape {self.shape}")
        if bn <= 0 or bm <= 0:
            raise ValueError(f"non-positive block shape {self.block_shape}")

    # -- grid extents -------------------------------------------------------
    @property
    def grid(self) -> Tuple[int, int]:
        n, m = self.shape
        bn, bm = self.block_shape
        return (max(1, ceil_div(n, bn)), max(1, ceil_div(m, bm)))

    @property
    def n_blocks(self) -> int:
        gn, gm = self.grid
        return gn * gm

    @property
    def padded_shape(self) -> Tuple[int, int]:
        gn, gm = self.grid
        bn, bm = self.block_shape
        return (gn * bn, gm * bm)

    @property
    def stacked_shape(self) -> Tuple[int, int, int, int]:
        """Shape of the stacked block tensor (gn, gm, bn, bm)."""
        gn, gm = self.grid
        bn, bm = self.block_shape
        return (gn, gm, bn, bm)

    # -- per-block logical extents ------------------------------------------
    def block_extent(self, i: int, j: int) -> Tuple[int, int]:
        """Logical (rows, cols) stored in block (i, j)."""
        n, m = self.shape
        bn, bm = self.block_shape
        rows = min(bn, n - i * bn)
        cols = min(bm, m - j * bm)
        return (max(0, rows), max(0, cols))

    def block_slices(self, i: int, j: int) -> Tuple[slice, slice]:
        n, m = self.shape
        bn, bm = self.block_shape
        return (
            slice(i * bn, min(n, (i + 1) * bn)),
            slice(j * bm, min(m, (j + 1) * bm)),
        )

    # -- mesh layout ----------------------------------------------------------
    def mesh_padded_grid(self, mesh_shape: Tuple[int, int]) -> Tuple[int, int]:
        """Grid extents rounded up to multiples of the mesh axes, so each
        device owns the same number of whole blocks (the SPMD analogue of the
        PyCOMPSs scheduler assigning blocks to workers)."""
        gn, gm = self.grid
        dn, dm = mesh_shape
        return (round_up(gn, dn), round_up(gm, dm))

    def transpose(self) -> "BlockGrid":
        return BlockGrid(self.shape[::-1], self.block_shape[::-1])

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"BlockGrid(shape={self.shape}, block={self.block_shape}, "
            f"grid={self.grid})"
        )


# ---------------------------------------------------------------------------
# Slice / regroup geometry (pure integer math used by core.structural).
# ---------------------------------------------------------------------------


def is_aligned_slice(s: slice, size: int, block: int) -> bool:
    """True iff ``s`` selects a contiguous range starting on a block boundary
    with unit step — the case a slice is a pure block-grid slice + edge remask."""
    start, stop, step = s.indices(size)
    return step == 1 and start % block == 0 and stop >= start


def grid_span(start: int, stop: int, block: int) -> Tuple[int, int]:
    """Half-open range of grid indices whose blocks cover rows [start, stop)."""
    if stop <= start:
        return (start // block, start // block + 1)  # empty -> keep one block
    return (start // block, ceil_div(stop, block))


def can_regroup(old: Tuple[int, int], new: Tuple[int, int]) -> bool:
    """True iff block shape ``old`` reaches ``new`` by a pure regroup reshape
    (per axis, one size evenly divides the other — split or merge); otherwise
    a gather-based repack is required."""
    return all(o % n == 0 or n % o == 0 for o, n in zip(old, new))


def compatible_for_elementwise(a: BlockGrid, b: BlockGrid) -> bool:
    return a.shape == b.shape and a.block_shape == b.block_shape


def compatible_for_matmul(a: BlockGrid, b: BlockGrid) -> bool:
    return a.shape[1] == b.shape[0] and a.block_shape[1] == b.block_shape[0]
