"""Parallel-creation / IO routines for ds-arrays (paper §4.2.2).

On PyCOMPSs these spawn one load task per block-row (files are parsed line by
line); in SPMD the analogue is each host reading only the row-range of the
file its shard needs.  ``load_npy_rows`` uses a memory-map so only touched
pages are read — the same "never materialize centrally" property.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Optional, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.dsarray import DsArray, from_array


def _fire(site: str, **info) -> None:
    """Fault-injection hook (``repro.resilience.inject``): loaders raise an
    injected ``IOLoadError`` before touching the file, so I/O-failure
    handling is provable without unreadable fixtures on disk."""
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


def from_array_auto(arr, block_shape: Tuple[int, int],
                    block_format: str = "auto",
                    density_threshold: Optional[float] = None) -> DsArray:
    """Block a local array, picking dense vs bcoo storage by density.

    ``block_format``: ``"dense"`` | ``"bcoo"`` | ``"auto"``.  Auto measures
    nnz/size and converts when it is below ``density_threshold`` — default
    the costmodel storage-crossover density (entries below it make the BCOO
    value+index stream smaller than the dense tensor, so every streaming
    op moves fewer bytes).  This is the paper's "sparse datasets load into
    CSR-blocked ds-arrays" decision, made by a cost law instead of a flag.
    """
    if block_format not in ("auto", "dense", "bcoo"):
        raise ValueError(f"unknown block_format {block_format!r}")
    a = from_array(np.asarray(arr), block_shape)
    if block_format == "dense":
        return a
    if block_format == "bcoo":
        return a.tosparse()
    arr = np.asarray(arr)
    thr = density_threshold if density_threshold is not None else \
        costmodel.sparse_storage_crossover_density(arr.dtype.itemsize)
    nnz = int(np.count_nonzero(arr))
    density = nnz / max(1, arr.size)
    return a.tosparse() if density < thr else a


def load_txt(path: str, block_shape: Tuple[int, int], delimiter: str = ",",
             dtype=np.float32, block_format: str = "dense") -> DsArray:
    """Load a delimited text file into a ds-array (one parse per block-row)."""
    _fire("io_load", source="load_txt", path=path)
    data = np.loadtxt(path, delimiter=delimiter, dtype=dtype, ndmin=2)
    return from_array_auto(data, block_shape, block_format)


def load_npy_rows(path: str, block_shape: Tuple[int, int],
                  row_range: Optional[Tuple[int, int]] = None,
                  block_format: str = "dense") -> DsArray:
    """Memory-mapped .npy load; reads only the requested row range."""
    _fire("io_load", source="load_npy_rows", path=path)
    mm = np.load(path, mmap_mode="r")
    if row_range is not None:
        mm = mm[row_range[0]: row_range[1]]
    return from_array_auto(np.asarray(mm), block_shape, block_format)


def load_npz_sparse(path: str, block_shape: Tuple[int, int]) -> DsArray:
    """scipy.sparse ``.npz`` file -> BCOO-blocked ds-array, never densifying
    (the paper's CSVM datasets ship in exactly this form)."""
    _fire("io_load", source="load_npz_sparse", path=path)
    import scipy.sparse as ssp
    from repro.core import sparse as sparse_mod
    return sparse_mod.from_scipy(ssp.load_npz(path), block_shape)


def save_npy(path: str, a: DsArray) -> None:
    np.save(path, np.asarray(a.collect()))


def save_blocks(dirpath: str, a: DsArray) -> None:
    """One file per block-row (what each PyCOMPSs worker / TPU host writes)."""
    os.makedirs(dirpath, exist_ok=True)
    blocks = np.asarray(a.ensure_zero_pad().blocks)   # canonical on-disk form
    meta = {"shape": list(a.shape), "block_shape": list(a.block_shape),
            "stacked_grid": list(a.stacked_grid), "dtype": str(blocks.dtype)}
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)
    for i in range(blocks.shape[0]):
        np.save(os.path.join(dirpath, f"blockrow_{i:05d}.npy"), blocks[i])


def load_blocks(dirpath: str) -> DsArray:
    _fire("io_load", source="load_blocks", path=dirpath)
    from repro.core.blocking import BlockGrid
    import jax.numpy as jnp

    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    gn = meta["stacked_grid"][0]
    rows = [np.load(os.path.join(dirpath, f"blockrow_{i:05d}.npy"))
            for i in range(gn)]
    blocks = jnp.asarray(np.stack(rows, axis=0))
    grid = BlockGrid(tuple(meta["shape"]), tuple(meta["block_shape"]))
    return DsArray(blocks, grid)
