"""Parallel-creation / IO routines for ds-arrays (paper §4.2.2).

On PyCOMPSs these spawn one load task per block-row (files are parsed line by
line); in SPMD the analogue is each host reading only the row-range of the
file its shard needs.  ``load_npy_rows`` uses a memory-map so only touched
pages are read — the same "never materialize centrally" property.
"""

from __future__ import annotations

import json
import os
from typing import Optional, Tuple

import numpy as np

from repro.core.dsarray import DsArray, from_array


def load_txt(path: str, block_shape: Tuple[int, int], delimiter: str = ",",
             dtype=np.float32) -> DsArray:
    """Load a delimited text file into a ds-array (one parse per block-row)."""
    data = np.loadtxt(path, delimiter=delimiter, dtype=dtype, ndmin=2)
    return from_array(data, block_shape)


def load_npy_rows(path: str, block_shape: Tuple[int, int],
                  row_range: Optional[Tuple[int, int]] = None) -> DsArray:
    """Memory-mapped .npy load; reads only the requested row range."""
    mm = np.load(path, mmap_mode="r")
    if row_range is not None:
        mm = mm[row_range[0]: row_range[1]]
    return from_array(np.asarray(mm), block_shape)


def save_npy(path: str, a: DsArray) -> None:
    np.save(path, np.asarray(a.collect()))


def save_blocks(dirpath: str, a: DsArray) -> None:
    """One file per block-row (what each PyCOMPSs worker / TPU host writes)."""
    os.makedirs(dirpath, exist_ok=True)
    blocks = np.asarray(a.ensure_zero_pad().blocks)   # canonical on-disk form
    meta = {"shape": list(a.shape), "block_shape": list(a.block_shape),
            "stacked_grid": list(a.stacked_grid), "dtype": str(blocks.dtype)}
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)
    for i in range(blocks.shape[0]):
        np.save(os.path.join(dirpath, f"blockrow_{i:05d}.npy"), blocks[i])


def load_blocks(dirpath: str) -> DsArray:
    from repro.core.blocking import BlockGrid
    import jax.numpy as jnp

    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    gn = meta["stacked_grid"][0]
    rows = [np.load(os.path.join(dirpath, f"blockrow_{i:05d}.npy"))
            for i in range(gn)]
    blocks = jnp.asarray(np.stack(rows, axis=0))
    grid = BlockGrid(tuple(meta["shape"]), tuple(meta["block_shape"]))
    return DsArray(blocks, grid)
