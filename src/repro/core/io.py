"""Parallel-creation / IO routines for ds-arrays (paper §4.2.2).

On PyCOMPSs these spawn one load task per block-row (files are parsed line
by line); in SPMD the analogue is each host reading only the row-range of
the file its shard needs.  The streaming loaders (``load_txt_file``,
``load_svmlight_file``) realize the paper's "no process ever holds the full
matrix" claim literally: the file is read in line-aligned byte ranges
(:mod:`repro.core.readers`), each range parses into at most one block row,
and every completed block row moves to the device arena before the next is
touched — peak HOST memory is O(block-row), not O(n·m), asserted with
tracemalloc in ``tests/test_io.py``.  ``load_npy_rows`` streams block rows
off a memory-map the same way, so only touched pages are read.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Optional, Tuple

import numpy as np

from repro.core import costmodel, readers
from repro.core.blocking import ceil_div
from repro.core.dsarray import DsArray, from_array
from repro.obs import tracing as _tracing


def _fire(site: str, **info) -> None:
    """Fault-injection hook (``repro.resilience.inject``): loaders raise an
    injected ``IOLoadError`` before touching the file — and the streaming
    loaders fire once per chunk (``block_row=<i>`` in the info), so
    mid-stream I/O failure handling is provable without unreadable
    fixtures on disk.  Loaders keep all assembly state in locals, so an
    abort mid-stream leaves no partial state behind."""
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


def from_array_auto(arr, block_shape: Tuple[int, int],
                    block_format: str = "auto",
                    density_threshold: Optional[float] = None) -> DsArray:
    """Block a local array, picking dense vs bcoo storage by density.

    ``block_format``: ``"dense"`` | ``"bcoo"`` | ``"auto"``.  Auto measures
    nnz/size and converts when it is below ``density_threshold`` — default
    the costmodel storage-crossover density (entries below it make the BCOO
    value+index stream smaller than the dense tensor, so every streaming
    op moves fewer bytes).  This is the paper's "sparse datasets load into
    CSR-blocked ds-arrays" decision, made by a cost law instead of a flag.
    Only ``"auto"`` pays the density scan — ``"dense"``/``"bcoo"`` never
    touch the input beyond the blocking copy.
    """
    if block_format not in ("auto", "dense", "bcoo"):
        raise ValueError(f"unknown block_format {block_format!r}")
    a = from_array(np.asarray(arr), block_shape)
    if block_format == "dense":
        return a
    if block_format == "bcoo":
        return a.tosparse()
    arr = np.asarray(arr)
    thr = density_threshold if density_threshold is not None else \
        costmodel.sparse_storage_crossover_density(arr.dtype.itemsize)
    nnz = int(np.count_nonzero(arr))
    density = nnz / max(1, arr.size)
    return a.tosparse() if density < thr else a


# ---------------------------------------------------------------------------
# Streaming block-row assembly
# ---------------------------------------------------------------------------


def _blockrow_to_device(buf: np.ndarray, gm: int, bm: int):
    """(bn, gm*bm) host block-row buffer -> (gm, bn, bm) device array."""
    import jax.numpy as jnp
    bn = buf.shape[0]
    return jnp.asarray(buf.reshape(bn, gm, bm).transpose(1, 0, 2))


def _stack_blockrows(blockrows, n: int, m: int,
                     block_shape: Tuple[int, int]) -> DsArray:
    """Stack streamed (gm, bn, bm) device block rows into a ds-array."""
    import jax.numpy as jnp
    from repro.core.blocking import BlockGrid
    return DsArray(jnp.stack(blockrows, axis=0),
                   BlockGrid((n, m), tuple(block_shape)))


def load_txt_file(path: str, block_shape: Tuple[int, int],
                  delimiter: str = ",", dtype=np.float32,
                  n_features: Optional[int] = None,
                  chunk_bytes: int = readers.DEFAULT_CHUNK_BYTES) -> DsArray:
    """Streaming delimited-text loader (dislib ``load_txt_file`` surface).

    The file is consumed in line-aligned byte ranges; each parses into a
    ``(k, m)`` slab that fills the current ``(bn, gm*bm)`` block-row
    buffer.  A full buffer converts to one device block row and a fresh
    zero buffer takes its place, so the final partial block row is
    zero-padded by construction (``pad_state`` stays PAD_ZERO).  Peak host
    memory: one chunk + one parsed slab + ~2 block-row buffers (the device
    copy is transient) — never the n×m matrix.  Bitwise-equal to
    ``from_array(np.loadtxt(path), block_shape)``.
    """
    _fire("io_load", source="load_txt_file", path=path)
    with _tracing.span("ingest.load", source="load_txt_file", path=path):
        bn, bm = int(block_shape[0]), int(block_shape[1])
        m = None if n_features is None else int(n_features)
        gm = buf = None
        fill = n = 0
        blockrows = []
        for chunk in readers.iter_line_chunks(path, chunk_bytes):
            _fire("io_load", source="load_txt_file", path=path,
                  block_row=len(blockrows))
            with _tracing.span("ingest.chunk", source="load_txt_file",
                               block_row=len(blockrows),
                               chunk_bytes=len(chunk)):
                arr = readers.parse_txt_chunk(chunk, delimiter, dtype)
                if arr is None:
                    continue
                if m is None:
                    m = arr.shape[1]
                if buf is None:
                    gm = max(1, ceil_div(m, bm))
                    buf = np.zeros((bn, gm * bm), dtype)
                if arr.shape[1] != m:
                    raise ValueError(
                        f"{path}: ragged row width {arr.shape[1]} "
                        f"(expected {m})")
                done = 0
                while done < arr.shape[0]:
                    take = min(bn - fill, arr.shape[0] - done)
                    buf[fill:fill + take, :m] = arr[done:done + take]
                    fill += take
                    done += take
                    n += take
                    if fill == bn:
                        blockrows.append(_blockrow_to_device(buf, gm, bm))
                        buf = np.zeros((bn, gm * bm), dtype)
                        fill = 0
        if fill:
            blockrows.append(_blockrow_to_device(buf, gm, bm))
        if not blockrows:
            raise ValueError(f"{path}: no data rows")
        return _stack_blockrows(blockrows, n, m, (bn, bm))


def load_svmlight_file(path: str, block_shape: Tuple[int, int],
                       n_features: int, store_sparse: bool = True,
                       dtype=np.float32, zero_based: bool = False,
                       nse: Optional[int] = None,
                       chunk_bytes: int = readers.DEFAULT_CHUNK_BYTES,
                       ) -> Tuple[DsArray, DsArray]:
    """Streaming svmlight/libsvm loader -> ``(x, y)`` (dislib surface).

    Each line-aligned chunk parses into COO triplets with chunk-local row
    ids; triplets route into the current block row and every completed
    block row is packed immediately — sparse rows through
    :class:`repro.core.sparse.StackedBCOOBuilder` (one stacked BCOO at a
    shared ``nse``, never densified), dense rows through a scatter into a
    ``(bn, gm*bm)`` buffer.  Labels assemble the same way into an (n, 1)
    dense ds-array with block shape ``(bn, 1)``.  Feature ids are 1-based
    unless ``zero_based=True`` (the sklearn convention); an id outside
    ``[0, n_features)`` after the shift raises, which catches a 0/1-based
    mismatch instead of mispacking.  Peak host memory is O(block-row);
    the sparse result is bitwise-equal to ``from_scipy`` of the same
    triplets (same default nse = max block nnz).
    """
    _fire("io_load", source="load_svmlight_file", path=path)
    from repro.core import sparse as sparse_mod
    bn, bm = int(block_shape[0]), int(block_shape[1])
    n_features = int(n_features)
    gm = max(1, ceil_div(n_features, bm))
    builder = sparse_mod.StackedBCOOBuilder(
        n_features, (bn, bm), dtype, nse) if store_sparse else None
    xbuf = None if store_sparse else np.zeros((bn, gm * bm), dtype)
    pend = ([], [], [])                      # sparse: per-segment triplets
    ybuf = np.zeros((bn, 1), dtype)
    x_blockrows, y_blockrows = [], []
    fill = n = 0

    def _flush(k: int) -> None:
        nonlocal xbuf, ybuf, pend
        if store_sparse:
            parts = [np.concatenate(p) if p else np.empty(0, np.int64)
                     for p in pend[:2]]
            vparts = np.concatenate(pend[2]) if pend[2] else \
                np.empty(0, dtype)
            builder.append_blockrow(parts[0], parts[1], vparts, k)
            pend = ([], [], [])
        else:
            x_blockrows.append(_blockrow_to_device(xbuf, gm, bm))
            xbuf = np.zeros((bn, gm * bm), dtype)
        y_blockrows.append(_blockrow_to_device(ybuf, 1, 1))
        ybuf = np.zeros((bn, 1), dtype)

    with _tracing.span("ingest.load", source="load_svmlight_file",
                       path=path, sparse=store_sparse):
        for chunk in readers.iter_line_chunks(path, chunk_bytes):
            _fire("io_load", source="load_svmlight_file", path=path,
                  block_row=n // bn)
            with _tracing.span("ingest.chunk", source="load_svmlight_file",
                               block_row=n // bn, chunk_bytes=len(chunk)):
                labels, rows, cols, vals = readers.parse_svmlight_chunk(
                    chunk, dtype, zero_based)
                if cols.size and int(cols.max()) >= n_features:
                    raise ValueError(
                        f"{path}: feature id {int(cols.max())} out of range "
                        f"for n_features={n_features} with "
                        f"zero_based={zero_based} (a 0-based file read as "
                        f"1-based shifts ids past the end)")
                k = len(labels)
                done = 0
                while done < k:
                    take = min(bn - fill, k - done)
                    lo = np.searchsorted(rows, done)
                    hi = np.searchsorted(rows, done + take)
                    if store_sparse:
                        pend[0].append(rows[lo:hi] - done + fill)
                        pend[1].append(cols[lo:hi])
                        pend[2].append(vals[lo:hi])
                    else:
                        xbuf[rows[lo:hi] - done + fill,
                             cols[lo:hi]] = vals[lo:hi]
                    ybuf[fill:fill + take, 0] = labels[done:done + take]
                    fill += take
                    done += take
                    n += take
                    if fill == bn:
                        _flush(bn)
                        fill = 0
        if fill:
            _flush(fill)
        if n == 0:
            raise ValueError(f"{path}: no data rows")
        if store_sparse:
            x = builder.finalize()
        else:
            x = _stack_blockrows(x_blockrows, n, n_features, (bn, bm))
        y = _stack_blockrows(y_blockrows, n, 1, (bn, 1))
        return x, y


# ---------------------------------------------------------------------------
# Materializing loaders (small files / full-array paths)
# ---------------------------------------------------------------------------


def load_txt(path: str, block_shape: Tuple[int, int], delimiter: str = ",",
             dtype=np.float32, block_format: str = "dense") -> DsArray:
    """Load a delimited text file into a ds-array (single full-file parse —
    prefer :func:`load_txt_file` for anything that does not trivially fit
    in host memory)."""
    _fire("io_load", source="load_txt", path=path)
    data = np.loadtxt(path, delimiter=delimiter, dtype=dtype, ndmin=2)
    return from_array_auto(data, block_shape, block_format)


def load_npy_rows(path: str, block_shape: Tuple[int, int],
                  row_range: Optional[Tuple[int, int]] = None,
                  block_format: str = "dense") -> DsArray:
    """Memory-mapped .npy load; reads only the requested row range.

    The default dense path streams block rows straight off the map — each
    ``(bn, m)`` slice copies into a block-row buffer and moves to the
    device, so host memory stays O(block-row) and untouched pages are
    never faulted in.  ``"auto"`` (density scan) and ``"bcoo"`` must read
    the range in full and materialize it.
    """
    _fire("io_load", source="load_npy_rows", path=path)
    mm = np.load(path, mmap_mode="r")
    if mm.ndim == 1:
        mm = mm.reshape(-1, 1)
    if row_range is not None:
        mm = mm[row_range[0]: row_range[1]]
    if block_format != "dense":
        return from_array_auto(np.asarray(mm), block_shape, block_format)
    bn, bm = int(block_shape[0]), int(block_shape[1])
    n, m = mm.shape
    if n == 0:
        raise ValueError(f"{path}: empty row range")
    gm = max(1, ceil_div(m, bm))
    blockrows = []
    for i in range(0, n, bn):
        buf = np.zeros((bn, gm * bm), mm.dtype)
        k = min(bn, n - i)
        buf[:k, :m] = mm[i:i + k]
        blockrows.append(_blockrow_to_device(buf, gm, bm))
    return _stack_blockrows(blockrows, n, m, (bn, bm))


def load_npz_sparse(path: str, block_shape: Tuple[int, int]) -> DsArray:
    """scipy.sparse ``.npz`` file -> BCOO-blocked ds-array, never densifying
    (the paper's CSVM datasets ship in exactly this form)."""
    _fire("io_load", source="load_npz_sparse", path=path)
    import scipy.sparse as ssp
    from repro.core import sparse as sparse_mod
    return sparse_mod.from_scipy(ssp.load_npz(path), block_shape)


# ---------------------------------------------------------------------------
# Spill / round-trip formats
# ---------------------------------------------------------------------------


def save_npy(path: str, a: DsArray) -> None:
    """Write the dense global array.  BCOO ds-arrays raise — ``collect``
    would densify the whole matrix silently; use :func:`save_blocks`
    (sparse-aware) or ``a.todense()`` when the densification is meant."""
    if a.block_format == "bcoo":
        raise ValueError(
            "save_npy writes the dense n x m array and would silently "
            "densify a BCOO ds-array; use save_blocks(dirpath, a) for a "
            "sparse-preserving spill, or save_npy(path, a.todense()) to "
            "densify explicitly")
    np.save(path, np.asarray(a.collect()))


def save_blocks(dirpath: str, a: DsArray) -> None:
    """One file per block-row (what each PyCOMPSs worker / TPU host
    writes).  Dense arrays spill one ``blockrow_*.npy`` per block row;
    BCOO arrays spill ``blockrow_*.data.npy`` + ``blockrow_*.indices.npy``
    and record nse/flags in the metadata, so the round trip preserves the
    block format without ever densifying."""
    os.makedirs(dirpath, exist_ok=True)
    a = a.ensure_zero_pad()
    meta = {"shape": list(a.shape), "block_shape": list(a.block_shape),
            "stacked_grid": list(a.stacked_grid),
            "format": a.block_format}
    if a.block_format == "bcoo":
        sp = a.blocks
        data = np.asarray(sp.data)
        indices = np.asarray(sp.indices)
        meta.update(dtype=str(data.dtype), nse=int(sp.nse),
                    indices_sorted=bool(sp.indices_sorted),
                    unique_indices=bool(sp.unique_indices))
        rows = [(f"blockrow_{i:05d}.data.npy", data[i]) for i in
                range(data.shape[0])]
        rows += [(f"blockrow_{i:05d}.indices.npy", indices[i]) for i in
                 range(indices.shape[0])]
    else:
        blocks = np.asarray(a.blocks)   # canonical on-disk form
        meta["dtype"] = str(blocks.dtype)
        rows = [(f"blockrow_{i:05d}.npy", blocks[i]) for i in
                range(blocks.shape[0])]
    with open(os.path.join(dirpath, "meta.json"), "w") as f:
        json.dump(meta, f)
    for name, arr in rows:
        np.save(os.path.join(dirpath, name), arr)


def load_blocks(dirpath: str) -> DsArray:
    _fire("io_load", source="load_blocks", path=dirpath)
    from repro.core.blocking import BlockGrid
    import jax.numpy as jnp

    with open(os.path.join(dirpath, "meta.json")) as f:
        meta = json.load(f)
    gn = meta["stacked_grid"][0]
    grid = BlockGrid(tuple(meta["shape"]), tuple(meta["block_shape"]))
    if meta.get("format", "dense") == "bcoo":
        from jax.experimental.sparse import BCOO
        data = np.stack([np.load(os.path.join(
            dirpath, f"blockrow_{i:05d}.data.npy")) for i in range(gn)])
        indices = np.stack([np.load(os.path.join(
            dirpath, f"blockrow_{i:05d}.indices.npy")) for i in range(gn)])
        blocks = BCOO((jnp.asarray(data), jnp.asarray(indices)),
                      shape=grid.stacked_shape,
                      indices_sorted=meta.get("indices_sorted", False),
                      unique_indices=meta.get("unique_indices", False))
        return DsArray(blocks, grid)
    rows = [np.load(os.path.join(dirpath, f"blockrow_{i:05d}.npy"))
            for i in range(gn)]
    blocks = jnp.asarray(np.stack(rows, axis=0))
    return DsArray(blocks, grid)
