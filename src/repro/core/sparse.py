"""Sparse (BCOO-backed) block storage for ds-arrays.

The paper's ds-array stores each block as EITHER a NumPy array or a
scipy.sparse CSR matrix, and the whole NumPy-like API keeps working over
both — that is what lets dislib run CSVM on datasets whose dense form would
not fit the cluster.  The TPU-native analogue used here keeps the stacked
layout of ``core.dsarray`` but swaps the rank-4 dense tensor for a single
``jax.experimental.sparse.BCOO`` with ``n_batch=2``:

* batch dims (gn, gm)      <->  the block grid (paper: list of lists)
* sparse dims (bn, bm)     <->  element-sparse storage inside each block
                                 (paper: one CSR matrix per block)
* ``nse``                  <->  max nnz per block; short blocks pad with
                                 out-of-bounds zero-data slots (dropped by
                                 every BCOO op)

A ds-array's storage is named by ``DsArray.block_format``:

* ``"dense"`` — the rank-4 stacked tensor (default, unchanged);
* ``"bcoo"``  — the stacked BCOO above.

Pad-state semantics: a BCOO block simply has **no entry** in the pad
region — construction (``tosparse``/``random_sparse``/``from_scipy``) masks
pad positions out — so sparse arrays are ZERO-padded *by construction*,
``ensure_zero_pad`` is the identity and remask elision is free.  Every
sparse-producing op below preserves that invariant (data maps are gated on
``fn(0) == 0``); ops that cannot, densify first.

Op policy (see the ``core.dsarray`` docstring for the full table):

* **sparse-native** — scalar scale/neg/abs/sqrt (index-preserving data
  maps), sparse±sparse and sparse*sparse (index merge), sparse*dense and
  sparse/dense (index-gather of the dense operand), ``astype``,
  ``transpose`` (batch-dim swap + index swap), grid padding, ``sum``
  (``bcoo_reduce_sum``), ``sp @ dense`` and ``spᵀ @ dense`` (one
  ``bcoo_dot_general`` per contraction — the sparse operand is **never**
  densified, asserted on the jaxpr in ``tests/test_sparse.py``);
* **densifying** — anything that breaks the implicit-zero algebra
  (``+ scalar``, ``exp``, dense/sp division), max/min reductions (implicit
  zeros compete), and the structural ops (slice/rechunk/concat/shuffle),
  which lower through the dense block-native kernels after ``todense()``.

The decision logic is shared by the eager dispatch (``binary``/
``map_blocks_sparse``) and the lazy facade (``core.expr`` records the same
classification, so a sparse ``Blockwise`` never silently densifies).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse
from jax.experimental.sparse import BCOO

from repro.core.blocking import BlockGrid, ceil_div

Number = Union[int, float]

FORMAT_DENSE = "dense"
FORMAT_BCOO = "bcoo"


def is_bcoo(x) -> bool:
    return isinstance(x, BCOO)


def _rebuild(ref: BCOO, data: jnp.ndarray,
             indices: Optional[jnp.ndarray] = None) -> BCOO:
    """BCOO with ``ref``'s index structure and new ``data`` (index-preserving
    data map).  Sorted/unique flags carry over: the indices are untouched."""
    return BCOO((data, ref.indices if indices is None else indices),
                shape=ref.shape, indices_sorted=ref.indices_sorted,
                unique_indices=ref.unique_indices)


def _canon_unique(sp: BCOO) -> BCOO:
    """``sp`` with duplicate indices merged (same capacity, jittable).

    Index-merge ops (sparse ± sparse) CONCATENATE entry lists, so a stored
    position may be split across several slots; a NONLINEAR data map over
    split entries is wrong (``|d1 + d2| != |d1| + |d2|``).  Every nonlinear
    data-map consumer routes through this; linear maps (scale, gather-mul)
    distribute over the split and skip it.
    """
    if sp.unique_indices:
        return sp
    return jsparse.bcoo_sum_duplicates(sp, nse=sp.nse)


_LINEAR_DATA_OPS = {"multiply", "divide"}


def _gather_dense_at(sp: BCOO, dense_blocks: jnp.ndarray) -> jnp.ndarray:
    """The dense stacked tensor's values at ``sp``'s stored positions.

    Advanced indexing with the batch iotas + stored indices emits one
    gather of shape (gn, gm, nse); out-of-bounds pad slots clamp (their data
    is zero so the gathered value is irrelevant).
    """
    gn, gm, bn, bm = sp.shape
    ii = jnp.minimum(sp.indices[..., 0], bn - 1)
    jj = jnp.minimum(sp.indices[..., 1], bm - 1)
    bi = jax.lax.broadcasted_iota(jnp.int32, ii.shape, 0)
    bj = jax.lax.broadcasted_iota(jnp.int32, ii.shape, 1)
    return dense_blocks[bi, bj, ii, jj]


# ---------------------------------------------------------------------------
# Conversions
# ---------------------------------------------------------------------------


def _pack_coo_arrays(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                     cell: np.ndarray, n_cells: int, bn: int, bm: int,
                     nse: Optional[int] = None, check_nse: bool = True,
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Bucket block-sorted COO triplets into ``(data, indices)`` host arrays
    of shape ``(n_cells, nse)`` / ``(n_cells, nse, 2)`` (pure NumPy: no XLA
    program per geometry).  ``cell`` is non-decreasing; ``rows``/``cols``
    are block-local.  Short cells pad with the out-of-bounds (bn, bm)
    sentinel and zero data.  With ``check_nse`` an explicit capacity below
    the real max cell nnz raises instead of silently dropping entries;
    pre-checked hot paths (the serve batcher) opt out.
    """
    counts = np.bincount(cell, minlength=n_cells)
    maxn = int(counts.max()) if counts.size else 0
    if nse is None:
        nse = maxn
    nse = max(1, int(nse))
    if check_nse and maxn > nse:
        raise ValueError(
            f"nse={nse} cannot hold the densest block ({maxn} nnz); "
            f"entries would be silently dropped.  Pass nse>=max_block_nnz "
            f"or check_nse=False if the capacity was already verified.")
    data = np.zeros((n_cells, nse), dtype=vals.dtype)
    indices = np.full((n_cells, nse, 2), (bn, bm), dtype=np.int32)
    slot = np.arange(len(cell)) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    keep = slot < nse                  # unchecked explicit nse may truncate
    cell, slot = cell[keep], slot[keep]
    data[cell, slot] = vals[keep]
    indices[cell, slot, 0] = rows[keep]
    indices[cell, slot, 1] = cols[keep]
    return data, indices


def _pack_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              cell: np.ndarray, gn: int, gm: int, bn: int, bm: int,
              nse: Optional[int] = None, check_nse: bool = False) -> BCOO:
    """Bucket block-sorted COO triplets into the stacked BCOO (see
    :func:`_pack_coo_arrays`).  ``cell`` = gi*gm + gj."""
    data, indices = _pack_coo_arrays(rows, cols, vals, cell, gn * gm,
                                     bn, bm, nse, check_nse)
    nse = data.shape[1]
    return BCOO((jnp.asarray(data.reshape(gn, gm, nse)),
                 jnp.asarray(indices.reshape(gn, gm, nse, 2))),
                shape=(gn, gm, bn, bm), indices_sorted=True,
                unique_indices=True)


def tosparse(a: "DsArray", nse: Optional[int] = None) -> "DsArray":
    """Dense ds-array -> BCOO-blocked ds-array (identity if already sparse).

    The pad region is forced to zero first, so no pad position owns an
    entry — the sparse pad invariant holds by construction.  ``nse`` caps
    stored entries per block (default: the max block nnz).  Concrete arrays
    convert on the host in NumPy (``BCOO.fromdense`` compiles a fresh XLA
    program per geometry — ~1s each, which a test corpus or a data-loading
    loop over many shapes cannot afford); traced arrays (the lazy
    ``ToSparse`` node under jit) take the fromdense path.
    """
    from repro.core.dsarray import DsArray, PAD_ZERO
    if a.block_format == FORMAT_BCOO:
        return a
    me = a.ensure_zero_pad()
    if isinstance(me.blocks, jax.core.Tracer) or \
            jax.default_backend() != "cpu":
        blocks = BCOO.fromdense(me.blocks, n_batch=2, nse=nse)
        return DsArray(blocks, a.grid, PAD_ZERO)
    host = np.asarray(me.blocks)
    gn, gm, bn, bm = host.shape
    gi, gj, rr, cc = np.nonzero(host)          # C-order: grouped by block
    blocks = _pack_coo(rr.astype(np.int32), cc.astype(np.int32),
                       host[gi, gj, rr, cc], gi * gm + gj,
                       gn, gm, bn, bm, nse)
    return DsArray(blocks, a.grid, PAD_ZERO)


def todense(a: "DsArray") -> "DsArray":
    """BCOO-blocked ds-array -> dense (identity if already dense).  Stored
    entries scatter into a zero tensor, so the result pad is exactly zero.
    Concrete CPU arrays scatter on the host (``BCOO.todense`` compiles one
    XLA program per geometry); traced / accelerator-resident arrays keep
    the compiled path."""
    from repro.core.dsarray import DsArray, PAD_ZERO
    if a.block_format == FORMAT_DENSE:
        return a
    sp = a.blocks
    if isinstance(sp.data, jax.core.Tracer) or jax.default_backend() != "cpu":
        return DsArray(sp.todense(), a.grid, PAD_ZERO)
    gn, gm, bn, bm = sp.shape
    data = np.asarray(sp.data)
    idx = np.asarray(sp.indices)
    host = np.zeros((gn, gm, bn, bm), data.dtype)
    bi = np.broadcast_to(np.arange(gn)[:, None, None], data.shape)
    bj = np.broadcast_to(np.arange(gm)[None, :, None], data.shape)
    ok = (idx[..., 0] < bn) & (idx[..., 1] < bm)     # drop OOB pad slots
    np.add.at(host, (bi[ok], bj[ok],                 # add: duplicates merge
                     idx[..., 0][ok], idx[..., 1][ok]), data[ok])
    return DsArray(jnp.asarray(host), a.grid, PAD_ZERO)


def density(a: "DsArray") -> float:
    """nnz / logical size (concrete arrays only)."""
    n, m = a.shape
    if a.block_format == FORMAT_BCOO:
        nnz = int(jnp.count_nonzero(a.blocks.data))
    else:
        nnz = int(jnp.count_nonzero(a.ensure_zero_pad().blocks))
    return nnz / max(1, n * m)


def canonicalize(a: "DsArray", nse: Optional[int] = None) -> "DsArray":
    """Re-pack a sparse ds-array: merge duplicate indices (left behind by
    sparse+sparse index concatenation) and shrink ``nse`` back to the max
    block nnz.  Eager-only (the output nse is data-dependent)."""
    from repro.core.dsarray import DsArray, PAD_ZERO
    if a.block_format != FORMAT_BCOO:
        return a
    blocks = jsparse.bcoo_sum_duplicates(a.blocks, nse=nse)
    return DsArray(blocks, a.grid, PAD_ZERO)


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def random_sparse(key, shape: Tuple[int, int], block_shape: Tuple[int, int],
                  density: float = 0.01, dtype=jnp.float32,
                  distribution: str = "normal") -> "DsArray":
    """Random BCOO-blocked ds-array: ``density`` fraction of entries per
    block hold samples, the rest are implicit zeros (paper §4.2.2 per-block
    creation, sparse edition).  Pad positions of edge blocks are zeroed so
    the sparse pad invariant holds."""
    from repro.core.dsarray import DsArray, PAD_ZERO
    grid = BlockGrid(tuple(shape), tuple(block_shape))
    gn, gm, bn, bm = grid.stacked_shape
    gen = {"normal": jax.random.normal, "uniform": jax.random.uniform}[distribution]
    sp = jsparse.random_bcoo(key, (gn, gm, bn, bm), nse=float(density),
                             n_batch=2, dtype=jnp.dtype(dtype), generator=gen)
    n, m = shape
    if gn * bn > n or gm * bm > m:
        # zero (not drop) entries landing in the pad region: indices keep
        # their static shape, zero data is an explicit zero — still valid
        bi = jax.lax.broadcasted_iota(jnp.int32, sp.data.shape, 0)
        bj = jax.lax.broadcasted_iota(jnp.int32, sp.data.shape, 1)
        valid = ((bi * bn + sp.indices[..., 0]) < n) & \
                ((bj * bm + sp.indices[..., 1]) < m)
        sp = _rebuild(sp, jnp.where(valid, sp.data, jnp.zeros((), sp.dtype)))
    return DsArray(sp, grid, PAD_ZERO)


def from_scipy(mat, block_shape: Tuple[int, int],
               nse: Optional[int] = None,
               check_nse: bool = True) -> "DsArray":
    """scipy.sparse matrix -> BCOO-blocked ds-array, without densifying.

    The paper loads CSVM datasets straight into CSR-blocked ds-arrays; here
    the COO triplets are bucketed by block (pure NumPy index math, touching
    only the nnz entries) and packed into the stacked BCOO with ``nse`` =
    the max block nnz.  An explicit ``nse`` fixes the stored-entry capacity
    instead: the serving layer packs every request batch of one geometry
    bucket at the bucket's declared capacity, which keeps the plan-cache
    leaf signature — and therefore the compiled program — identical across
    batches with different nnz.  An explicit ``nse`` below the real max
    block nnz raises ``ValueError`` (the bincount guard costs O(nnz));
    pre-checked hot paths that already compared :func:`max_block_nnz`
    against the capacity pass ``check_nse=False`` to skip the raise.
    """
    from repro.core.dsarray import DsArray, PAD_ZERO
    coo = mat.tocoo()
    coo.sum_duplicates()
    n, m = coo.shape
    grid = BlockGrid((n, m), tuple(block_shape))
    gn, gm, bn, bm = grid.stacked_shape
    cell = (coo.row // bn) * gm + coo.col // bm
    order = np.argsort(cell, kind="stable")
    blocks = _pack_coo((coo.row[order] % bn).astype(np.int32),
                       (coo.col[order] % bm).astype(np.int32),
                       coo.data[order], cell[order], gn, gm, bn, bm, nse,
                       check_nse=check_nse)
    return DsArray(blocks, grid, PAD_ZERO)


def max_block_nnz(mat, block_shape: Tuple[int, int]) -> int:
    """Max nnz of any block of ``mat`` under ``block_shape`` (host-side
    NumPy over the stored triplets only) — the guard a fixed-capacity
    :func:`from_scipy` pack needs: a batch whose densest block exceeds the
    declared bucket ``nse`` must fall back rather than truncate."""
    coo = mat.tocoo()
    coo.sum_duplicates()
    if coo.nnz == 0:
        return 0
    n, m = coo.shape
    grid = BlockGrid((n, m), tuple(block_shape))
    gn, gm, bn, bm = grid.stacked_shape
    cell = (coo.row // bn) * gm + coo.col // bm
    return int(np.bincount(cell, minlength=gn * gm).max())


class StackedBCOOBuilder:
    """Incremental stacked-BCOO assembly, one block row at a time.

    The streaming svmlight loader hands each completed block row's COO
    triplets here; they are bucketed by block column with the same pure
    NumPy pack as :func:`from_scipy` (sorted by (row, col) inside each
    block so ``indices_sorted`` holds) and moved to the device arena
    immediately — host memory stays O(one block row's triplets), never
    O(nnz of the file).

    With ``nse=None`` (default) each appended row packs at its own max
    block nnz and :meth:`finalize` pads every row up to the global max —
    bit-identical capacity to the :func:`from_scipy` default.  An explicit
    ``nse`` fixes the capacity up front and overflowing rows raise
    ``ValueError`` at append time (no silent truncation mid-stream).
    """

    def __init__(self, m: int, block_shape: Tuple[int, int],
                 dtype=np.float32, nse: Optional[int] = None):
        self.bn, self.bm = int(block_shape[0]), int(block_shape[1])
        self.m = int(m)
        self.gm = max(1, ceil_div(self.m, self.bm))
        self.dtype = np.dtype(dtype)
        self.nse = None if nse is None else max(1, int(nse))
        self.n_rows = 0                       # logical rows appended so far
        self._data: list = []                 # per block row: jnp (gm, nse_i)
        self._indices: list = []              # per block row: jnp (gm, nse_i, 2)

    def append_blockrow(self, rows: np.ndarray, cols: np.ndarray,
                        vals: np.ndarray, n_rows: int) -> None:
        """Add one block row from triplets (``rows`` block-local in
        [0, bn), ``cols`` global in [0, m), any order)."""
        if not 0 < n_rows <= self.bn:
            raise ValueError(f"n_rows={n_rows} outside (0, bn={self.bn}]")
        rows = np.asarray(rows, np.int32)
        cols = np.asarray(cols, np.int32)
        vals = np.asarray(vals, self.dtype)
        if cols.size and (int(cols.max()) >= self.m or int(cols.min()) < 0):
            raise ValueError(
                f"feature id {int(cols.max())} out of range for "
                f"n_features={self.m} (0-based after shift — a 0-based "
                f"file read as 1-based hits this)")
        cell = cols // self.bm
        # sort by (block, row, col) so indices_sorted holds (int64 key:
        # gm*bn*bm can pass 2**31)
        order = np.argsort(cell.astype(np.int64) * (self.bn * self.bm)
                           + rows.astype(np.int64) * self.bm + cols % self.bm,
                           kind="stable")
        data, indices = _pack_coo_arrays(
            rows[order], (cols % self.bm)[order], vals[order], cell[order],
            self.gm, self.bn, self.bm, nse=self.nse, check_nse=True)
        # copy=True: jnp.asarray would zero-copy an aligned host buffer,
        # RETAINING one host array per block row — O(file) host memory.
        # An owned device copy frees the host side immediately.
        self._data.append(jnp.array(data, copy=True))
        self._indices.append(jnp.array(indices, copy=True))
        self.n_rows += int(n_rows)

    def finalize(self) -> "DsArray":
        """Stack the appended block rows into a BCOO ds-array of shape
        ``(n_rows, m)``.  Per-row capacities pad up to the target nse on
        device (data pads with zeros, indices with the OOB sentinel, so
        sortedness and the pad invariant are preserved)."""
        from repro.core.dsarray import DsArray, PAD_ZERO
        if not self._data:
            raise ValueError("no block rows appended")
        target = self.nse if self.nse is not None else \
            max(1, max(d.shape[1] for d in self._data))
        sentinel = jnp.asarray([self.bn, self.bm], jnp.int32)
        data_rows, index_rows = [], []
        for d, ix in zip(self._data, self._indices):
            pad = target - d.shape[1]
            if pad:
                d = jnp.concatenate(
                    [d, jnp.zeros((self.gm, pad), d.dtype)], axis=1)
                ix = jnp.concatenate(
                    [ix, jnp.broadcast_to(sentinel, (self.gm, pad, 2))],
                    axis=1)
            data_rows.append(d)
            index_rows.append(ix)
        blocks = BCOO((jnp.stack(data_rows), jnp.stack(index_rows)),
                      shape=(len(data_rows), self.gm, self.bn, self.bm),
                      indices_sorted=True, unique_indices=True)
        grid = BlockGrid((self.n_rows, self.m), (self.bn, self.bm))
        if grid.stacked_shape[0] != len(data_rows):
            raise ValueError(
                f"appended {len(data_rows)} block rows but {self.n_rows} "
                f"logical rows need {grid.stacked_shape[0]}")
        return DsArray(blocks, grid, PAD_ZERO)


def fetch_row_dense(a: "DsArray", i: int) -> jnp.ndarray:
    """Row ``i`` of a sparse ds-array as a padded dense ``(gm*bm,)`` vector.

    Touches only block row ``i // bn`` (its entries scatter-add into the
    output), never the whole array — the k-means++ seeding fetch.
    """
    gn, gm, bn, bm = a.blocks.shape
    gi, off = int(i) // bn, int(i) % bn
    data = a.blocks.data[gi]                       # (gm, nse)
    idx = a.blocks.indices[gi]                     # (gm, nse, 2)
    bj = jax.lax.broadcasted_iota(jnp.int32, data.shape, 0)
    col = bj * bm + jnp.minimum(idx[..., 1], bm - 1)
    hit = (idx[..., 0] == off) & (idx[..., 1] < bm)
    vals = jnp.where(hit, data, jnp.zeros((), data.dtype))
    return jnp.zeros((gm * bm,), data.dtype).at[col.ravel()].add(vals.ravel())


# ---------------------------------------------------------------------------
# Elementwise dispatch (shared by the eager ops and the lazy recorder)
# ---------------------------------------------------------------------------


def _probe_zero(op: Callable, rhs, reverse: bool, dtype) -> bool:
    """True iff ``op`` maps an implicit zero (paired with the known scalar
    ``rhs``) back to zero — the gate for index-preserving data maps."""
    try:
        z = jnp.zeros((), dtype)
        out = op(rhs, z) if reverse else op(z, rhs)
        return bool(np.asarray(out) == 0)
    except Exception:
        return False


_PAIR_NATIVE = {"add", "subtract", "multiply"}
_GATHER_NATIVE = {"multiply", "divide"}


def classify_binary(op: Callable, lhs_sparse: bool, rhs, reverse: bool,
                    lhs_dtype) -> str:
    """How to execute ``op(lhs, rhs)`` with at least one sparse operand.

    ``rhs`` is ``("ds", is_sparse, dtype)`` or a raw scalar.  Returns:

    * ``"data"``   — index-preserving map over the sparse operand's data
      (scalar other, ``op(0, s) == 0``);
    * ``"pair"``   — both sparse: BCOO index-merge add/sub/mul;
    * ``"gather"`` — sparse x dense mul/div with the SPARSE side as the
      left numerator: gather the dense operand at the stored indices;
    * ``"dense"``  — no zero-preserving sparse form: densify first.
    """
    name = getattr(op, "__name__", "")
    if isinstance(rhs, tuple):
        _, rhs_sparse, _ = rhs
        if lhs_sparse and rhs_sparse:
            return "pair" if name in _PAIR_NATIVE else "dense"
        if not (lhs_sparse or rhs_sparse):
            # both dense: alignment can densify a sparse operand (a
            # block-shape mismatch rechunks, and rechunk densifies by
            # policy) — nothing sparse is left for gather to index
            return "dense"
        # exactly one side sparse; the gather form needs op(0, y) == 0 for
        # EVERY y, so only mul (0*y) and div with the sparse side on top
        sparse_on_top = lhs_sparse != reverse
        if name in _GATHER_NATIVE and (name == "multiply" or sparse_on_top):
            return "gather"
        return "dense"
    return "data" if (lhs_sparse and _probe_zero(op, rhs, reverse, lhs_dtype)) \
        else "dense"


def data_map_fn(op: Callable, scalar, reverse: bool) -> Callable:
    """blocks->blocks closure for a scalar data map (used by the lazy
    Blockwise recorder as well as the eager path)."""
    linear = getattr(op, "__name__", "") in _LINEAR_DATA_OPS

    def fn(sp: BCOO) -> BCOO:
        if not linear:
            sp = _canon_unique(sp)
        out = op(scalar, sp.data) if reverse else op(sp.data, scalar)
        return _rebuild(sp, out)
    return fn


def pair_fn(op: Callable, reverse: bool) -> Callable:
    """blocks->blocks closure for sparse (+|-|*) sparse."""
    name = getattr(op, "__name__", "")

    def fn(x: BCOO, y: BCOO) -> BCOO:
        a, b = (y, x) if reverse else (x, y)
        if name == "multiply":
            return jsparse.bcoo_multiply_sparse(a, b)
        # jnp ufuncs reject BCOO; the operator forms concatenate indices
        return a + b if name == "add" else a - b
    return fn


def gather_fn(op: Callable, sparse_left: bool) -> Callable:
    """blocks->blocks closure for sparse x dense mul/div: the dense operand
    is gathered at the sparse operand's stored indices, so the result keeps
    the index structure and the dense block tensor is read once."""
    def fn(x, y):
        sp, dn = (x, y) if sparse_left else (y, x)
        vals = _gather_dense_at(sp, dn)
        out = op(sp.data, vals) if sparse_left else op(vals, sp.data)
        # zero-data slots (pad sentinels, grid-growth fillers) must stay
        # EXACTLY zero — 0/0 at a dirty dense pad position would smuggle a
        # nan into the pad region and break the construction invariant
        out = jnp.where(sp.data == 0, jnp.zeros((), out.dtype), out)
        return _rebuild(sp, out)
    return fn


def binary(a: "DsArray", other, op: Callable, reverse: bool):
    """Eager sparse-aware ``_binary``: operands are aligned exactly like the
    dense path, then dispatched per :func:`classify_binary`.  Returns
    NotImplemented for operand types the dense path also rejects."""
    from repro.core.dsarray import DsArray, PAD_ZERO
    me = a
    if isinstance(other, DsArray):
        if other.shape != me.shape:
            raise ValueError(f"shape mismatch {me.shape} vs {other.shape}")
        if other.block_shape != me.block_shape:
            other = other.rechunk(me.block_shape)      # densifies a sparse rhs
        if other.stacked_grid != me.stacked_grid:
            common = (max(me.stacked_grid[0], other.stacked_grid[0]),
                      max(me.stacked_grid[1], other.stacked_grid[1]))
            me, other = me._pad_grid_to(common), other._pad_grid_to(common)
        rhs_desc = ("ds", other.block_format == FORMAT_BCOO, other.dtype)
    elif isinstance(other, (int, float, jnp.ndarray, np.ndarray)) \
            and jnp.ndim(other) == 0:
        if isinstance(other, jax.core.Tracer):
            return todense(me)._binary(other, op, reverse)
        rhs_desc = other
    else:
        return NotImplemented

    mode = classify_binary(op, me.block_format == FORMAT_BCOO, rhs_desc,
                           reverse, me.dtype)
    if mode == "data":
        return DsArray(data_map_fn(op, other, reverse)(me.blocks), me.grid,
                       PAD_ZERO)
    if mode == "pair":
        return DsArray(pair_fn(op, reverse)(me.blocks, other.blocks),
                       me.grid, PAD_ZERO)
    if mode == "gather":
        lhs_sp = me.block_format == FORMAT_BCOO
        x, y = (me.blocks, other.blocks)
        out = gather_fn(op if not reverse else (lambda u, v: op(v, u)),
                        lhs_sp)(x, y)
        return DsArray(out, me.grid, PAD_ZERO)
    # densify whichever operands are sparse and take the dense path
    me = todense(me)
    if isinstance(other, DsArray):
        other = todense(other)
    return me._binary(other, op, reverse)


def zero_preserving_map(fn: Callable, dtype) -> bool:
    """Probe ``fn`` on a zero block (like the dense pad probe): data-map
    eligible iff it is shape-preserving and maps zero to zero."""
    try:
        probe = jnp.zeros((1, 1, 1, 1), dtype)
        out = fn(probe)
        return (not isinstance(out, jax.core.Tracer)
                and getattr(out, "shape", None) == (1, 1, 1, 1)
                and bool(np.asarray(out).item() == 0))
    except Exception:
        return False


def map_blocks_sparse(a: "DsArray", fn: Callable, pad) -> "DsArray":
    """``map_blocks`` over a sparse ds-array.

    Zero-preserving elementwise fns (probed on a zero block, like the dense
    pad probe) run as an index-preserving data map — the data vector is
    viewed as rank-4 ``(gn, gm, nse, 1)`` so fns written against block
    tensors see the rank they expect.  Anything else (``fn(0) != 0``,
    position-dependent fns flagged with an explicit ``pad``) densifies.
    """
    from repro.core.dsarray import DsArray, PAD_ZERO
    if pad is not None or not zero_preserving_map(fn, a.dtype):
        return todense(a).map_blocks(fn, pad=pad)
    return DsArray(sparse_map_fn(fn)(a.blocks), a.grid, PAD_ZERO)


def sparse_map_fn(fn: Callable) -> Callable:
    """blocks->blocks closure of the data-map above (for the lazy layer).
    User fns are nonlinear until proven otherwise: merge split entries."""
    def mapped(sp: BCOO) -> BCOO:
        sp = _canon_unique(sp)
        return _rebuild(sp, fn(sp.data[..., None])[..., 0])
    return mapped


# ---------------------------------------------------------------------------
# Structure ops (sparse-native)
# ---------------------------------------------------------------------------


def aligned_slice_sparse(a: "DsArray", rows: slice, cols: slice) -> "DsArray":
    """Block-aligned slice of a BCOO-blocked ds-array, sparse-natively.

    A slice whose start sits on a block boundary (unit step) is a pure
    **batch-dim slice** of the stacked BCOO: ``data[g0:g1, h0:h1]`` /
    ``indices[g0:g1, h0:h1]`` — O(selected entries), no re-bucketing, and
    crucially no ``bcoo_todense`` (the ROADMAP PR-4 follow-on: this used to
    densify).  A slice that STOPS mid-block keeps the entry slots but zeroes
    the data of positions past the new logical edge — indices keep their
    static shape, zero data is an explicit zero (the same trick
    ``random_sparse`` uses for pad entries), so the zero-pad-by-construction
    invariant holds on the result.

    This is the CSVM cascade's per-chunk row partition: chunks are batch
    slices of the one stacked BCOO, so the data matrix is never densified
    on the way into the per-node solvers.
    """
    from repro.core.dsarray import DsArray, PAD_ZERO
    from repro.core.blocking import grid_span
    sp = a.blocks
    gn, gm, bn, bm = sp.shape
    n, m = a.shape
    r0, r1, rs = rows.indices(n)
    c0, c1, cs = cols.indices(m)
    assert rs == 1 and cs == 1 and r0 % bn == 0 and c0 % bm == 0
    g0, g1 = (0, 1) if r1 <= r0 else grid_span(r0, r1, bn)
    h0, h1 = (0, 1) if c1 <= c0 else grid_span(c0, c1, bm)
    data = sp.data[g0:g1, h0:h1]
    indices = sp.indices[g0:g1, h0:h1]
    nr, nc = max(0, r1 - r0), max(0, c1 - c0)
    # zero entries past the new logical edge (slice stopped mid-block, or an
    # empty selection kept its one placeholder block)
    if (g1 - g0) * bn > nr or (h1 - h0) * bm > nc:
        bi = jax.lax.broadcasted_iota(jnp.int32, data.shape, 0)
        bj = jax.lax.broadcasted_iota(jnp.int32, data.shape, 1)
        valid = ((bi * bn + indices[..., 0]) < nr) & \
                ((bj * bm + indices[..., 1]) < nc)
        data = jnp.where(valid, data, jnp.zeros((), data.dtype))
    blocks = BCOO((data, indices), shape=(g1 - g0, h1 - h0, bn, bm),
                  indices_sorted=sp.indices_sorted,
                  unique_indices=sp.unique_indices)
    from repro.core.structural import preserve_sharding
    out = DsArray(blocks, BlockGrid((nr, nc), (bn, bm)), PAD_ZERO)
    return preserve_sharding(out, sp.data)


def rows_to_dense(a: "DsArray") -> np.ndarray:
    """All rows of a (small) sparse ds-array as one dense ``(n, m)`` host
    array: stored entries scatter-add straight into row-major layout.

    This is the CSVM per-node basis extraction — the (s, m) dense sub-problem
    matrix every kernel-SVM solver materializes (libsvm's kernel cache does
    the same) — built from the BCOO's triplets in O(nnz) NumPy, never
    through ``todense()`` (which would build the stacked dense tensor and
    compile an XLA scatter per geometry).  Dense inputs take ``collect``.
    """
    if a.block_format != FORMAT_BCOO:
        return np.asarray(a.collect())
    sp = a.blocks
    gn, gm, bn, bm = sp.shape
    n, m = a.shape
    data = np.asarray(sp.data)
    idx = np.asarray(sp.indices)
    out = np.zeros((gn * bn, gm * bm), data.dtype)
    rr = (np.arange(gn)[:, None, None] * bn +
          np.minimum(idx[..., 0], bn - 1))
    cc = (np.arange(gm)[None, :, None] * bm +
          np.minimum(idx[..., 1], bm - 1))
    ok = (idx[..., 0] < bn) & (idx[..., 1] < bm)      # drop OOB pad slots
    np.add.at(out, (rr[ok], cc[ok]), data[ok])        # add: duplicates merge
    return out[:n, :m]


def astype_sparse(a: "DsArray", dtype) -> "DsArray":
    from repro.core.dsarray import DsArray, PAD_ZERO
    # merge split entries first: cast(d1 + d2) != cast(d1) + cast(d2) for
    # narrowing casts, and the dense path casts the SUMMED value
    sp = _canon_unique(a.blocks)
    return DsArray(_rebuild(sp, sp.data.astype(dtype)), a.grid, PAD_ZERO)


def transpose_sparse(a: "DsArray") -> "DsArray":
    """Batch-dim swap + per-entry index swap: no dense relayout, the HBM
    traffic is O(nnz) instead of O(dense)."""
    from repro.core.dsarray import DsArray, PAD_ZERO
    return DsArray(a.blocks.transpose((1, 0, 3, 2)), a.grid.transpose(),
                   PAD_ZERO)


def pad_grid_sparse(a: "DsArray", stacked_grid: Tuple[int, int]) -> "DsArray":
    """Grow the stacked grid: new blocks get zero-data slots at index (0, 0)
    — explicit zeros, which every consumer treats as absent."""
    from repro.core.dsarray import DsArray, PAD_ZERO
    gn, gm = a.stacked_grid
    tn, tm = stacked_grid
    if (tn, tm) == (gn, gm):
        return a
    if tn < gn or tm < gm:
        raise ValueError("can only grow the stacked grid")
    sp = a.blocks
    data = jnp.pad(sp.data, ((0, tn - gn), (0, tm - gm), (0, 0)))
    indices = jnp.pad(sp.indices, ((0, tn - gn), (0, tm - gm), (0, 0), (0, 0)))
    blocks = BCOO((data, indices), shape=(tn, tm) + sp.shape[2:])
    return DsArray(blocks, a.grid, PAD_ZERO)


def reduce_sparse(a: "DsArray", op: str, axis: Optional[int]):
    """Reductions over a sparse ds-array.

    ``sum`` is sparse-native: ``bcoo_reduce_sum`` folds the stored entries
    (implicit zeros are the identity) — the sparse operand is never
    densified; only the small reduced result is.  ``max``/``min`` must rank
    stored entries against the implicit zeros, so they take the dense path.
    """
    from repro.core.dsarray import DsArray, pad_state_of
    if op != "sum":
        return todense(a)._reduce(op, axis)
    sp = a.blocks
    if axis is None:
        return jsparse.bcoo_reduce_sum(sp, axes=(0, 1, 2, 3)).todense()
    if axis == 0:
        out = jsparse.bcoo_reduce_sum(sp, axes=(0, 2)).todense()  # (gm, bm)
        gm, bm = out.shape
        blocks = out.reshape(1, gm, 1, bm)
        grid = BlockGrid((1, a.shape[1]), (1, bm))
    elif axis == 1:
        out = jsparse.bcoo_reduce_sum(sp, axes=(1, 3)).todense()  # (gn, bn)
        gn, bn = out.shape
        blocks = out.reshape(gn, 1, bn, 1)
        grid = BlockGrid((a.shape[0], 1), (bn, 1))
    else:
        raise ValueError(f"axis must be 0, 1 or None, got {axis}")
    return DsArray(blocks, grid, pad_state_of(0))


def distribute_sparse(a: "DsArray", mesh, axes) -> "DsArray":
    """Shard a sparse ds-array's batch (grid) dims over the mesh: data and
    indices are placed leaf-by-leaf with matching specs."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.dsarray import DsArray
    from repro.core.blocking import round_up
    dn = mesh.shape[axes[0]] if axes[0] else 1
    dm = mesh.shape[axes[1]] if axes[1] else 1
    gn, gm = a.stacked_grid
    padded = pad_grid_sparse(a, (round_up(gn, dn), round_up(gm, dm)))
    sp = padded.blocks
    data = jax.device_put(sp.data, NamedSharding(mesh, P(axes[0], axes[1], None)))
    indices = jax.device_put(
        sp.indices, NamedSharding(mesh, P(axes[0], axes[1], None, None)))
    return DsArray(BCOO((data, indices), shape=sp.shape), a.grid,
                   padded.pad_state)


# ---------------------------------------------------------------------------
# Invariant checking (the differential harness + REPRO_DEBUG=1 validator)
# ---------------------------------------------------------------------------


def check_bcoo_invariants(a: "DsArray") -> None:
    """Raise if the BCOO storage violates the sparse ds-array contract:
    non-negative indices; any out-of-bounds slot carries zero data (the pad
    sentinel); any in-bounds entry at a logical-pad position carries zero
    data (sparse arrays are zero-padded by construction)."""
    sp = a.blocks
    if sp.n_batch != 2 or sp.n_dense != 0:
        raise AssertionError(
            f"sparse blocks must be n_batch=2/n_dense=0 BCOO, got "
            f"n_batch={sp.n_batch} n_dense={sp.n_dense}")
    if a.pad_state.kind != "zero":
        raise AssertionError(
            f"sparse ds-arrays are zero-padded by construction, "
            f"claimed {a.pad_state}")
    idx = np.asarray(sp.indices)
    data = np.asarray(sp.data)
    gn, gm, bn, bm = sp.shape
    n, m = a.shape
    def _site(mask) -> str:
        gi, gj, slot = (int(v) for v in np.argwhere(mask)[0])
        return (f"{int(mask.sum())} violation(s), first in block "
                f"({gi}, {gj}) slot {slot}: index "
                f"({int(idx[gi, gj, slot, 0])}, {int(idx[gi, gj, slot, 1])})"
                f", data {data[gi, gj, slot]!r}")

    neg = (idx[..., 0] < 0) | (idx[..., 1] < 0)
    if np.any(neg):
        raise AssertionError(f"negative BCOO index: {_site(neg)}")
    oob = (idx[..., 0] >= bn) | (idx[..., 1] >= bm)
    bad = oob & (data != 0)
    if np.any(bad):
        raise AssertionError(
            f"out-of-bounds BCOO slot with nonzero data: {_site(bad)}")
    bi = np.arange(gn)[:, None, None]
    bj = np.arange(gm)[None, :, None]
    in_pad = ((bi * bn + idx[..., 0]) >= n) | ((bj * bm + idx[..., 1]) >= m)
    bad = in_pad & ~oob & (data != 0)
    if np.any(bad):
        raise AssertionError(
            "nonzero BCOO entry in the logical pad region "
            f"(sparse pad invariant violated): {_site(bad)}")
