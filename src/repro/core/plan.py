"""Optimize, compile and cache lazy ds-array expression plans.

``compute()`` takes recorded ``Expr`` DAGs (see ``core.expr``) through three
stages:

1. **optimize** —
   (a) canonicalize + hash-cons (CSE): identical subexpressions become one
       node, so sibling reductions over the same operand evaluate it once
       and duplicate reductions collapse entirely;
   (b) transpose rules: ``T(T(x)) → x``; a Blockwise whose ds operands are
       all transposes hoists the transpose above the elementwise work (so
       chains keep fusing and the matmul fold below can fire);
       ``(A.T) @ B → MatMul(A, B, transpose_a=True)``, which lowers through
       the fused Pallas GEMM with the transpose folded into block-index
       maps — the transposed stacked tensor is never materialized;
   (c) blockwise fusion: runs of elementwise/map_blocks nodes with
       single-consumer intermediates compose into ONE per-block function,
       whose pad state is re-probed on the leaf pad constants — the eager
       layer's pad tracking, propagated symbolically across the whole plan,
       so a chain pays at most one remask at its consumer.

2. **compile** — the optimized DAG is lowered onto the eager block-native
   primitives (each node's ``lower``) inside a single ``jax.jit``; leaf
   arrays are the only runtime inputs.  A fused elementwise chain is one
   jitted body with one HBM write — the eager path dispatched every op
   separately.

3. **cache** — compiled plans are keyed by a structural hash (node kinds +
   static params + leaf signatures, NOT leaf data), so hot-loop bodies like
   the PCA power iteration compile once and replay.  The OPTIMIZER is
   cached the same way: a pre-optimization structural key (which also
   encodes leaf aliasing — two uses of the same array must keep CSE-ing)
   maps straight to the optimized plan key + input order, so re-recording a
   structurally-unchanged DAG (every ``compute()`` in a hot loop) skips
   canonicalize/fuse entirely — the remaining per-iteration recording cost
   the ROADMAP flagged after metadata memoization landed.

Block formats: a sparse (bcoo) ``Blockwise`` is a **fusion boundary** — its
fn consumes/produces BCOO block structures, which cannot compose with dense
per-block fns — but sparse nodes still CSE, and sparse plans cache by
structure + nse like any other.
"""

from __future__ import annotations

import contextlib
import os
import sys
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.core import expr as _expr
from repro.core.dsarray import DsArray
from repro.core.expr import (ArrayLeaf, Blockwise, Expr, Leaf, MatMul,
                             Transpose, _is_ds, _is_sparse)
from repro.obs import metrics as _metrics
from repro.obs import tracing as _tracing

# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def emission_order(roots: Sequence[Expr]) -> List[Expr]:
    """Every DAG node in the naive emission order: the child-first,
    left-to-right DFS that ``Plan._make_run``'s ``ev`` memoization actually
    evaluates in.  The analysis layer's 'naive' schedule is exactly this."""
    seen = set()
    order: List[Expr] = []

    def visit(n: Expr) -> None:
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.children:
            visit(c)
        order.append(n)

    for r in roots:
        visit(r)
    return order


def _count_nodes(roots: Sequence[Expr]) -> int:
    seen = set()

    def visit(n):
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.children:
            visit(c)

    for r in roots:
        visit(r)
    return sum(1 for i in seen)


def _rules(n: Expr) -> Expr:
    """Local rewrite rules, applied bottom-up after children are canonical."""
    if isinstance(n, Transpose) and isinstance(n.children[0], Transpose):
        return n.children[0].children[0]
    if isinstance(n, MatMul) and not n.transpose_a \
            and isinstance(n.children[0], Transpose):
        return MatMul(n.children[0].children[0], n.children[1],
                      transpose_a=True)
    if isinstance(n, Blockwise) and n.elementwise and _is_ds(n.meta) \
            and n.children \
            and all(isinstance(c, Transpose) for c in n.children):
        # elementwise only: a position-dependent map_blocks fn does not
        # commute with the block transpose.  Transpose preserves pad
        # constants, so the resolved pad carries over unchanged.
        inner = Blockwise(n.fn, tuple(c.children[0] for c in n.children),
                          ("hoistT", n.key), pad=n.pad, elementwise=True)
        return Transpose(inner)
    return n


def _canonicalize(roots: Sequence[Expr]) -> List[Expr]:
    """Bottom-up rewrite + hash-consing (CSE) over the whole DAG."""
    memo: Dict[int, Expr] = {}
    cons: Dict[tuple, Expr] = {}

    def canon(node: Expr) -> Expr:
        if id(node) in memo:
            return memo[id(node)]
        kids = [canon(c) for c in node.children]
        n2 = node if all(a is b for a, b in zip(kids, node.children)) \
            else node.rebuild(kids)
        n2 = _rules(n2)
        if isinstance(n2, Leaf):
            key = ("leafid", id(n2.value))
        elif isinstance(n2, ArrayLeaf):
            key = ("aleafid", id(n2.value))
        else:
            key = (type(n2).__name__, n2.local_key(),
                   tuple(id(c) for c in n2.children))
        n2 = cons.setdefault(key, n2)
        memo[id(node)] = n2
        return n2

    return [canon(r) for r in roots]


def _use_counts(roots: Sequence[Expr]) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    seen = set()

    def visit(n):
        for c in n.children:
            counts[id(c)] = counts.get(id(c), 0) + 1
            if id(c) not in seen:
                seen.add(id(c))
                visit(c)

    for r in roots:
        counts[id(r)] = counts.get(id(r), 0) + 1
        if id(r) not in seen:
            seen.add(id(r))
            visit(r)
    return counts


def _compose(parent_fn, specs):
    """One per-block function for a fused Blockwise: each spec is
    ('arg', slot) — pass input through — or ('call', child_fn, slots) —
    inline the child's computation."""

    def fused(*args):
        vals = []
        for kind, payload in specs:
            if kind == "arg":
                vals.append(args[payload])
            else:
                cfn, idxs = payload
                vals.append(cfn(*[args[i] for i in idxs]))
        return parent_fn(*vals)

    return fused


def _fuse(roots: Sequence[Expr]) -> Tuple[List[Expr], int]:
    """Fuse single-consumer Blockwise chains into composed Blockwise nodes."""
    counts = _use_counts(roots)
    memo: Dict[int, Expr] = {}
    fused_away = 0

    def fuse(node: Expr) -> Expr:
        nonlocal fused_away
        if id(node) in memo:
            return memo[id(node)]
        kids = [fuse(c) for c in node.children]
        out = node if all(a is b for a, b in zip(kids, node.children)) \
            else node.rebuild(kids)
        if isinstance(out, Blockwise) and _is_ds(out.meta):
            specs, new_children, key_parts = [], [], []
            slot_of: Dict[int, int] = {}
            inlined = 0

            def slot(child: Expr) -> int:
                if id(child) not in slot_of:
                    slot_of[id(child)] = len(new_children)
                    new_children.append(child)
                return slot_of[id(child)]

            for orig_c, new_c in zip(node.children, kids):
                # sparse nodes are fusion boundaries: a BCOO-consuming fn
                # cannot be inlined into a dense per-block body (or vice
                # versa) — data/indices structure is not elementwise state
                fusible = (isinstance(new_c, Blockwise)
                           and _is_ds(new_c.meta)
                           and not _is_sparse(new_c.meta)
                           and not _is_sparse(out.meta)
                           and not any(_is_sparse(gc.meta)
                                       for gc in new_c.children
                                       if _is_ds(gc.meta))
                           and counts.get(id(orig_c), 2) == 1
                           and new_c.meta.blocks.shape == out.meta.blocks.shape
                           and new_c.meta.grid == out.meta.grid)
                if fusible:
                    idxs = [slot(gc) for gc in new_c.children]
                    specs.append(("call", (new_c.fn, idxs)))
                    key_parts.append(("call", new_c.key, tuple(idxs)))
                    inlined += 1
                else:
                    s = slot(new_c)
                    specs.append(("arg", s))
                    key_parts.append(("arg", s))
            if inlined:
                fused_away += inlined
                # the fused node computes exactly what the outer node did,
                # so its pad is the outer node's RESOLVED pad — re-probing
                # the composed fn could wrongly upgrade an explicit DIRTY
                ew = out.elementwise and all(
                    c.elementwise for s, c in zip(specs, kids)
                    if s[0] == "call")
                out = Blockwise(
                    _compose(out.fn, specs), new_children,
                    ("fused", out.key, tuple(key_parts)), pad=out.pad,
                    elementwise=ew)
        memo[id(node)] = out
        return out

    new_roots = [fuse(r) for r in roots]
    return new_roots, fused_away


def optimize(roots: Sequence[Expr]) -> Tuple[List[Expr], Dict[str, int]]:
    before = _count_nodes(roots)
    roots = _canonicalize(roots)
    roots, fused = _fuse(roots)
    # fusion can leave freshly-composed siblings identical: re-cons
    roots = _canonicalize(roots)
    after = _count_nodes(roots)
    return roots, {"nodes_before": before, "nodes_after": after,
                   "fused_elementwise": fused}


# ---------------------------------------------------------------------------
# Detached inputs (so cached compiled plans never pin leaf DATA alive)
# ---------------------------------------------------------------------------


class _Input(Expr):
    """Positional plan input: carries only the leaf's static metadata."""

    __slots__ = ("idx", "is_ds", "grid", "pad")

    def __init__(self, leaf: Expr, idx: int):
        self.idx = idx
        self.is_ds = isinstance(leaf, Leaf)
        if self.is_ds:
            self.grid = leaf.value.grid
            self.pad = leaf.value.pad_state
        else:
            self.grid = self.pad = None
        self.children = ()
        self.meta = leaf.meta        # ShapeDtypeStruct-based: holds no data

    def bind(self, val):
        return DsArray(val, self.grid, self.pad) if self.is_ds else val

    def lower(self):  # pragma: no cover - inputs are bound, not lowered
        raise RuntimeError("plan inputs are bound at execution")

    def rebuild(self, children):
        return self


def _detach(roots: Sequence[Expr], leaves: Sequence[Expr]) -> List[Expr]:
    """Clone the DAG with Leaf/ArrayLeaf replaced by ``_Input`` stubs, so the
    compiled closure references no concrete arrays."""
    memo: Dict[int, Expr] = {
        id(l): _Input(l, i) for i, l in enumerate(leaves)}

    def clone(node: Expr) -> Expr:
        if id(node) in memo:
            return memo[id(node)]
        kids = [clone(c) for c in node.children]
        out = node.rebuild(kids)
        memo[id(node)] = out
        return out

    return [clone(r) for r in roots]


# ---------------------------------------------------------------------------
# Structural plan key + compiled-plan cache
# ---------------------------------------------------------------------------


def _plan_key(roots: Sequence[Expr]) -> Tuple[tuple, List[Expr]]:
    """Linear structural encoding of the DAG + the ordered leaf list.

    Keys capture node kinds, static params and LEAF SIGNATURES (geometry,
    dtype, pad state) — never leaf data — so re-running a structurally
    identical plan on fresh arrays reuses the compiled program.
    """
    entries: List[tuple] = []
    index: Dict[int, int] = {}
    leaves: List[Expr] = []

    def key(node: Expr) -> int:
        if id(node) in index:
            return index[id(node)]
        cids = tuple(key(c) for c in node.children)
        if isinstance(node, (Leaf, ArrayLeaf)):
            leaves.append(node)
            entry = ("input", node.signature())
        else:
            entry = (type(node).__name__, node.local_key(), cids)
        entries.append(entry)
        index[id(node)] = len(entries) - 1
        return index[id(node)]

    rids = tuple(key(r) for r in roots)
    return (tuple(entries), rids), leaves


def _preopt_key(roots: Sequence[Expr]) -> Tuple[tuple, List[Expr]]:
    """Structural key of the RAW (pre-optimization) DAG + its leaf list.

    Same encoding as :func:`_plan_key`, plus an alias-group index per input:
    the optimizer CSEs leaves by value identity, so two recordings that
    differ only in whether two uses share one array must not collide (one
    optimizes to a shared node, the other does not).  The optimized plan is
    a pure function of this key, which is what makes skipping
    re-canonicalization sound.
    """
    entries: List[tuple] = []
    index: Dict[int, int] = {}
    leaves: List[Expr] = []
    alias: Dict[int, int] = {}

    def key(node: Expr) -> int:
        if id(node) in index:
            return index[id(node)]
        cids = tuple(key(c) for c in node.children)
        if isinstance(node, (Leaf, ArrayLeaf)):
            leaves.append(node)
            grp = alias.setdefault(id(node.value), len(alias))
            entry = ("input", node.signature(), grp)
        else:
            entry = (type(node).__name__, node.local_key(), cids)
        entries.append(entry)
        index[id(node)] = len(entries) - 1
        return index[id(node)]

    rids = tuple(key(r) for r in roots)
    return (tuple(entries), rids), leaves


# LRU-bounded: structural keys can embed user fn objects (map_blocks), so a
# loop that records a FRESH lambda per iteration would otherwise grow the
# cache — and pin each jitted executable + closure — without bound.
_CACHE: "OrderedDict[tuple, callable]" = OrderedDict()
# preopt structural key -> (optimized plan key, leaf positions, stats):
# repeat recordings of an unchanged DAG skip canonicalize/CSE/fuse entirely
_OPT_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_CACHE_MAX = 256
# cache-discipline counters, registered as "plan.*" in the obs registry
# (obs.snapshot() sees them; cache_stats() below stays the local view)
_STATS = _metrics.CounterGroup(
    "plan", ("hits", "misses", "launches", "opt_runs", "opt_skips",
             "eager_launches", "aot_compiles"))


def cache_stats() -> Dict[str, int]:
    return _STATS.as_dict()


def clear_cache() -> None:
    _CACHE.clear()
    _OPT_CACHE.clear()
    _STATS.reset()


def _fire(site: str, **info) -> None:
    """Fault-injection hook (see ``repro.resilience.inject``).

    Same zero-overhead idiom as ``DsArray._lazy_mode``: only consult the
    injector when its module is already imported (a chaos test armed it);
    clean runs pay one sys.modules lookup, never an import.
    """
    ri = sys.modules.get("repro.resilience.inject")
    if ri is not None:
        ri.maybe_fire(site, **info)


# Plan observers: the analysis CLI records the plans real workloads build
# (estimator fits call compute_multi internally) by registering a callback
# here.  Empty in normal operation — Plan.__init__ pays one truthiness
# check.
_PLAN_OBSERVERS: List = []


@contextlib.contextmanager
def capture_plans():
    """Collect every ``Plan`` constructed inside the block (post-dedup is
    the caller's job — hot loops re-plan the same structure)."""
    captured: List[Plan] = []
    _PLAN_OBSERVERS.append(captured.append)
    try:
        yield captured
    finally:
        _PLAN_OBSERVERS.remove(captured.append)


class Plan:
    """An optimized, compilable plan over one or more roots.

    Optimization is skipped when a structurally-identical DAG was planned
    before (``_OPT_CACHE``): the cached optimized-plan key + input order are
    reused and the optimized roots are only materialized on demand (for
    ``jaxpr()``/``lowered()`` inspection, or a compiled-cache miss).
    """

    def __init__(self, roots: Sequence[Expr]):
        self.stats: Dict[str, int]
        self._raw_roots = list(roots)
        self._roots: Optional[List[Expr]] = None
        pre_key = None
        raw_leaves: List[Expr] = []
        try:
            pre_key, raw_leaves = _preopt_key(self._raw_roots)
            cached = _OPT_CACHE.get(pre_key)
        except TypeError:            # unhashable static param: no caching
            cached = None
        if cached is not None:
            _OPT_CACHE.move_to_end(pre_key)
            _STATS.inc("opt_skips")
            self.key, positions, stats = cached
            self.stats = dict(stats)
            self.leaves = [raw_leaves[p] for p in positions]
        else:
            self._optimize_now(pre_key, raw_leaves)
        if _PLAN_OBSERVERS:
            for cb in list(_PLAN_OBSERVERS):
                cb(self)

    @property
    def raw_roots(self) -> List[Expr]:
        """The as-recorded (pre-optimization) roots — the plan plane the
        ``recompile-hazard`` rule lints, since canonicalization erases the
        recording artifacts (fresh lambdas, baked scalars) it looks for."""
        return self._raw_roots

    def _optimize_now(self, pre_key=None, raw_leaves=None) -> None:
        _STATS.inc("opt_runs")
        with _tracing.span("plan.optimize",
                           roots=len(self._raw_roots)) as sp:
            opt_roots, self.stats = optimize(self._raw_roots)
            sp.set(nodes_before=self.stats["nodes_before"],
                   nodes_after=self.stats["nodes_after"])
        self.key, self.leaves = _plan_key(opt_roots)
        self._roots = opt_roots
        self.stats["n_inputs"] = len(self.leaves)
        if pre_key is None:
            return
        # optimized leaves are a subset of the raw ones (CSE only merges);
        # record their positions so a later hit can bind fresh leaf values
        pos = {id(l): i for i, l in enumerate(raw_leaves)}
        if all(id(l) in pos for l in self.leaves):
            _OPT_CACHE[pre_key] = (self.key,
                                   tuple(pos[id(l)] for l in self.leaves),
                                   dict(self.stats))
            while len(_OPT_CACHE) > _CACHE_MAX:
                _OPT_CACHE.popitem(last=False)

    @property
    def roots(self) -> List[Expr]:
        if self._roots is None:
            # inspection (or recompilation) after an optimizer-cache hit:
            # re-derive the optimized DAG; same structure => same key/order
            self._optimize_now()
        return self._roots

    def _make_run(self):
        detached = _detach(self.roots, self.leaves)
        n_inputs = len(self.leaves)

        def run(*vals):
            assert len(vals) == n_inputs
            memo: Dict[int, object] = {}

            def ev(node: Expr):
                nid = id(node)
                if nid in memo:
                    return memo[nid]
                if isinstance(node, _Input):
                    out = node.bind(vals[node.idx])
                else:
                    out = node.lower(*[ev(c) for c in node.children])
                memo[nid] = out
                return out

            return tuple(ev(r) for r in detached)

        return run

    def leaf_values(self) -> List:
        return [l.value.blocks if isinstance(l, Leaf) else l.value
                for l in self.leaves]

    def jaxpr(self):
        """make_jaxpr of the compiled body (for tests/inspection)."""
        with _expr.suspend_lazy():
            return jax.make_jaxpr(self._make_run())(*self.leaf_values())

    def lowered(self):
        """jit-lowered (unoptimized-HLO-capable) form for inspection."""
        with _expr.suspend_lazy():
            return jax.jit(self._make_run()).lower(*self.leaf_values())

    def compile_aot(self, donate_argnums: tuple = ()) -> bool:
        """Ahead-of-time compile this plan into the shared compiled-plan
        cache: ``jit(body).lower().compile()`` on the current leaf values'
        avals, keyed by the same structural :attr:`key` ``execute`` looks
        up.  The serving layer calls this at model-load time so the FIRST
        request for a warmed geometry already hits the cache — no request
        ever pays XLA compilation.  Returns True when a fresh executable
        was compiled, False when the key was already cached (idempotent).

        A ``jax.stages.Compiled`` is positionally callable with exactly the
        avals it was lowered on, which the structural key guarantees: any
        ``execute()`` that maps to this key binds leaf values of identical
        geometry/dtype/format, so the warmed executable replays on every
        later request batch.

        ``donate_argnums`` (positions into :attr:`leaves`) marks leaf
        buffers the executable may alias for its outputs — the serving
        layer donates the packed request batch (a per-request temporary),
        which removes one batch-sized HBM copy per predict on accelerators.
        Caveat: the executable lives in the SHARED structural cache, so
        every ``execute()`` mapping to this key consumes the donation —
        donate only leaves that are always per-call temporaries (never
        fitted model state), as the caller's donated buffer is invalidated
        on backends that implement donation (CPU ignores it).
        """
        cached = _CACHE.get(self.key)
        if cached is not None:
            _CACHE.move_to_end(self.key)
            return False
        with _tracing.span("plan.aot_compile", inputs=len(self.leaves),
                           donated=len(tuple(donate_argnums))):
            with _expr.suspend_lazy():
                compiled = jax.jit(
                    self._make_run(),
                    donate_argnums=tuple(donate_argnums)).lower(
                    *self.leaf_values()).compile()
        _STATS.inc("aot_compiles")
        _CACHE[self.key] = compiled
        while len(_CACHE) > _CACHE_MAX:
            _CACHE.popitem(last=False)
        return True

    def execute(self) -> tuple:
        _fire("plan_execute", mode="fused")
        compiled = _CACHE.get(self.key)
        cached = compiled is not None
        if not cached:
            _STATS.inc("misses")
            compiled = jax.jit(self._make_run())
            _CACHE[self.key] = compiled
            while len(_CACHE) > _CACHE_MAX:
                _CACHE.popitem(last=False)
        else:
            _STATS.inc("hits")
            _CACHE.move_to_end(self.key)
        _STATS.inc("launches")
        if _tracing.enabled():
            # fence inside the span so it measures device work, not async
            # dispatch; the disabled path below stays byte-identical
            with _tracing.span("plan.launch", mode="fused", cached=cached,
                               inputs=len(self.leaves)):
                with _expr.suspend_lazy():
                    out = compiled(*self.leaf_values())
                jax.block_until_ready(out)
            return out
        with _expr.suspend_lazy():
            return compiled(*self.leaf_values())

    def execute_eager(self, backend: Optional[str] = None) -> tuple:
        """Per-node un-jitted execution — the degradation rungs.

        The fused jitted plan holds every intermediate of its body live
        inside one XLA launch; when that launch RESOURCE_EXHAUSTs, running
        the same DAG node-by-node (each ``lower`` its own dispatch, memo
        freed per plan) trades launch count for peak footprint.  With
        ``backend`` set (``"einsum"``), local GEMMs additionally bypass the
        Pallas kernel via the ``REPRO_GEMM`` dispatch for the duration of
        this execution.  Results match ``execute()`` modulo float
        reassociation.  Never cached — this is the emergency path.
        """
        _fire("plan_execute", mode=backend or "eager")
        _STATS.inc("eager_launches")
        run = self._make_run()
        if _tracing.enabled():
            with _tracing.span("plan.launch", mode=backend or "eager",
                               inputs=len(self.leaves)):
                out = self._run_eager(run, backend)
                jax.block_until_ready(out)
            return out
        return self._run_eager(run, backend)

    def _run_eager(self, run, backend: Optional[str]) -> tuple:
        if backend is None:
            with _expr.suspend_lazy():
                return run(*self.leaf_values())
        prev = os.environ.get("REPRO_GEMM")
        os.environ["REPRO_GEMM"] = backend
        try:
            with _expr.suspend_lazy():
                return run(*self.leaf_values())
        finally:
            if prev is None:
                os.environ.pop("REPRO_GEMM", None)
            else:
                os.environ["REPRO_GEMM"] = prev


def compute_multi(*exprs: Expr) -> tuple:
    """Evaluate several recorded expressions as ONE plan.

    CSE runs across the roots, so sibling reductions over the same operand
    share a single evaluation of it (and of any fused chain feeding it) —
    the plan-level analogue of the paper's shared task graph.
    """
    roots = [e.expr if isinstance(e, (_expr.LazyDsArray, _expr.LazyScalar))
             else e for e in exprs]
    return Plan(roots).execute()


def compute(e) -> object:
    """Evaluate one recorded expression; DsArray out for ds-shaped plans."""
    return compute_multi(e)[0]


def plan_for(*exprs) -> Plan:
    """The optimized Plan for inspection (stats, jaxpr) without executing."""
    roots = [e.expr if isinstance(e, (_expr.LazyDsArray, _expr.LazyScalar))
             else e for e in exprs]
    return Plan(roots)
