"""Version-compat shims over the moving parts of the jax API.

The repo targets the installed toolchain (jax 0.4.37 here) but is written
against the modern spellings used on TPU pods.  Three surfaces moved between
jax 0.4.x and 0.5+/0.6+:

* ``jax.sharding.AxisType``       — did not exist before 0.5; meshes were
  implicitly ``Auto``.  We expose an ``AxisType`` enum stand-in so call sites
  can always say ``axis_types=(AxisType.Auto,) * n``.
* ``jax.make_mesh(..., axis_types=...)`` — the kwarg is new.  ``make_mesh``
  here forwards it when supported and drops it otherwise (old meshes are
  always Auto, which is what every caller in this repo wants).
* ``jax.shard_map`` / ``check_vma`` — previously
  ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  ``shard_map``
  here resolves the import and translates the flag.

Import from here instead of jax directly in any code that touches mesh
construction or shard_map: ``from repro.core.compat import AxisType,
make_mesh, shard_map``.
"""

from __future__ import annotations

import enum
from typing import Optional, Sequence, Tuple

import jax

__all__ = ["AxisType", "make_mesh", "shard_map", "tpu_compiler_params"]


class _AxisTypeShim(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on jax < 0.5 (all-Auto world)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeShim)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types: Optional[Tuple] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``.

    ``axis_types`` defaults to all-Auto, matching the implicit behaviour of
    old jax; it is forwarded only when the installed jax understands it.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is None:
        axis_types = (AxisType.Auto,) * len(tuple(axis_names))
    try:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types, **kwargs)
    except TypeError:  # jax < 0.5: no axis_types kwarg; meshes are Auto
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across its rename (was ``TPUCompilerParams``).

    Same kwargs either way (``dimension_semantics``, ``vmem_limit_bytes``,
    ...); import is lazy so merely importing compat never pulls in pallas.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


def _resolve_shard_map():
    if hasattr(jax, "shard_map"):  # jax >= 0.6 public spelling
        return jax.shard_map
    try:
        from jax.experimental.shard_map import shard_map as sm
        return sm
    except ImportError:  # pragma: no cover - very old layout
        from jax.sharding import shard_map as sm  # type: ignore
        return sm


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None,
              check_rep: Optional[bool] = None):
    """shard_map with the ``check_vma``/``check_rep`` flag translated.

    Callers pass whichever flag they like; the shim maps it onto what the
    installed jax accepts (the two names denote the same replication check).
    """
    sm = _resolve_shard_map()
    check = check_vma if check_vma is not None else check_rep
    if check is None:
        check = True
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)
