"""Blocked linear algebra on ds-arrays.

The paper's conclusion: "ds-arrays extend dislib's functionality to common
mathematical operations, such as matrix multiplication and decomposition, in
a more natural way than using Datasets".  This module provides the
decomposition side:

* ``pca``        — top-k principal components by subspace (block power)
  iteration: the data matrix is touched ONLY through ds-array matmuls
  (Gram-vector products), so every pass is block-parallel / SUMMA-ready.
* ``frobenius``  — blocked norm.
* ``tsqr``       — tall-skinny QR: per-block-row local QRs + a reduction
  tree over R factors (the paper's Fig. 3 pattern applied to factorization).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsarray import DsArray, from_array


def frobenius(a: DsArray) -> float:
    return float(jnp.sqrt((a * a).sum()))


def pca(x: DsArray, n_components: int, n_iter: int = 30, seed: int = 0
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k PCA of (n_samples × n_features) ds-array.

    Returns (components (k, m), explained_variance (k,)).  Centers the data
    via the ds-array mean (paper Fig. 5 column reduction), then runs
    orthogonal (power) iteration on the Gram operator — only ds-array
    matmuls touch the distributed data.
    """
    n, m = x.shape
    mean = x.mean(axis=0)                         # (1, m) ds-array
    xc = x - _broadcast_rows(mean, n)
    q = jnp.linalg.qr(
        jax.random.normal(jax.random.PRNGKey(seed), (m, n_components)))[0]
    bq = (x.block_shape[1], n_components)
    for _ in range(n_iter):
        y = xc.transpose() @ (xc @ from_array(q, bq))   # (m, k) ds-array
        q, _ = jnp.linalg.qr(y.collect())
    proj = xc @ from_array(q, bq)                 # (n, k)
    var = jnp.asarray((proj * proj).sum(axis=0).collect()).ravel() / (n - 1)
    order = jnp.argsort(-var)
    return q.T[order], var[order]


def _broadcast_rows(row: DsArray, n: int) -> DsArray:
    """(1, m) -> (n, m) ds-array with the row repeated (block-local)."""
    g = row.collect()
    return from_array(jnp.broadcast_to(g, (n, g.shape[1])), (
        max(1, n // max(1, row.stacked_grid[1])), row.block_shape[1]))


def tsqr(x: DsArray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tall-skinny QR: local QR per block-row + an R-merge reduction tree.

    Requires m <= block rows; returns (q (n, m) dense, r (m, m)).
    """
    n, m = x.shape
    gn = x.stacked_grid[0]
    # local QR per block-row (one 'task' per block-row)
    blocks = np.array_split(np.asarray(x.collect()), gn, axis=0)
    qs, rs = zip(*[np.linalg.qr(b) for b in blocks])
    # reduction tree over stacked R factors (paper Fig. 3)
    level_q = list(qs)
    level_r = list(rs)
    while len(level_r) > 1:
        nq, nr = [], []
        for i in range(0, len(level_r) - 1, 2):
            stacked = np.concatenate([level_r[i], level_r[i + 1]], axis=0)
            q2, r2 = np.linalg.qr(stacked)
            nq.append((q2[:m], q2[m:]))
            nr.append(r2)
        merged_q = []
        for j, (qa, qb) in enumerate(nq):
            merged_q.append(np.concatenate(
                [level_q[2 * j] @ qa, level_q[2 * j + 1] @ qb], axis=0))
        if len(level_r) % 2:
            merged_q.append(level_q[-1])
            nr.append(level_r[-1])
        level_q = merged_q
        level_r = nr
    q = np.concatenate(level_q, axis=0)
    return jnp.asarray(q), jnp.asarray(level_r[0])
