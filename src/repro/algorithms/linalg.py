"""Blocked linear algebra on ds-arrays.

The paper's conclusion: "ds-arrays extend dislib's functionality to common
mathematical operations, such as matrix multiplication and decomposition, in
a more natural way than using Datasets".  This module provides the
decomposition side:

* ``pca``        — top-k principal components by subspace (block power)
  iteration: the data matrix is touched ONLY through ds-array matmuls
  (Gram-vector products) and a block-native row broadcast, so every pass is
  block-parallel / SUMMA-ready and the (n, m) data never materializes as a
  global rank-2 tensor or leaves the devices.
* ``frobenius``  — blocked norm.
* ``tsqr``       — tall-skinny QR: a vmapped, device-resident local QR per
  block-row + a reduction tree over R factors (the paper's Fig. 3 pattern
  applied to factorization).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import BlockGrid, ceil_div
from repro.core.dsarray import DsArray, PAD_ZERO, from_array
from repro.estimators.base import BaseEstimator


def frobenius(a: DsArray) -> float:
    return float(jnp.sqrt((a * a).sum()))


def _broadcast_rows(row: DsArray, n: int, bn: Optional[int] = None) -> DsArray:
    """(1, m) -> (n, m) ds-array with the row repeated, block-natively.

    The seed path did ``collect()`` + ``from_array`` — a global (n, m)
    re-block of the broadcast, the exact O(n·m) materialization anti-pattern
    PR 1 removed from k-means/ALS.  Here the (1, bm) row tile is broadcast
    per block straight into the stacked layout (and sharding survives under
    jit); only the pad rows of the last block row need masking.
    """
    if row.shape[0] != 1:
        raise ValueError(f"_broadcast_rows wants a (1, m) row, got {row.shape}")
    row = row.ensure_zero_pad()
    m = row.shape[1]
    bm = row.block_shape[1]
    bn = bn or min(max(1, n), 512)
    gn = max(1, ceil_div(n, bn))
    tile = row.blocks[:1]                          # (1, gm, 1, bm)
    blocks = jnp.broadcast_to(tile, (gn, tile.shape[1], bn, bm))
    if gn * bn > n:                                # zero the broadcast pad rows
        from repro.core.structural import _mask_axes
        blocks = _mask_axes(blocks, n=n)
    return DsArray(blocks, BlockGrid((n, m), (bn, bm)), PAD_ZERO)


@dataclasses.dataclass
class PCA(BaseEstimator):
    """Estimator form of :func:`pca` under the ``repro.estimators``
    contract: ``fit`` stores ``components_ (k, m)`` and
    ``explained_variance_ (k,)``; ``transform`` projects through the
    block-native matmul (``sp @ dense`` for bcoo inputs — with
    ``center=False`` the data matrix is never densified, the TruncatedSVD
    convention); ``score`` is the mean explained variance of the kept
    subspace."""

    n_components: int = 2
    n_iter: int = 30
    seed: int = 0
    center: bool = True

    components_: Optional[jnp.ndarray] = None
    explained_variance_: Optional[jnp.ndarray] = None
    mean_: Optional[np.ndarray] = None

    def fit(self, x, y=None) -> "PCA":
        del y
        with self._driver_scope():
            x = self._validate_x(x)
            if self.center:
                # the TRAINING mean is fitted state (transform must center
                # new data by it, not by the batch's own mean); center HERE
                # and hand pca() the centered array so the column reduction
                # runs once per fit, not once per layer
                mean_row = x.mean(axis=0)
                self.mean_ = np.asarray(mean_row.collect(), np.float32)
                x = x - _broadcast_rows(mean_row, x.shape[0],
                                        x.block_shape[0])
            else:
                self.mean_ = None
            self.components_, self.explained_variance_ = pca(
                x, self.n_components, n_iter=self.n_iter, seed=self.seed,
                center=False)
        return self

    def transform(self, x) -> DsArray:
        """Project onto the fitted components (centered by the mean stored
        at fit): an (n, k) ds-array."""
        self._check_fitted("components_")
        with self._driver_scope():
            x = self._validate_x(x)
            comp = self.components_
            if self.center:
                mean = from_array(jnp.asarray(self.mean_).reshape(1, -1),
                                  (1, x.block_shape[1]))
                x = x - _broadcast_rows(mean, x.shape[0], x.block_shape[0])
            w = from_array(jnp.asarray(comp).T, (x.block_shape[1],
                                                 comp.shape[0]))
            return x @ w

    def fit_transform(self, x, y=None) -> DsArray:
        return self.fit(x, y).transform(x)

    def score(self, x, y=None) -> float:
        del x, y
        self._check_fitted("components_")
        return float(jnp.mean(self.explained_variance_))


def pca(x: DsArray, n_components: int, n_iter: int = 30, seed: int = 0,
        center: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k PCA of (n_samples × n_features) ds-array.

    (The functional form; :class:`PCA` is the estimator-contract wrapper
    over exactly this routine.)

    Returns (components (k, m), explained_variance (k,)).  Centers the data
    via the ds-array mean (paper Fig. 5 column reduction) subtracted through
    a block-native row broadcast, then runs orthogonal (power) iteration on
    the Gram operator.  The iteration body ``xcᵀ @ (xc @ q)`` is recorded
    through the lazy expression layer: the optimizer folds the transpose
    into the GEMM block-index maps (``matmul_ta`` — the transposed stacked
    tensor is never materialized in HBM) and the structurally-hashed plan
    compiles ONCE and replays every iteration; only the small (m, k) QR
    runs outside the plan.

    BCOO-blocked inputs: centering destroys sparsity (sparse − dense
    densifies by policy), so pass ``center=False`` for sparse data — the
    power iteration then runs entirely through ``spᵀ @ (sp @ q)``
    bcoo_dot_generals and the stored entries are never densified (the
    TruncatedSVD convention for exactly this reason).
    """
    n, m = x.shape
    if center:
        mean = x.mean(axis=0)                     # (1, m) ds-array
        xc = x - _broadcast_rows(mean, n, x.block_shape[0])
    else:
        xc = x
    bq = (x.block_shape[1], n_components)

    xl = xc.lazy()
    q = jnp.linalg.qr(
        jax.random.normal(jax.random.PRNGKey(seed), (m, n_components)))[0]
    for _ in range(n_iter):
        y = (xl.T @ (xl @ from_array(q, bq))).compute()  # (m, k) ds-array
        q = jnp.linalg.qr(y.collect())[0]                # (m, k): small, local
    proj = xc @ from_array(q, bq)                 # (n, k)
    var = jnp.asarray((proj * proj).sum(axis=0).collect()).ravel() / (n - 1)
    order = jnp.argsort(-var)
    return q.T[order], var[order]


def tsqr(x: DsArray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Tall-skinny QR: local QR per block-row + an R-merge reduction tree.

    The leaf level is a single ``jax.vmap(jnp.linalg.qr)`` over the stacked
    block tensor — device-resident and block-parallel (the seed looped
    ``np.linalg.qr`` over host splits of ``collect()``).  The log-depth
    R-merge tree then works on (2m, m) stacks.  Requires m <= block rows and
    a (numerically) full-rank input; returns (q (n, m) dense, r (m, m)).
    """
    n, m = x.shape
    if x.is_sparse:
        x = x.todense()    # per-block QR factors are dense whatever the input
    if x.block_shape[1] != m:
        x = x.rechunk((x.block_shape[0], m))
    x = x.ensure_zero_pad()
    bn = x.block_shape[0]
    gn = max(1, ceil_div(n, bn))
    stacked = x.blocks[:gn, 0]                     # (gn, bn, m), tail zero-pad
    # leaf level: one QR per block-row, vmapped (zero pad rows of the tail
    # block factor out: QR = A R^{-1} keeps them zero for full-rank A)
    qs, rs = jax.vmap(jnp.linalg.qr)(stacked)      # (gn, bn, m), (gn, m, m)
    level_q = [qs[i] for i in range(gn)]
    level_r = [rs[i] for i in range(gn)]
    # reduction tree over stacked R factors (paper Fig. 3), device-resident
    while len(level_r) > 1:
        nq, nr = [], []
        for i in range(0, len(level_r) - 1, 2):
            pair = jnp.concatenate([level_r[i], level_r[i + 1]], axis=0)
            q2, r2 = jnp.linalg.qr(pair)
            nq.append((q2[:m], q2[m:]))
            nr.append(r2)
        merged_q = []
        for j, (qa, qb) in enumerate(nq):
            merged_q.append(jnp.concatenate(
                [level_q[2 * j] @ qa, level_q[2 * j + 1] @ qb], axis=0))
        if len(level_r) % 2:
            merged_q.append(level_q[-1])
            nr.append(level_r[-1])
        level_q = merged_q
        level_r = nr
    q = jnp.concatenate(level_q, axis=0)[:n]       # drop the tail pad rows
    return q, level_r[0]
