"""K-means on ds-arrays (paper §5.5) + the Dataset-baseline variant.

The paper uses K-means as the control experiment: its parallelization
(per-partition partial sums + a reduction) is representation-neutral, so
ds-arrays must match Datasets.  Here the per-block-row "tasks" are one fused
SPMD op over the stacked block tensor; the reduction tree becomes a psum over
the `data` mesh axis when sharded.

The hot inner loop (pairwise distances + argmin + one-hot partial sums) is
also available as a fused Pallas kernel (``repro.kernels.kmeans``) — that is
the TPU adaptation of the paper's per-Subset task.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse
from jax.experimental.sparse import BCOO

from repro.core.dsarray import DsArray, from_array
from repro.core.dataset_baseline import Dataset
from repro.estimators.base import BaseEstimator, _FitCheckpoint, \
    _fire, _iter_span


def _row_sq_norms(x: DsArray) -> jnp.ndarray:
    """Per-row squared norms ``(gn, bn)`` via ONE fused lazy plan.

    ``(x*x).sum(axis=1)`` recorded lazily lowers to a single jitted
    square+row-reduce pass over the stacked tensor (mul fused into the
    reduction, zero remasks on the ZERO pad).  The assignment step is
    ``‖x‖² − 2·x·cᵀ + ‖c‖²``: ``‖x‖²`` does not change across Lloyd
    iterations, so it is computed once here and threaded through
    ``_center_stats`` instead of being re-derived per iteration (and the
    structurally-hashed plan is shared by fit/predict/score)."""
    s = (x.lazy() * x).sum(axis=1).compute()        # (n, 1) ds-array
    gn, bn = x.blocks.shape[0], x.blocks.shape[2]
    return s.blocks.reshape(gn, bn).astype(jnp.float32)


def _dots(blocks, c_blocks: jnp.ndarray) -> jnp.ndarray:
    """``x · cᵀ`` summed over feature blocks: (gn, bn, k).

    A BCOO-blocked x contracts its stored entries directly against the
    center blocks (one ``bcoo_dot_general`` over the (gm, bm) feature dims
    — nnz-proportional work, the CSVM/k-means payoff of sparse blocks); the
    dense stacked tensor keeps the einsum.
    """
    if isinstance(blocks, BCOO):
        return jsparse.bcoo_dot_general(
            blocks, c_blocks, dimension_numbers=(((1, 3), (1, 2)), ((), ())))
    return jnp.einsum("ijab,kjb->iak", blocks, c_blocks,
                      preferred_element_type=jnp.float32)


def _center_stats(blocks, row_valid: jnp.ndarray,
                  centers: jnp.ndarray, x_sq: jnp.ndarray,
                  n_cols: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Distance + assign + partial sums over the stacked block tensor.

    blocks:    (gn, gm, bn, bm) feature-blocked samples (pad = 0), dense
               stacked tensor OR stacked BCOO (sparse rows never densify:
               both contractions run through bcoo_dot_general)
    row_valid: (gn, bn) bool
    centers:   (k, m_padded)    pad columns zero
    x_sq:      (gn, bn) per-row squared norms (see ``_row_sq_norms``)
    returns (labels (gn, bn), sums (k, m_padded), counts (k,))
    """
    gn, gm, bn, bm = blocks.shape
    k = centers.shape[0]
    c_blocks = centers.reshape(k, gm, bm)
    dots = _dots(blocks, c_blocks)                          # (gn, bn, k)
    c_sq = jnp.einsum("km,km->k", centers, centers,
                      preferred_element_type=jnp.float32)
    dist = x_sq[..., None] - 2.0 * dots + c_sq[None, None, :]
    labels = jnp.argmin(dist, axis=-1)                      # (gn, bn)
    onehot = jax.nn.one_hot(labels, k, dtype=blocks.dtype)  # (gn, bn, k)
    onehot = onehot * row_valid[..., None].astype(blocks.dtype)
    if isinstance(blocks, BCOO):
        # onehotᵀ · x with the SPARSE side on the left (the dense-lhs form
        # hits a jax-0.4.37 bcoo batching bug): contract the (gn, bn)
        # sample dims -> (gm, bm, k), then relabel to (k, gm*bm)
        sums = jsparse.bcoo_dot_general(
            blocks, onehot, dimension_numbers=(((0, 2), (0, 1)), ((), ())))
        sums = sums.transpose(2, 0, 1).reshape(k, gm * bm)
    else:
        sums = jnp.einsum("iak,ijab->kjb", onehot, blocks,
                          preferred_element_type=jnp.float32)
        sums = sums.reshape(k, gm * bm)
    counts = onehot.sum(axis=(0, 1))
    return labels, sums, counts


@functools.partial(jax.jit, static_argnames=("n_cols", "tol", "max_iter"))
def _kmeans_run(blocks, centers0, row_valid, x_sq, n_cols, tol, max_iter):
    """Lloyd iterations as a jitted while_loop (module-level so repeated
    ``fit`` calls hit the jit cache)."""

    def cond(state):
        _, shift, it = state
        return (shift > tol) & (it < max_iter)

    def body(state):
        centers, _, it = state
        _, sums, counts = _center_stats(blocks, row_valid, centers,
                                        x_sq, n_cols)
        safe = jnp.maximum(counts, 1.0)[:, None]
        new = jnp.where(counts[:, None] > 0, sums / safe, centers)
        shift = jnp.sqrt(((new - centers) ** 2).sum())
        return new, shift, it + 1

    return jax.lax.while_loop(cond, body, (centers0, jnp.float32(jnp.inf), 0))


@functools.partial(jax.jit, static_argnames=("n_cols",))
def _kmeans_step(blocks, centers, row_valid, x_sq, n_cols):
    """ONE Lloyd iteration (same math as ``_kmeans_run``'s body) — the
    host-driven loop used when per-iteration checkpointing is requested,
    where the device-resident while_loop cannot yield control."""
    _, sums, counts = _center_stats(blocks, row_valid, centers, x_sq, n_cols)
    safe = jnp.maximum(counts, 1.0)[:, None]
    new = jnp.where(counts[:, None] > 0, sums / safe, centers)
    shift = jnp.sqrt(((new - centers) ** 2).sum())
    return new, shift


def _kmeanspp_init(x: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    """k-means++ seeding (Arthur & Vassilvitskii) — D² sampling."""
    n = x.shape[0]
    centers = [x[rng.integers(n)]]
    d2 = ((x - centers[0]) ** 2).sum(-1)
    for _ in range(1, k):
        tot = d2.sum()
        # degenerate data (every remaining point coincides with a center):
        # D² sampling is undefined, fall back to uniform
        p = d2 / tot if tot > 0 else np.full(n, 1.0 / n)
        centers.append(x[rng.choice(n, p=p)])
        d2 = np.minimum(d2, ((x - centers[-1]) ** 2).sum(-1))
    return np.stack(centers).astype(x.dtype)


@functools.partial(jax.jit)
def _d2_to_center(blocks, row_valid: jnp.ndarray,
                  center: jnp.ndarray, x_sq: jnp.ndarray) -> jnp.ndarray:
    """Per-row squared distance to one center, over the stacked tensor.

    ``center`` is the (gm*bm,)-padded row; both the block pad and the center
    pad are zero, so the squared difference vanishes on pad columns.  Dense
    blocks use the numerically-nicer squared-difference einsum; BCOO blocks
    use the ``‖x‖² − 2·x·c + ‖c‖²`` expansion so only stored entries are
    touched.  Returns (gn, bn) with invalid rows zeroed.
    """
    gn, gm, bn, bm = blocks.shape
    c_blocks = center.reshape(gm, bm)
    if isinstance(blocks, BCOO):
        dots = jsparse.bcoo_dot_general(
            blocks, c_blocks, dimension_numbers=(((1, 3), (0, 1)), ((), ())))
        c_sq = jnp.sum(center * center)
        d2 = jnp.maximum(x_sq - 2.0 * dots + c_sq, 0.0)
    else:
        diff = blocks - c_blocks[None, :, None, :]
        d2 = jnp.einsum("ijab,ijab->ia", diff, diff,
                        preferred_element_type=jnp.float32)
    return d2 * row_valid.astype(d2.dtype)


def _kmeanspp_init_ds(x: DsArray, k: int, rng: np.random.Generator,
                      row_valid: jnp.ndarray, x_sq: jnp.ndarray) -> jnp.ndarray:
    """Block-native k-means++: never materializes the global array.

    The seed version did ``x.collect()`` — O(n·m) single-host memory, the
    exact materialization tax the ds-array is meant to avoid.  Here each D²
    pass is one fused op over the stacked tensor (nnz-proportional for BCOO
    blocks); only the O(n) distance vector and the O(m) chosen rows ever
    reach the host.
    """
    n, m = x.shape
    gn, gm, bn, bm = x.blocks.shape

    def fetch_row(i: int) -> jnp.ndarray:
        if x.is_sparse:
            # one block row's stored entries scatter into the padded row
            from repro.core import sparse as sparse_mod
            return sparse_mod.fetch_row_dense(x, int(i))
        # block-native single-row gather -> (1, m) -> padded (gm*bm,)
        row = x[int(i)].collect().ravel()
        return jnp.pad(row, (0, gm * bm - m))

    centers = [fetch_row(int(rng.integers(n)))]
    d2 = _d2_to_center(x.blocks, row_valid, centers[0], x_sq)
    for _ in range(1, k):
        d = np.maximum(np.asarray(d2, dtype=np.float64).reshape(-1)[:n], 0.0)
        tot = d.sum()
        # degenerate data (all rows coincide with a center): uniform fallback
        p = d / tot if tot > 0 else np.full(n, 1.0 / n)
        centers.append(fetch_row(int(rng.choice(n, p=p))))
        d2 = jnp.minimum(d2, _d2_to_center(x.blocks, row_valid, centers[-1],
                                           x_sq))
    return jnp.stack(centers)[:, : gm * bm]


@dataclasses.dataclass
class KMeans(BaseEstimator):
    """dislib-style estimator: ``KMeans(...).fit(x)`` with x a ds-array.

    Implements the ``repro.estimators`` contract (``get_params`` /
    ``set_params`` from the dataclass fields, trailing-underscore fitted
    state); ``score`` is the clustering convention (negative inertia)
    rather than the classifier/regressor mixins'."""

    n_clusters: int = 8
    max_iter: int = 20
    tol: float = 1e-4
    seed: int = 0

    centers_: Optional[jnp.ndarray] = None
    n_iter_: int = 0

    def _row_valid(self, x: DsArray) -> jnp.ndarray:
        gn, gm, bn, bm = x.blocks.shape
        gi = jax.lax.broadcasted_iota(jnp.int32, (gn, bn), 0)
        bi = jax.lax.broadcasted_iota(jnp.int32, (gn, bn), 1)
        return (gi * bn + bi) < x.shape[0]

    def fit(self, x: DsArray, y=None, checkpoint_dir: Optional[str] = None,
            resume: Optional[str] = None) -> "KMeans":
        del y                     # unsupervised; kept for the fit(x, y) shape
        with self._driver_scope():
            return self._fit(x, checkpoint_dir=checkpoint_dir, resume=resume)

    def _fit(self, x: DsArray, checkpoint_dir: Optional[str] = None,
             resume: Optional[str] = None) -> "KMeans":
        x = self._validate_x(x).ensure_zero_pad()  # contractions read raw blocks
        n, m = x.shape
        row_valid = self._row_valid(x)
        # assignment-step invariant ‖x‖², hoisted out of the Lloyd loop and
        # computed by one fused lazy plan (was re-derived every iteration);
        # for BCOO blocks the lazy plan is the sparse x*x -> row-sum pair,
        # and the init + Lloyd contractions below never densify x
        x_sq = _row_sq_norms(x)
        # block-native k-means++ init (k D² passes, each one fused op over the
        # stacked tensor; no x.collect() — the array never leaves the devices)
        init = _kmeanspp_init_ds(x, self.n_clusters,
                                 np.random.default_rng(self.seed), row_valid,
                                 x_sq)
        if checkpoint_dir is None and resume is None:
            # clean path: the device-resident jitted while_loop, untouched —
            # the iterations live inside ONE launch, so the trace gets one
            # fit.loop span instead of per-iteration fit.iteration spans
            from repro.obs import tracing as _tracing
            with _tracing.span("fit.loop", estimator=type(self).__name__,
                               max_iter=self.max_iter):
                centers, _, iters = _kmeans_run(x.blocks, init, row_valid,
                                                x_sq, m, self.tol,
                                                self.max_iter)
            self.centers_ = centers[:, :m]
            self.n_iter_ = int(iters)
            return self
        # checkpointing path: Lloyd driven from the host (one jitted step
        # per iteration, same math) so every iteration can commit
        centers = init
        it = 0
        start_it = 1
        done = False
        if resume is not None:
            got = _FitCheckpoint(resume, type(self).__name__).load()
            if got is not None:
                it0, st = got
                centers = jnp.asarray(st["centers"])
                it = it0
                done = bool(st["done"])
                start_it = it0 + 1
        ckpt = _FitCheckpoint(checkpoint_dir, type(self).__name__) \
            if checkpoint_dir is not None else None
        if not done:
            for it in range(start_it, self.max_iter + 1):
                _fire("fit_iteration", estimator=type(self).__name__,
                      iteration=it)
                with _iter_span(self, it):
                    centers, shift = _kmeans_step(x.blocks, centers, row_valid,
                                                  x_sq, m)
                    done = bool(shift <= self.tol)
                    if ckpt is not None:
                        ckpt.save(it, {"centers": centers, "done": done})
                    if done:
                        break
        self.centers_ = centers[:, :m]
        self.n_iter_ = it
        return self

    def predict(self, x: DsArray) -> DsArray:
        """Labels as a new (n, 1) ds-array — the paper's API fix (predict
        returns a NEW distributed array instead of mutating the input)."""
        self._check_fitted("centers_")
        with self._driver_scope():
            x = self._validate_x(x).ensure_zero_pad()
            gn, gm, bn, bm = x.blocks.shape
            m_pad = gm * bm
            centers = jnp.pad(self.centers_,
                              ((0, 0), (0, m_pad - self.centers_.shape[1])))
            labels, _, _ = _center_stats(x.blocks, self._row_valid(x),
                                         centers, _row_sq_norms(x),
                                         x.shape[1])
            flat = labels.reshape(-1, 1).astype(jnp.int32)[: x.shape[0]]
            return from_array(flat, (x.block_shape[0], 1))

    def score(self, x: DsArray, y=None) -> float:
        """Negative inertia (sum of squared distances to nearest center)."""
        del y
        self._check_fitted("centers_")
        x = self._validate_x(x).ensure_zero_pad()
        gn, gm, bn, bm = x.blocks.shape
        m_pad = gm * bm
        centers = jnp.pad(self.centers_, ((0, 0), (0, m_pad - self.centers_.shape[1])))
        c_blocks = centers.reshape(-1, gm, bm)
        dots = _dots(x.blocks, c_blocks)
        x_sq = _row_sq_norms(x)
        c_sq = jnp.einsum("km,km->k", centers, centers)
        dist = x_sq[..., None] - 2 * dots + c_sq[None, None, :]
        best = dist.min(axis=-1)
        best = best * self._row_valid(x)
        return float(-best.sum())


# ---------------------------------------------------------------------------
# Dataset-baseline K-means (paper Fig. 9 parity experiment)
# ---------------------------------------------------------------------------


def kmeans_dataset(ds: Dataset, n_clusters: int, max_iter: int = 20,
                   tol: float = 1e-4, seed: int = 0) -> np.ndarray:
    """K-means with the Dataset task structure: one partial-sum task per
    Subset + a binary reduction tree per iteration (paper Fig. 3)."""
    rng = np.random.default_rng(seed)
    all_rows = ds.collect()
    centers = _kmeanspp_init(all_rows, n_clusters, rng)
    for _ in range(max_iter):
        def partial(x, centers=centers):
            d = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
            lab = d.argmin(1)
            oh = np.eye(n_clusters, dtype=x.dtype)[lab]
            return np.concatenate([oh.T @ x, oh.sum(0)[:, None]], axis=1)

        partials = ds.map_subsets(partial)
        tot = ds.reduce(partials, np.add)
        sums, counts = tot[:, :-1], tot[:, -1]
        new = np.where(counts[:, None] > 0, sums / np.maximum(counts, 1)[:, None],
                       centers)
        shift = float(np.sqrt(((new - centers) ** 2).sum()))
        centers = new
        if shift < tol:
            break
    return centers
