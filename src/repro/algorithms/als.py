"""Alternating Least Squares on ds-arrays (paper §5.3).

The paper's point: ALS alternates row- and column-access to the ratings
matrix.  Datasets (row-partitioned) must materialize a transposed COPY
(N^2+N tasks + 2x memory); ds-arrays block both axes, so the column pass is
just the transpose view — on TPU, grid-dim swaps that XLA lowers to a single
collective (or zero, since ``R.T @ U`` contracts over the SAME axis layout).

Model: weighted-regularized dense ALS (Hu/Koren/Volinsky form with uniform
weights at container scale; the Netflix run in the paper is sparse — see
DESIGN.md §2 for the density adaptation note):

    U <- R  V (VᵀV + λI)⁻¹
    V <- Rᵀ U (UᵀU + λI)⁻¹

``f`` (latent factors) is small, so the Gram matrices are replicated; the
big products R@V / Rᵀ@U are ds-array matmuls (SUMMA/Cannon under the mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dsarray import DsArray, from_array, random_array
from repro.core.dataset_baseline import Dataset
from repro.core.structural import gram
from repro.estimators.base import BaseEstimator, _FitCheckpoint, \
    _fire, _iter_span


def _solve_gram_ds(y: DsArray, reg: float) -> jnp.ndarray:
    """(YᵀY + λI)⁻¹ with the Gram computed block-natively (no collect()).

    ``core.structural.gram`` does one einsum over the stacked tensor — the
    per-block partial-Gram tasks of the paper, psum'd over the grid — so the
    (n, f) factor matrix never materializes on one host.
    """
    f = y.shape[1]
    g = gram(y) + reg * jnp.eye(f, dtype=y.dtype)
    return jnp.linalg.inv(g)


@dataclasses.dataclass
class ALS(BaseEstimator):
    """dislib-style estimator: ``ALS(...).fit(r)`` with r an (n×m) ds-array.

    Implements the ``repro.estimators`` contract; ``predict(i, j)`` keeps
    the recommender signature (a single rating) rather than the row-wise
    classifier/regressor shape, and ``score(r)`` is the negative
    reconstruction RMSE."""

    n_factors: int = 16
    reg: float = 0.1
    max_iter: int = 10
    tol: float = 1e-4
    seed: int = 0
    check_convergence: bool = True

    u_: Optional[DsArray] = None
    v_: Optional[DsArray] = None
    n_iter_: int = 0

    def fit(self, r: DsArray, y=None, checkpoint_dir: Optional[str] = None,
            resume: Optional[str] = None) -> "ALS":
        del y                     # the ratings matrix IS the target
        with self._driver_scope():
            return self._fit(r, checkpoint_dir=checkpoint_dir, resume=resume)

    def _fit(self, r: DsArray, checkpoint_dir: Optional[str] = None,
             resume: Optional[str] = None) -> "ALS":
        r = self._validate_x(r)
        n, m = r.shape
        f = self.n_factors
        key = jax.random.PRNGKey(self.seed)
        ku, kv = jax.random.split(key)
        bn = r.block_shape[0]
        bm = r.block_shape[1]
        # factor matrices blocked along their long axis only
        u = random_array(ku, (n, f), (bn, f)) * 0.1
        v = random_array(kv, (m, f), (bm, f)) * 0.1
        rt = r.transpose()  # ds-array transpose: grid swap, one fused op

        prev = jnp.float32(jnp.inf)
        it = 0
        start_it = 1
        if resume is not None:
            got = _FitCheckpoint(resume, type(self).__name__).load()
            if got is not None:
                it0, st = got
                u, v = st["u"], st["v"]
                prev = jnp.float32(st["prev"])
                if bool(st["done"]):
                    self.u_, self.v_, self.n_iter_ = u, v, it0
                    return self
                start_it = it0 + 1
                it = it0
        ckpt = _FitCheckpoint(checkpoint_dir, type(self).__name__) \
            if checkpoint_dir is not None else None
        for it in range(start_it, self.max_iter + 1):
            _fire("fit_iteration", estimator=type(self).__name__,
                  iteration=it)
            with _iter_span(self, it):
                u, v = self._step(r, rt, u, v)
                done = False
                if self.check_convergence:
                    err = self._rmse(r, u, v)
                    done = abs(prev - err) < self.tol
                    prev = err
                if ckpt is not None:
                    ckpt.save(it, {"u": u, "v": v, "prev": float(prev),
                                   "done": bool(done)})
                if done:
                    break
        self.u_, self.v_, self.n_iter_ = u, v, it
        return self

    @staticmethod
    @jax.jit
    def _step_jit(r: DsArray, rt: DsArray, u: DsArray, v: DsArray,
                  reg: float) -> Tuple[DsArray, DsArray]:
        vg = _solve_gram_ds(v, reg)             # (f, f) replicated, no collect
        u_new = (r @ v) @ from_array(vg, (v.block_shape[1], v.block_shape[1]))
        ug = _solve_gram_ds(u_new, reg)
        v_new = (rt @ u_new) @ from_array(ug, (u_new.block_shape[1],
                                               u_new.block_shape[1]))
        return u_new, v_new

    def _step(self, r, rt, u, v):
        return ALS._step_jit(r, rt, u, v, self.reg)

    def _rmse(self, r: DsArray, u: DsArray, v: DsArray) -> float:
        pred = u @ v.transpose()
        diff = pred - r
        return float(jnp.sqrt((diff * diff).sum() / (r.shape[0] * r.shape[1])))

    def predict(self, i: int, j: int) -> float:
        """Predicted rating for (row i, col j)."""
        self._check_fitted("u_")
        with self._driver_scope():
            return float(
                (self.u_[i] @ self.v_[j].transpose()).collect()[0, 0])

    def score(self, r: DsArray, y=None) -> float:
        """Negative reconstruction RMSE (higher is better)."""
        del y
        self._check_fitted("u_")
        with self._driver_scope():
            return -self._rmse(self._validate_x(r), self.u_, self.v_)


# ---------------------------------------------------------------------------
# Dataset-baseline ALS: identical math, but the column pass must build the
# transposed Dataset via the N^2+N task path (the paper's bottleneck).
# ---------------------------------------------------------------------------


def als_dataset(ds: Dataset, n_factors: int = 16, reg: float = 0.1,
                max_iter: int = 10, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    r = ds.collect()
    n, m = r.shape
    u = rng.normal(size=(n, n_factors)).astype(r.dtype) * 0.1
    v = rng.normal(size=(m, n_factors)).astype(r.dtype) * 0.1
    ds_t = ds.transpose()  # N^2 + N tasks, 2x memory (the paper's complaint)
    for _ in range(max_iter):
        vg = np.linalg.inv(v.T @ v + reg * np.eye(n_factors, dtype=r.dtype))
        partial_u = ds.map_subsets(lambda x: x @ v)
        u = np.concatenate(partial_u, axis=0) @ vg
        ug = np.linalg.inv(u.T @ u + reg * np.eye(n_factors, dtype=r.dtype))
        partial_v = ds_t.map_subsets(lambda x: x @ u)
        v = np.concatenate(partial_v, axis=0) @ ug
    return u, v
