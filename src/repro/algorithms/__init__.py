"""Distributed ML algorithms built on ds-arrays (paper §5).

Every class here implements the ``repro.estimators`` contract
(``BaseEstimator``: fit/predict/score + get_params/set_params); the
estimator collection proper (CSVM, linear models, random forest) lives in
``repro.estimators``.
"""

from repro.algorithms.kmeans import KMeans, kmeans_dataset
from repro.algorithms.als import ALS, als_dataset
from repro.algorithms.linalg import PCA, frobenius, pca, tsqr

__all__ = ["KMeans", "kmeans_dataset", "ALS", "als_dataset",
           "PCA", "pca", "tsqr", "frobenius"]
