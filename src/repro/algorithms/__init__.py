"""Distributed ML algorithms built on ds-arrays (paper §5)."""

from repro.algorithms.kmeans import KMeans, kmeans_dataset
from repro.algorithms.als import ALS, als_dataset

__all__ = ["KMeans", "kmeans_dataset", "ALS", "als_dataset"]
