"""Production mesh definitions.

Single pod: (16, 16) = ("data", "model") — 256 chips (TPU v5e pod).
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips.

Functions, not module constants: importing this module never touches jax
device state (required so smoke tests see 1 CPU device).

Mesh construction goes through ``repro.core.compat.make_mesh`` so the same
call works on jax versions with and without ``axis_types``.
"""

from __future__ import annotations

from typing import Tuple

import jax

from repro.core.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def dp_axes(multi_pod: bool = False) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_host_mesh(shape: Tuple[int, ...] = None, axes=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n) if n > 1 else (1, 1)
        axes = ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))
