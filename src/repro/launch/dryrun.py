import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh (16×16 single-pod or 2×16×16
multi-pod), constructs abstract inputs (ShapeDtypeStruct — zero allocation),
jits the appropriate step with explicit in/out shardings, and runs
``.lower().compile()``.  Success proves the distribution config is coherent;
``memory_analysis()`` proves it fits; ``cost_analysis()`` + the HLO
collective parse feed §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --multi-pod both --out results/dryrun
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import all_arch_ids, get_config
from repro.data.pipeline import Batch
from repro.distributed import sharding as shlib
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch import specs as speclib
from repro.models import common as cm
from repro.models.config import get_shape_cell
from repro.models.model import build_model
from repro.optim import make_optimizer
from repro.train.step import TrainState, make_train_step


def pick_optimizer(cfg, total_params: int):
    """Memory-driven optimizer policy (see EXPERIMENTS.md §Dry-run)."""
    if total_params > 100e9:
        return make_optimizer("adafactor"), "adafactor"
    if total_params > 5e9:
        return make_optimizer("adamw", moment_dtype="bfloat16"), "adamw-bf16"
    return make_optimizer("adamw"), "adamw-fp32"


def pick_accum(cfg) -> Tuple[int, str]:
    """Per-arch microbatching policy for train_4k so activations + grad
    accumulators fit 16 GiB HBM (derived empirically from memory_analysis;
    recorded in EXPERIMENTS.md §Dry-run)."""
    if cfg.param_count() > 100e9:           # grok-1-314b
        return 16, "bfloat16"
    if cfg.family == "moe":
        return 2, "float32"                 # mixtral (tp_sp)
    if cfg.family == "hybrid":
        return 2, "float32"                 # zamba2 (tp_sp; fsdp needs >16G)
    return 1, "float32"


def lower_cell(arch: str, shape: str, multi_pod: bool,
               banded: bool = True, accum_steps: Optional[int] = None,
               compile_: bool = True, vocab_parallel: bool = True,
               bf16_tp_reduce: bool = False,
               gather_weights: bool = True,
               mode: str = "auto") -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_config(arch)
    cell = get_shape_cell(shape)
    ok, why = speclib.cell_supported(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes(multi_pod)
    if mode in ("fsdp", "auto"):
        total = 1
        for n in dp + ("model",):
            total *= mesh.shape[n]
        # fsdp mode: every MICROBATCH must cover the whole mesh, weight
        # gathers must be cheaper than activation reshards (excludes MoE and
        # >20B dense), and per-device activations must fit (excludes
        # nemotron's 24k d_ff).  Policy derived from measured temp bytes —
        # see EXPERIMENTS.md SS Dry-run.
        accum_probe = accum_steps or pick_accum(cfg)[0]
        micro = cell.global_batch // max(accum_probe, 1)
        fsdp_ok = (cell.kind == "train" and micro % total == 0
                   and cfg.family not in ("moe", "hybrid")
                   and cfg.param_count() < 20e9 and cfg.d_ff <= 16384)
        if mode == "auto":
            mode = "fsdp" if fsdp_ok else "tp_sp"
        elif not fsdp_ok:
            mode = "tp_sp"   # fsdp prerequisites not met
    env = cm.ShardEnv(mesh=mesh, dp=dp, tp="model",
                      vocab_parallel=vocab_parallel,
                      bf16_tp_reduce=bf16_tp_reduce,
                      gather_weights=gather_weights, mode=mode)
    batch_dp = env.batch_axes
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)

    abstract_params = jax.eval_shape(model.init, key)
    p_shardings = shlib.param_shardings(abstract_params, mesh)

    result = {"arch": arch, "shape": shape,
              "multi_pod": multi_pod, "kind": cell.kind,
              "params_b": cfg.param_count() / 1e9,
              "active_params_b": cfg.active_param_count() / 1e9}

    if cell.kind == "train":
        opt, opt_name = pick_optimizer(cfg, cfg.param_count())
        auto_accum, accum_dtype = pick_accum(cfg)
        if accum_steps is None:
            accum_steps = auto_accum
        # every microbatch must stay divisible by the dp extent, or the
        # batch sharding sanitizes away and compute replicates
        dp_total = 1
        for n in dp:
            dp_total *= mesh.shape[n]
        while accum_steps > 1 and (cell.global_batch // accum_steps) % dp_total:
            accum_steps //= 2
        result["optimizer"] = opt_name
        result["accum_steps"] = accum_steps
        result["mode"] = mode
        abstract_opt = jax.eval_shape(opt.init, abstract_params)
        o_shardings = shlib.opt_state_shardings(abstract_opt, abstract_params,
                                                mesh)
        state_shardings = TrainState(params=p_shardings,
                                     opt_state=o_shardings)
        abstract_state = TrainState(params=abstract_params,
                                    opt_state=abstract_opt)
        batch = speclib.batch_spec(cfg, cell)
        b_shardings = shlib.to_shardings(
            shlib.batch_specs(batch, mesh, batch_dp), mesh)
        step = make_train_step(model, opt, env, accum_steps=accum_steps,
                               banded=banded, accum_dtype=accum_dtype)
        jitted = jax.jit(step, in_shardings=(state_shardings, b_shardings),
                         out_shardings=(state_shardings, None),
                         donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(abstract_state, batch)
    elif cell.kind == "prefill":
        batch = speclib.batch_spec(cfg, cell)
        b_shardings = shlib.to_shardings(
            shlib.batch_specs(batch, mesh, dp), mesh)

        def prefill_step(params, batch: Batch):
            hidden, _ = model.module.forward_hidden(
                params, cfg, batch.tokens, batch.patches, env, banded)
            last = hidden[:, -1:, :]
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            logits = jnp.einsum("btd,dv->btv", last, head,
                                preferred_element_type=jnp.float32)
            return logits

        jitted = jax.jit(prefill_step,
                         in_shardings=(p_shardings, b_shardings))
        with mesh:
            lowered = jitted.lower(abstract_params, batch)
    else:  # decode
        dspec = speclib.decode_specs(model, cell)
        cache, tokens = dspec["cache"], dspec["tokens"]
        c_shardings = shlib.to_shardings(
            shlib.cache_specs(cache, mesh, dp), mesh)
        t_shardings = shlib.to_shardings(
            shlib.batch_specs(tokens, mesh, dp), mesh)

        def serve_step(params, cache, tokens):
            return model.decode_step(params, cache, tokens, env)

        jitted = jax.jit(serve_step, in_shardings=(p_shardings, c_shardings,
                                                   t_shardings),
                         out_shardings=(None, c_shardings),
                         donate_argnums=(1,))
        with mesh:
            lowered = jitted.lower(abstract_params, cache, tokens)

    result["lower_s"] = round(time.time() - t0, 1)
    if not compile_:
        result["status"] = "lowered"
        return result

    t1 = time.time()
    with mesh:
        compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
    }
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    result["cost"] = {      # raw XLA numbers (loop bodies counted ONCE)
        "flops": cost.get("flops"),
        "bytes_accessed": cost.get("bytes accessed"),
    }
    # trip-count-aware per-device analysis from the post-SPMD optimized HLO
    from benchmarks.hlo_analysis import analyze_hlo
    try:
        result["hlo"] = analyze_hlo(compiled.as_text())
    except Exception as e:                                   # noqa: BLE001
        result["hlo"] = {"error": str(e)}
    result["chips"] = 512 if multi_pod else 256
    result["status"] = "ok"
    result["total_s"] = round(time.time() - t0, 1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["on", "off", "both"],
                    default="off")
    ap.add_argument("--no-banded", action="store_true",
                    help="paper-faithful dense attention baseline")
    ap.add_argument("--accum-steps", type=int, default=None)
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    archs = all_arch_ids() if args.arch == "all" else [args.arch]
    shapes = (["train_4k", "prefill_32k", "decode_32k", "long_500k"]
              if args.shape == "all" else [args.shape])
    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch} x {shape} x {'2pod' if mp else '1pod'}"
                try:
                    r = lower_cell(arch, shape, mp, banded=not args.no_banded,
                                   accum_steps=args.accum_steps,
                                   compile_=not args.no_compile)
                except Exception as e:                       # noqa: BLE001
                    r = {"arch": arch, "shape": shape, "multi_pod": mp,
                         "status": "error", "error": str(e),
                         "traceback": traceback.format_exc()}
                results.append(r)
                status = r["status"]
                extra = ""
                if status == "ok":
                    peak = (r.get("memory") or {}).get("temp_bytes")
                    hlo = r.get("hlo", {})
                    extra = (f" flops/dev={hlo.get('flops', 0):.3e}"
                             f" coll/dev={hlo.get('collective_bytes', 0):.3e}B"
                             f" temp={peak/2**30 if peak else -1:.2f}GiB"
                             f" ({r.get('total_s')}s)")
                elif status == "error":
                    extra = " " + r["error"][:200]
                print(f"[{status:7s}] {tag}{extra}", flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", args.out)
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"done: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
