"""Batched serving driver: prefill + greedy decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 32

Demonstrates the full serving path for every family: transformer KV caches
(with rolling buffers on sliding-window layers), SSM constant-size states,
hybrid mixed caches, and enc-dec encoder-once decoding.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import common as cm
from repro.models.model import build_model


def prefill_into_cache(model, params, cache, tokens, env=cm.NO_SHARD):
    """Feed a prompt token-by-token through decode_step (simple, exercises
    the cache path; a production system would use the prefill kernel)."""
    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t, env))
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = step(params, cache, tokens[:, i:i + 1])
    return logits, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    b = args.batch
    max_len = args.prompt_len + args.gen

    kw = {}
    if cfg.family == "encdec":
        kw["enc_len"] = args.prompt_len
    cache = model.init_cache(b, max_len, **kw)
    if cfg.family == "encdec":
        from repro.models import encdec
        frames = jax.random.normal(key, (b, args.prompt_len,
                                         cfg.frontend_dim), jnp.float32)
        cache["enc_out"] = encdec.encode(params, cfg, frames)

    prompt = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)
    t0 = time.time()
    logits, cache = prefill_into_cache(model, params, cache, prompt)
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    t_gen = time.time() - t0
    tps = b * (args.gen - 1) / max(t_gen, 1e-9)
    print(f"arch={cfg.name} batch={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {t_prefill:.2f}s, decode {t_gen:.2f}s "
          f"({tps:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())
    return gen


if __name__ == "__main__":
    main()
