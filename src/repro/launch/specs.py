"""ShapeDtypeStruct stand-ins for every (arch × shape-cell) input.

``input_specs`` returns abstract inputs for the dry-run: weak-type-correct,
shardable, zero allocation.  Train cells produce a Batch spec; decode cells
produce (tokens, cache) specs built via ``jax.eval_shape`` over the model's
cache constructor.

Cell skip policy (DESIGN.md §5): ``long_500k`` only for sub-quadratic archs
(ssm/hybrid/sliding-window); nothing else is skipped.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import Batch
from repro.models.config import ModelConfig, ShapeCell, get_shape_cell
from repro.models.model import Model, build_model

# archs with bounded-window or recurrent context -> long_500k runnable
_SUBQUADRATIC = {"ssm", "hybrid"}


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    if cell.name == "long_500k":
        if cfg.family in _SUBQUADRATIC:
            return True, ""
        if cfg.attn_window > 0:
            return True, ""  # SWA / local-global: rolling caches bound memory
        return False, ("pure full-attention arch: 500k decode KV grows "
                       "unboundedly; skipped per DESIGN.md")
    return True, ""


def batch_spec(cfg: ModelConfig, cell: ShapeCell) -> Batch:
    """Abstract Batch for train/prefill cells (mirrors data.pipeline logic)."""
    b, s = cell.global_batch, cell.seq_len
    patches = None
    if cfg.frontend == "vision":
        s = max(8, s - cfg.frontend_tokens)
        patches = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    elif cfg.frontend == "audio":
        enc_len = cell.seq_len
        s = min(s, 4096)
        patches = jax.ShapeDtypeStruct((b, enc_len, cfg.frontend_dim),
                                       jnp.float32)
    tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
    return Batch(tokens=tokens, labels=jax.ShapeDtypeStruct((b, s), jnp.int32),
                 patches=patches)


def decode_specs(model: Model, cell: ShapeCell) -> Dict[str, Any]:
    """Abstract (tokens, cache) for decode cells: one new token against a
    cache of ``cell.seq_len`` context."""
    cfg = model.cfg
    b = cell.global_batch
    kw = {}
    if cfg.family == "encdec":
        kw["enc_len"] = min(cell.seq_len, 32768)
    cache = jax.eval_shape(
        lambda: model.init_cache(b, cell.seq_len, **kw))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return {"tokens": tokens, "cache": cache}


def input_specs(cfg: ModelConfig, cell_name: str) -> Dict[str, Any]:
    cell = get_shape_cell(cell_name)
    ok, why = cell_supported(cfg, cell)
    if not ok:
        raise ValueError(f"{cfg.name} x {cell_name} skipped: {why}")
    model = build_model(cfg)
    if cell.kind in ("train", "prefill"):
        return {"batch": batch_spec(cfg, cell), "kind": cell.kind}
    return {**decode_specs(model, cell), "kind": "decode"}
