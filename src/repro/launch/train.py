"""End-to-end training driver (fault-tolerant).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (1 CPU here; the production mesh path is the
same code — pass --mesh data,model=16,16 on a pod).  Features: deterministic
synthetic pipeline, AdamW + cosine, per-layer remat, async checkpointing,
automatic resume, heartbeat, optional crash injection to exercise the
restart path.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import pipeline_for_model
from repro.distributed import sharding as shlib
from repro.distributed.fault_tolerance import Heartbeat, run_with_restarts
from repro.models import common as cm
from repro.models.model import build_model
from repro.optim import make_optimizer
from repro.train.step import TrainState, init_state, make_train_step


def parse_mesh(spec: str):
    if not spec:
        return None, ("data",)
    names, shape = [], []
    for part in spec.split(","):
        k, v = part.split("=")
        names.append(k)
        shape.append(int(v))
    mesh = jax.make_mesh(tuple(shape), tuple(names),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    dp = tuple(n for n in names if n != "model")
    return mesh, dp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--mesh", default="", help="e.g. data=2,model=2")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="inject a failure at this step (tests restart)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    mesh, dp = parse_mesh(args.mesh)
    env = cm.ShardEnv(mesh=mesh, dp=dp, tp="model") if mesh else cm.NO_SHARD

    pipe = pipeline_for_model(cfg, args.batch, args.seq, mesh, dp)
    opt = make_optimizer(args.optimizer, peak_lr=args.lr, warmup=10,
                         total=args.steps)
    step_fn_inner = make_train_step(model, opt, env,
                                    accum_steps=args.accum_steps)
    jit_step = jax.jit(step_fn_inner, donate_argnums=(0,))

    def make_init():
        return init_state(model, opt, jax.random.PRNGKey(0))

    hb = Heartbeat(f"{args.ckpt_dir}/heartbeat.json")
    crashed = {"done": False}
    losses = []
    t0 = time.time()

    def step_fn(state, step):
        if step == args.crash_at and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected failure (testing restart)")
        batch = pipe.batch_at(step)
        state, metrics = jit_step(state, batch)
        return state, metrics

    def on_metrics(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)

    state_shardings = None
    if mesh is not None:
        abstract = jax.eval_shape(make_init)
        state_shardings = TrainState(
            params=shlib.param_shardings(abstract.params, mesh),
            opt_state=shlib.opt_state_shardings(abstract.opt_state,
                                                abstract.params, mesh))

    state, stats = run_with_restarts(
        init_state=make_init, step_fn=step_fn, ckpt_root=args.ckpt_dir,
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        heartbeat=hb, state_shardings=state_shardings,
        on_metrics=on_metrics)

    first = sum(losses[:10]) / max(len(losses[:10]), 1)
    last = sum(losses[-10:]) / max(len(losses[-10:]), 1)
    print(f"done: steps={args.steps} failures={stats.failures} "
          f"loss {first:.4f} -> {last:.4f} "
          f"({time.time() - t0:.1f}s)")
    return state


if __name__ == "__main__":
    main()
