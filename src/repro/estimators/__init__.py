"""repro.estimators — the dislib-style fit/predict layer over ds-arrays.

The paper's ds-array exists to power dislib's estimator collection; this
package is that layer for the reproduction: a sklearn-shaped contract
(``base``) and the three estimators the paper's evaluation names —
CascadeSVM (§6, the sparse backend's target workload), linear models
(normal equations + TSQR fallback) and a random forest (histogram trees on
the stacked tensor).  ``repro.algorithms``'s KMeans / ALS / PCA implement
the same :class:`BaseEstimator` contract (import them from there — this
package does not re-export them, to keep the import graph acyclic).

Model registry: ``save_model``/``load_model`` persist fitted estimators
through ``repro.checkpoint``; :func:`load_model` here dispatches on the
class name recorded in the manifest — ``repro.algorithms`` names resolve
lazily at call time, so the import graph stays acyclic.
"""

from repro.estimators.base import (BaseClassifier, BaseEstimator,
                                   BaseRegressor, NotFittedError,
                                   resolve_estimator)
from repro.estimators.csvm import CascadeSVM
from repro.estimators.forest import RandomForestClassifier
from repro.estimators.linear import LinearRegression, Ridge


def load_model(directory: str, version=None) -> BaseEstimator:
    """Reconstruct any saved model: the manifest names the class, the
    registry (estimators exports, then ``repro.algorithms``) resolves it.
    ``version`` pins a checkpoint step (default: newest committed)."""
    return BaseEstimator.load_model(directory, version=version)


__all__ = [
    "BaseEstimator", "BaseClassifier", "BaseRegressor", "NotFittedError",
    "CascadeSVM", "LinearRegression", "Ridge", "RandomForestClassifier",
    "load_model", "resolve_estimator",
]
